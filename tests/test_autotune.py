"""Autotuner subsystem tests (DESIGN.md §Autotuner).

Covers: the kernel-aware ``bucket_size``/``row_block`` shape math, tuned
configs bitwise-equal to the reference-default path across (pool-rows, dim)
buckets, persisted-cache round-trip + corrupt/partial-file rejection, the
``PoolTilePolicy`` bridge (bitwise encodes + closed signature universe), and
the ValueError shape contracts that replaced bare asserts.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import bucket_size
from repro.kernels import autotune as at
from repro.kernels import ops
from repro.kernels.ref import gather_fuse_ref, intersect_ref, scoring_ref


@pytest.fixture
def tuner(tmp_path):
    return at.KernelTuner(path=str(tmp_path / "tiles.json"), iters=1,
                          warmup=0)


@pytest.fixture(autouse=True)
def _isolate_global_tuner():
    prev = at.set_tuner(None)
    yield
    at.set_tuner(prev)


# ------------------------------------------------------------- shape math
def test_pow2ceil_and_ceil_to():
    assert [at.pow2ceil(n) for n in (0, 1, 2, 3, 8, 9)] == [1, 1, 2, 4, 8, 16]
    assert at.ceil_to(13, 8) == 16
    assert at.ceil_to(16, 8) == 16


@pytest.mark.parametrize("n", [1, 3, 8, 13, 100, 288, 511])
@pytest.mark.parametrize("tile", [1, 8, 32, 128, 256])
def test_row_block_properties(n, tile):
    block, padded = at.row_block(n, tile)
    assert padded >= n
    assert padded % block == 0
    assert block <= max(8, at.pow2ceil(n))
    # Never worse than bare pow2 padding.
    assert padded <= max(8, at.pow2ceil(n))


@pytest.mark.parametrize("n", [1, 5, 17, 100, 288, 500, 512, 700])
@pytest.mark.parametrize("b_max", [128, 512])
@pytest.mark.parametrize("tile", [1, 8, 64, 256])
def test_bucket_size_kernel_aware(n, b_max, tile):
    pow2 = bucket_size(n, b_max)
    tiled = bucket_size(n, b_max, tile)
    assert tiled <= pow2                       # never MORE pad than pow2
    assert tiled >= min(n, b_max)              # still covers the pool
    if tile > 1 and n < b_max:
        assert tiled % min(tile, pow2) == 0    # launch-aligned
    assert bucket_size(n, b_max, 1) == pow2    # tile=1 is the legacy rule


def test_bucket_size_saves_pad_waste():
    # The motivating case: 288 rows with a 128-row tile pads to 384, not 512.
    assert bucket_size(288, 512) == 512
    assert bucket_size(288, 512, 128) == 384


# -------------------------------------------- tuned configs are bitwise
@pytest.mark.parametrize("bucket", [(8, 128, 32), (32, 256, 64)])
def test_scoring_tuned_bitwise(tuner, bucket, rng):
    cfg = tuner.tune("scoring", bucket)
    B, N, d = 7, 100, bucket[2]
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    tuned = ops.scoring(q, e, gamma=1.5, mode="dot", bm=cfg["bm"],
                        bn=cfg["bn"], bk=cfg["bk"], interpret=True)
    default = ops.scoring(q, e, gamma=1.5, mode="dot", interpret=True)
    assert np.array_equal(np.asarray(tuned), np.asarray(default))
    np.testing.assert_allclose(
        np.asarray(tuned), np.asarray(scoring_ref(q, e, 1.5, "dot")),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bucket", [(16, 2, 32, 64), (64, 3, 16, 32)])
def test_intersect_tuned_bitwise(tuner, bucket, rng):
    cfg = tuner.tune("intersect", bucket)
    n, k, d, hd = 13, bucket[1], bucket[2], bucket[3]
    x = jnp.asarray(rng.normal(size=(n, k, d)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(d, hd)) * 0.2, jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(hd,)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(hd, 1)) * 0.2, jnp.float32)
    b2 = jnp.zeros((1,), jnp.float32)
    tuned = ops.intersect(x, w1, b1, w2, b2, bn=cfg["bn"], interpret=True)
    default = ops.intersect(x, w1, b1, w2, b2, interpret=True)
    assert np.array_equal(np.asarray(tuned), np.asarray(default))
    np.testing.assert_allclose(
        np.asarray(tuned), np.asarray(intersect_ref(x, w1, b1, w2, b2)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows", [1, 4, 16])
def test_gather_fuse_rows_bitwise(rows, rng):
    """Every blocked launch geometry produces the SAME bits as the rows=1
    scalar-prefetch path — blocking only moves work."""
    E, d, dl, dp, n = 60, 16, 8, 4, 21
    ids = jnp.asarray(rng.integers(0, E, n), jnp.int32)
    h_str = jnp.asarray(rng.normal(size=(E, d)), jnp.float32)
    h_sem = jnp.asarray(rng.normal(size=(E, dl)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(dl, dp)) * 0.2, jnp.float32)
    bp = jnp.asarray(rng.normal(size=(dp,)) * 0.1, jnp.float32)
    wf = jnp.asarray(rng.normal(size=(d + dp, d)) * 0.2, jnp.float32)
    bf = jnp.zeros((d,), jnp.float32)
    base = ops.gather_fuse(ids, h_str, h_sem, wp, bp, wf, bf, rows=1,
                           interpret=True)
    out = ops.gather_fuse(ids, h_str, h_sem, wp, bp, wf, bf, rows=rows,
                          interpret=True)
    assert np.array_equal(np.asarray(base), np.asarray(out))
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(gather_fuse_ref(ids, h_str, h_sem, wp, bp, wf, bf)),
        rtol=1e-5, atol=1e-5)


def test_sweep_rejects_nonbitwise_candidates(tuner, monkeypatch):
    """A candidate whose output differs by one bit must be rejected, not
    timed into the cache."""
    real = at._make_runner

    def poisoned(op, bucket, dtype, interpret):
        run, args = real(op, bucket, dtype, interpret)

        def bad_run(cfg, *a):
            out = run(cfg, *a)
            if cfg.get("bn") == 8:  # poison one non-default candidate
                return jnp.asarray(np.asarray(out) + 1e-7)
            return out

        return bad_run, args

    monkeypatch.setattr(at, "_make_runner", poisoned)
    cfg = tuner.tune("intersect", (16, 2, 16, 32))
    assert cfg["bn"] != 8
    assert int(tuner.verify_rejects) >= 1


# ------------------------------------------------------ persisted cache
def test_cache_roundtrip(tuner, tmp_path):
    cfg = tuner.tune("intersect", (16, 2, 16, 32))
    assert os.path.exists(tuner.path)
    fresh = at.KernelTuner(path=tuner.path, iters=1, warmup=0)
    assert len(fresh) == 1
    assert fresh.tune("intersect", (16, 2, 16, 32)) == cfg
    assert int(fresh.sweeps) == 0  # served from disk, no re-sweep


@pytest.mark.parametrize("payload", [
    "not json at all {{{",
    '{"version": 99, "entries": {}}',
    '{"version": 1}',
    '{"version": 1, "entries": {"k": {"op": "intersect"}}}',
    '{"version": 1, "entries": {"k": {"op": "nope", "config": {"bn": 8}}}}',
    '{"version": 1, "entries": {"k": {"op": "intersect", '
    '"config": {"bn": -4}}}}',
    '{"version": 1, "entries": {"k": {"op": "intersect", '
    '"config": {"wrong_key": 8}}}}',
])
def test_corrupt_cache_rejected_not_crashed(tmp_path, payload):
    p = tmp_path / "tiles.json"
    p.write_text(payload)
    t = at.KernelTuner(path=str(p), iters=1, warmup=0)
    assert len(t) == 0                 # nothing partial leaked in
    assert t.load_error is not None    # and the rejection is recorded
    assert int(t.load_rejects) == 1
    # ...and the tuner still tunes (retune instead of crash).
    cfg = t.tune("intersect", (16, 2, 16, 32))
    assert set(cfg) == {"bn"}
    # The rewrite repaired the file.
    fresh = at.KernelTuner(path=str(p))
    assert fresh.load_error is None and len(fresh) == 1


def test_partial_write_never_visible(tuner):
    """Crash-safe publish: the cache file is always complete JSON (tmp +
    rename), so a reader can never observe partial bytes."""
    tuner.tune("intersect", (16, 2, 16, 32))
    with open(tuner.path) as f:
        payload = json.load(f)
    assert payload["version"] == at.CACHE_VERSION
    assert not os.path.exists(tuner.path + ".tmp")


def test_env_var_names_default_cache(tmp_path, monkeypatch):
    monkeypatch.setenv(at.ENV_CACHE, str(tmp_path / "env_tiles.json"))
    at.set_tuner(None)
    t = at.get_tuner()
    assert t.path == str(tmp_path / "env_tiles.json")


# ---------------------------------------------------- PoolTilePolicy
def _tuned_policy_for(model, tuner, b_max=64):
    n = at.tune_for_model(model, tuner, b_max=b_max, batch=16)
    assert n > 0
    policy = at.pool_tile_policy(model, tuner, b_max=b_max)
    assert policy  # entries matched the model dims
    return policy


def test_pool_tile_policy_bitwise_and_closed(tiny_kg, tuner, rng):
    import jax

    from repro.core import PooledExecutor
    from repro.models import ModelConfig, make_model
    from repro.sampling import OnlineSampler

    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    policy = _tuned_policy_for(model, tuner)
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    qs = [s.query for s in OnlineSampler(tiny_kg, seed=3).sample_batch(24)]
    tuned_ex = PooledExecutor(model, b_max=64, tile_policy=policy)
    plain_ex = PooledExecutor(model, b_max=64, tile_policy=None)
    enc_t = np.asarray(tuned_ex.encode(params, qs))
    enc_p = np.asarray(plain_ex.encode(params, qs))
    assert np.array_equal(enc_t, enc_p)  # padding must not move real rows

    # Closed signature universe: replaying the same queries compiles nothing.
    tuned_ex.reset_cache_counters()
    np.asarray(tuned_ex.encode(params, qs))
    stats = tuned_ex.cache_stats()
    assert all(int(stats[k]["misses"]) == 0
               for k in ("schedule", "encode", "encode_jit")), stats


def test_policy_key_separates_cache_entries(tiny_kg, tuner):
    """Two executors with different tunings must not alias schedules: the
    policy key is part of every schedule/plan cache key."""
    from repro.core.compiler import compile_batch
    from repro.models import ModelConfig, make_model
    from repro.sampling import OnlineSampler

    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    policy = _tuned_policy_for(model, tuner)
    qs = [s.query for s in OnlineSampler(tiny_kg, seed=3).sample_batch(24)]
    plain = compile_batch(qs, model_name=model.name, b_max=64)
    tuned = compile_batch(qs, model_name=model.name, b_max=64,
                          tile_policy=policy)
    assert plain.structure_key != tuned.structure_key


def test_untuned_tuner_means_no_policy():
    from repro.models import ModelConfig, make_model

    model = make_model("gqe", ModelConfig(dim=8))
    t = at.KernelTuner()  # no entries
    assert at.pool_tile_policy(model, t) is None


def test_executor_auto_snapshots_process_tuner(tiny_kg, tuner):
    from repro.core import PooledExecutor
    from repro.models import ModelConfig, make_model

    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    _tuned_policy_for(model, tuner)
    at.set_tuner(tuner)
    ex = PooledExecutor(model, b_max=64)  # tile_policy="auto"
    assert ex.tile_policy
    at.set_tuner(None)
    ex2 = PooledExecutor(model, b_max=64)
    assert ex2.tile_policy is None


# ------------------------------------------------- ValueError contracts
def test_scoring_shape_errors():
    from repro.kernels.scoring import scoring_pallas

    q = jnp.zeros((10, 128), jnp.float32)
    e = jnp.zeros((256, 128), jnp.float32)
    with pytest.raises(ValueError, match="B=10.*bm=128"):
        scoring_pallas(q, e, bm=128, bn=256, bk=128, interpret=True)
    with pytest.raises(ValueError, match="N=100.*bn=256"):
        scoring_pallas(jnp.zeros((128, 128)), jnp.zeros((100, 128)),
                       bm=128, bn=256, bk=128, interpret=True)
    with pytest.raises(ValueError, match="d=64.*bk=128"):
        scoring_pallas(jnp.zeros((128, 64)), jnp.zeros((256, 64)),
                       bm=128, bn=256, bk=128, interpret=True)
    with pytest.raises(ValueError, match="d=128 != e feature dim d=64"):
        scoring_pallas(jnp.zeros((128, 128)), jnp.zeros((256, 64)),
                       bm=128, bn=256, bk=64, interpret=True)


def test_intersect_shape_errors():
    from repro.kernels.intersect import intersect_pallas

    x = jnp.zeros((10, 2, 32), jnp.float32)
    w1 = jnp.zeros((32, 64), jnp.float32)
    b1 = jnp.zeros((64,), jnp.float32)
    w2 = jnp.zeros((64, 128), jnp.float32)
    b2 = jnp.zeros((128,), jnp.float32)
    with pytest.raises(ValueError, match="n=10.*bn=256"):
        intersect_pallas(x, w1, b1, w2, b2, bn=256, interpret=True)
    with pytest.raises(ValueError, match="input dim 16 != state"):
        intersect_pallas(jnp.zeros((8, 2, 32)), jnp.zeros((16, 64)), b1,
                         w2, b2, bn=8, interpret=True)


def test_gather_fuse_shape_errors():
    from repro.kernels.gather_fuse import gather_fuse_pallas

    ids = jnp.zeros((10,), jnp.int32)
    h_str = jnp.zeros((16, 8), jnp.float32)
    h_sem = jnp.zeros((16, 4), jnp.float32)
    wp = jnp.zeros((4, 4), jnp.float32)
    bp = jnp.zeros((4,), jnp.float32)
    wf = jnp.zeros((12, 8), jnp.float32)
    bf = jnp.zeros((8,), jnp.float32)
    with pytest.raises(ValueError, match="n=10.*rows=4"):
        gather_fuse_pallas(ids, h_str, h_sem, wp, bp, wf, bf, rows=4,
                           interpret=True)
    with pytest.raises(ValueError, match="rows must be >= 1"):
        gather_fuse_pallas(ids, h_str, h_sem, wp, bp, wf, bf, rows=0,
                           interpret=True)
    with pytest.raises(ValueError, match="sem_ids shape"):
        gather_fuse_pallas(ids, h_str, h_sem, wp, bp, wf, bf,
                           jnp.zeros((4,), jnp.int32), rows=1,
                           interpret=True)


# ------------------------------------------------------------- metrics
def test_autotune_metrics_published(tuner):
    from repro.obs import get_registry

    tuner.tune("intersect", (16, 2, 16, 32))
    tuner.config_for("intersect", (16, 2, 16, 32))
    tuner.config_for("intersect", (999, 2, 16, 32))  # untuned -> default
    snap = get_registry().snapshot()
    assert snap["autotune_sweeps"] >= 1
    assert snap["autotune_lookup_hits"] >= 1
    assert snap["autotune_lookup_misses"] >= 1
    assert snap["autotune_entries"] == 1
