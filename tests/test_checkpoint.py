import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import (
    CheckpointManager,
    list_checkpoints,
    load_checkpoint,
    save_checkpoint,
)


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"m": jnp.zeros((2, 3)), "step": jnp.array(7)}}


def test_roundtrip(tmp_path, tree):
    save_checkpoint(str(tmp_path), 5, tree, metadata={"loss": 1.5})
    step, restored, meta = load_checkpoint(str(tmp_path), template=tree)
    assert step == 5
    assert meta["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_corruption_detected_falls_back(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, jax_tree_scale(tree, 2.0))
    # corrupt the newest checkpoint's arrays
    newest = list_checkpoints(str(tmp_path))[-1]
    path = os.path.join(newest, "arrays.npz")
    data = bytearray(open(path, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(path, "wb").write(bytes(data))
    step, restored, _ = load_checkpoint(str(tmp_path), template=tree)
    assert step == 1  # fell back to the older valid checkpoint


def jax_tree_scale(tree, s):
    import jax

    return jax.tree.map(lambda x: x * s if x.dtype.kind == "f" else x, tree)


def test_retention(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    for i in range(5):
        mgr.maybe_save(i + 1, tree)
    assert len(list_checkpoints(str(tmp_path))) == 2


def test_resume_trainer(tmp_path, tiny_kg):
    import jax

    from repro.models import ModelConfig, make_model
    from repro.training import AdamConfig, NGDBTrainer, TrainConfig

    cfg = TrainConfig(batch_size=8, n_negatives=4, b_max=16, prefetch=0,
                      patterns=("1p",), checkpoint_dir=str(tmp_path),
                      checkpoint_every=2, adam=AdamConfig(lr=1e-3))
    model = make_model("gqe", ModelConfig(dim=8))
    tr = NGDBTrainer(model, tiny_kg, cfg)
    tr.train(4, log_every=0)
    w_before = np.asarray(tr.params["entity"])

    tr2 = NGDBTrainer(model, tiny_kg, cfg)
    assert tr2.resume()
    assert tr2.step == 4
    np.testing.assert_array_equal(np.asarray(tr2.params["entity"]), w_before)


def test_empty_dir_resume(tmp_path):
    assert load_checkpoint(str(tmp_path)) is None
