"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import gather_fuse_ref, intersect_ref, scoring_ref


@pytest.mark.parametrize("B,N,d", [(8, 64, 32), (70, 333, 96), (128, 256, 128)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["dot", "l1"])
def test_scoring_sweep(B, N, d, dtype, mode, rng):
    q = jnp.asarray(rng.normal(size=(B, d)), dtype)
    e = jnp.asarray(rng.normal(size=(N, d)), dtype)
    out = ops.scoring(q, e, gamma=1.5, mode=mode, interpret=True)
    ref = scoring_ref(q.astype(jnp.float32), e.astype(jnp.float32), gamma=1.5, mode=mode)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,k,d,hd", [(16, 2, 32, 64), (100, 3, 64, 128), (64, 4, 128, 128)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_intersect_sweep(n, k, d, hd, dtype, rng):
    x = jnp.asarray(rng.normal(size=(n, k, d)), dtype)
    w1 = jnp.asarray(rng.normal(size=(d, hd)) * 0.2, jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(hd,)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(hd, 1)) * 0.2, jnp.float32)
    b2 = jnp.zeros((1,), jnp.float32)
    out = ops.intersect(x, w1, b1, w2, b2, interpret=True)
    ref = intersect_ref(x.astype(jnp.float32), w1, b1, w2, b2)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("E,d,dl,dp,n", [(40, 16, 32, 16, 8), (100, 64, 128, 32, 33)])
def test_gather_fuse_sweep(E, d, dl, dp, n, rng):
    ids = jnp.asarray(rng.integers(0, E, n), jnp.int32)
    h_str = jnp.asarray(rng.normal(size=(E, d)), jnp.float32)
    h_sem = jnp.asarray(rng.normal(size=(E, dl)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(dl, dp)) * 0.2, jnp.float32)
    bp = jnp.asarray(rng.normal(size=(dp,)) * 0.1, jnp.float32)
    wf = jnp.asarray(rng.normal(size=(d + dp, d)) * 0.2, jnp.float32)
    bf = jnp.zeros((d,), jnp.float32)
    # n=33 is not a multiple of the row block: wrapper must pad internally
    pad = (-n) % 8
    ids_p = jnp.concatenate([ids, jnp.zeros((pad,), jnp.int32)]) if pad else ids
    out = ops.gather_fuse(ids_p, h_str, h_sem, wp, bp, wf, bf, interpret=True)[:n]
    ref = gather_fuse_ref(ids, h_str, h_sem, wp, bp, wf, bf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_gather_fuse_matches_model_path(tiny_kg, rng):
    """The kernel must agree with QueryEncoder.fused_entity_vec (Eq. 12)."""
    import jax

    from repro.models import ModelConfig, make_model

    table = rng.normal(size=(tiny_kg.n_entities, 24)).astype(np.float32)
    model = make_model("gqe", ModelConfig(dim=16, semantic_dim=24, semantic_proj_dim=8))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations, semantic_table=table)
    ids = jnp.asarray(rng.integers(0, tiny_kg.n_entities, 16), jnp.int32)
    ref = model.fused_entity_vec(params, ids)
    out = ops.gather_fuse(ids, params["entity"], params["sem_table"],
                          params["sem_proj_w"], params["sem_proj_b"],
                          params["fuse_w"], params["fuse_b"], interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
