import numpy as np
import pytest

from repro.core import PATTERN_NAMES, answer_query
from repro.sampling import AdaptiveDistribution, OnlineSampler


def test_all_patterns_sampleable(tiny_kg):
    s = OnlineSampler(tiny_kg, seed=3)
    for pat in PATTERN_NAMES:
        sq = s.sample(pat)
        assert sq.query.pattern == pat
        assert len(sq.answers) > 0
        # rejection guarantee: oracle agrees the answers are non-empty
        assert answer_query(tiny_kg, sq.query) >= set(sq.answers.tolist()) or True
        assert set(sq.answers.tolist()) <= answer_query(tiny_kg, sq.query)


def test_batch_distribution(tiny_kg):
    s = OnlineSampler(tiny_kg, patterns=("1p", "2i"), seed=0)
    batch = s.sample_batch(64, dist={"1p": 1.0, "2i": 0.0})
    assert all(b.query.pattern == "1p" for b in batch)


def test_training_arrays_negative_filtering(tiny_kg):
    s = OnlineSampler(tiny_kg, seed=1)
    batch = s.sample_batch(16)
    queries, pos, neg = s.to_training_arrays(batch, n_negatives=8)
    assert pos.shape == (16,) and neg.shape == (16, 8)
    for i, b in enumerate(batch):
        assert pos[i] in b.answers
        assert not np.isin(neg[i], b.answers).any()


def test_adaptive_shifts_toward_hard():
    ad = AdaptiveDistribution(["1p", "2i", "3p"], ema=0.5, uniform_floor=0.2)
    for _ in range(10):
        ad.update({"1p": 0.1, "2i": 5.0, "3p": 0.1})
    d = ad.distribution()
    assert d["2i"] > d["1p"]
    assert d["2i"] > 1 / 3
    assert abs(sum(d.values()) - 1.0) < 1e-9
    # uniform floor keeps everything sampleable
    assert min(d.values()) >= 0.2 / 3 - 1e-9


def test_sampler_determinism(tiny_kg):
    a = OnlineSampler(tiny_kg, seed=42).sample_batch(8)
    b = OnlineSampler(tiny_kg, seed=42).sample_batch(8)
    for x, y in zip(a, b):
        assert x.query.key() == y.query.key()
