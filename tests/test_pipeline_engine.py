"""Pipelined dataflow engine (DESIGN.md §Pipeline): the pipeline must be
SEMANTICALLY INVISIBLE — identical numerics to the sync ablation baseline —
and the compile cache must never retrace a repeated schedule signature."""
import numpy as np
import pytest

import jax

from repro.core import CompileCache
from repro.data.pipeline import PreparedBatchPrefetcher
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training import AdamConfig, NGDBTrainer, TrainConfig


def _trainer(kg, pipeline: bool, **kw) -> NGDBTrainer:
    model = make_model(kw.pop("model", "gqe"), ModelConfig(dim=8))
    cfg = TrainConfig(batch_size=16, n_negatives=4, b_max=32, prefetch=2,
                      pipeline=pipeline, adam=AdamConfig(lr=1e-3), seed=0, **kw)
    return NGDBTrainer(model, kg, cfg)


@pytest.fixture(scope="module")
def replay_batches(tiny_kg):
    """Fixed mixed-pattern workload from a DEDICATED sampler so both engines'
    own samplers draw identical negative streams during replay."""
    src = OnlineSampler(tiny_kg, seed=123)
    return [src.sample_batch(16) for _ in range(5)]


def test_pipelined_matches_sync_numerics(tiny_kg, replay_batches):
    """Same workload through both engines -> identical per-step losses and
    bit-identical trained parameters."""
    tr_sync = _trainer(tiny_kg, pipeline=False)
    tr_pipe = _trainer(tiny_kg, pipeline=True)
    tr_sync.train(len(replay_batches), log_every=0, batches=replay_batches)
    tr_pipe.train(len(replay_batches), log_every=0, batches=replay_batches)

    losses_s = [r["loss"] for r in tr_sync.history]
    losses_p = [r["loss"] for r in tr_pipe.history]
    np.testing.assert_allclose(losses_s, losses_p, rtol=0, atol=0)
    for a, b in zip(jax.tree.leaves(tr_sync.params), jax.tree.leaves(tr_pipe.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipelined_matches_sync_betae(tiny_kg, replay_batches):
    """Second backbone: the equivalence is engine-level, not model-specific."""
    tr_sync = _trainer(tiny_kg, pipeline=False, model="betae")
    tr_pipe = _trainer(tiny_kg, pipeline=True, model="betae")
    tr_sync.train(3, log_every=0, batches=replay_batches[:3])
    tr_pipe.train(3, log_every=0, batches=replay_batches[:3])
    np.testing.assert_allclose([r["loss"] for r in tr_sync.history],
                               [r["loss"] for r in tr_pipe.history],
                               rtol=0, atol=0)


def test_compile_cache_100pct_hit_on_repeat(tiny_kg, replay_batches):
    """After one warm pass every signature is compiled: a replay of the same
    batch list must be 100% hits — ZERO retraces."""
    tr = _trainer(tiny_kg, pipeline=True)
    tr.train(len(replay_batches), log_every=0, batches=replay_batches)  # warm
    tr._train_fns.reset_counters()
    tr.train(2 * len(replay_batches), log_every=0, batches=replay_batches)
    st = tr._train_fns.stats()
    assert st["misses"] == 0
    assert st["hits"] == 2 * len(replay_batches)
    assert st["hit_rate"] == 1.0


def test_pipelined_respects_step_count_and_history(tiny_kg, replay_batches):
    tr = _trainer(tiny_kg, pipeline=True)
    tr.train(7, log_every=0, batches=replay_batches)
    assert tr.step == 7
    assert len(tr.history) == 7
    assert all(np.isfinite(r["loss"]) for r in tr.history)


def test_pipelined_online_sampling_smoke(tiny_kg):
    """No replay list: full pipeline with sampling workers + scheduler thread."""
    tr = _trainer(tiny_kg, pipeline=True)
    tr.train(3, log_every=0)
    assert tr.step == 3


# ----------------------------------------------------------- CompileCache
def test_compile_cache_lru_eviction_and_counters():
    c = CompileCache(capacity=2, name="t")
    assert c.get("a") is None                  # miss
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1                     # hit; "a" now most-recent
    c.put("c", 3)                              # evicts LRU "b"
    assert "b" not in c and "a" in c and "c" in c
    assert c.get("b") is None                  # miss after eviction
    st = c.stats()
    assert (st["hits"], st["misses"], st["evictions"], st["size"]) == (1, 2, 1, 2)
    assert st["hit_rate"] == pytest.approx(1 / 3)
    c.reset_counters()
    assert c.stats()["hits"] == 0 and len(c) == 2  # contents survive reset


def test_compile_cache_rejects_zero_capacity():
    with pytest.raises(ValueError):
        CompileCache(capacity=0)


def test_executor_cache_stats_exposed(tiny_kg, replay_batches):
    tr = _trainer(tiny_kg, pipeline=False)
    tr.train(2, log_every=0, batches=replay_batches[:2])
    stats = tr.compile_cache_stats()
    assert set(stats) == {"train_step", "schedule", "encode", "encode_jit"}
    assert stats["train_step"]["misses"] >= 1


def test_dev_static_keyed_by_structure_not_signature(tiny_kg):
    """5 vs 6 queries of one pattern can share a program SIGNATURE (same
    bucketed shapes) while having different slot/answer arrays — the device
    cache must key on the structure, not the signature."""
    from repro.data.pipeline import prepare_work_item

    tr = _trainer(tiny_kg, pipeline=False)
    src = OnlineSampler(tiny_kg, seed=5, patterns=("1p",))
    b5, b6 = src.sample_batch(5), src.sample_batch(6)
    cache = CompileCache(8, name="t")
    i5 = prepare_work_item(tr.sampler, tr.executor, b5, 4, cache)
    i6 = prepare_work_item(tr.sampler, tr.executor, b6, 4, cache)
    if i5.prepared.signature == i6.prepared.signature:  # the collision trap
        assert i5.prepared.structure_key != i6.prepared.structure_key
    assert int(i5.ans.shape[0]) == 5
    assert int(i6.ans.shape[0]) == 6


def test_pipelined_checkpoint_roundtrip(tiny_kg, replay_batches, tmp_path):
    """Checkpoint boundaries inside the dispatch window must snapshot params
    before donation invalidates them; resume restores the final state."""
    tr = _trainer(tiny_kg, pipeline=True, checkpoint_dir=str(tmp_path),
                  checkpoint_every=3)
    tr.train(5, log_every=0, batches=replay_batches)
    tr2 = _trainer(tiny_kg, pipeline=True, checkpoint_dir=str(tmp_path),
                   checkpoint_every=3)
    assert tr2.resume()
    assert tr2.step == 5  # final force-save
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------- PreparedBatchPrefetcher
def test_prefetcher_items_match_direct_prepare(tiny_kg, replay_batches):
    """Work items must carry canonical-order pos/neg consistent with the
    prepared batch the main thread would have built itself."""
    tr = _trainer(tiny_kg, pipeline=False)
    it = iter(replay_batches)
    pf = PreparedBatchPrefetcher(tr.sampler, tr.executor, 16, 4, depth=2,
                                 batch_fn=lambda: next(it))
    try:
        item = pf.next(timeout=30.0)
        assert item.n_queries == 16
        assert len(item.patterns) == 16
        assert item.pos.shape == (16,)
        assert item.neg.shape == (16, 4)
        # canonical order == pattern-sorted order of the prepared batch
        assert item.patterns == sorted(item.patterns)
        assert len(item.steps) == len(item.prepared.meta)
    finally:
        pf.close()


def test_prefetcher_propagates_worker_error(tiny_kg):
    def boom():
        raise ValueError("no batches for you")

    tr = _trainer(tiny_kg, pipeline=False)
    pf = PreparedBatchPrefetcher(tr.sampler, tr.executor, 16, 4, batch_fn=boom)
    with pytest.raises(RuntimeError, match="prefetcher failed"):
        pf.next(timeout=10.0)
    pf.close()


def test_prefetcher_close_is_prompt(tiny_kg, replay_batches):
    import itertools
    import time

    tr = _trainer(tiny_kg, pipeline=False)
    it = itertools.cycle(replay_batches)
    pf = PreparedBatchPrefetcher(tr.sampler, tr.executor, 16, 4, depth=2,
                                 batch_fn=lambda: next(it))
    pf.next(timeout=30.0)
    t0 = time.monotonic()
    pf.close()
    assert time.monotonic() - t0 < 5.0
    assert not pf._thread.is_alive()
