"""Kernels as first-class model features: ModelConfig(use_pallas=True) must
be numerically invisible across the public API (score_all, executor encode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PooledExecutor
from repro.models import ModelConfig, make_model


@pytest.mark.parametrize("name,mode", [("gqe", "l1"), ("complex", "dot")])
def test_score_all_kernel_parity(name, mode, tiny_kg):
    ref_model = make_model(name, ModelConfig(dim=16))
    k_model = make_model(name, ModelConfig(dim=16, use_pallas=True))
    assert k_model.pallas_score_mode == mode
    params = ref_model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                                   tiny_kg.n_relations)
    k_model.n_entities = ref_model.n_entities
    q = ref_model.embed(params, jnp.array([3, 5, 9]))
    ref = np.asarray(ref_model.score_all(params, q))
    got = np.asarray(k_model.score_all(params, q))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_betae_intersect_kernel_parity(tiny_kg, mixed_queries):
    """Full operator-level encode with the fused intersection kernel."""
    ref_model = make_model("betae", ModelConfig(dim=16))
    k_model = make_model("betae", ModelConfig(dim=16, use_pallas=True))
    params = ref_model.init_params(jax.random.PRNGKey(1), tiny_kg.n_entities,
                                   tiny_kg.n_relations)
    queries = [b.query for b in mixed_queries][:8]
    ref = np.asarray(PooledExecutor(ref_model, b_max=16).encode(params, queries))
    got = np.asarray(PooledExecutor(k_model, b_max=16).encode(params, queries))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)
