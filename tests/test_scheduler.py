import numpy as np
import pytest

from repro.core import OpType, build_batched_dag, schedule
from repro.core.scheduler import bucket_size


def _simulate(dag, sched):
    """Replay the schedule checking dependency order and slot liveness."""
    produced = {}      # node -> value (node id itself)
    slot_holder = {}   # slot -> node currently owning it
    node_done = np.zeros(dag.n_nodes, bool)
    for step in sched.steps:
        for bi, v in enumerate(step.node_ids):
            # deps must be complete AND their slots still hold their value
            for ci, j in enumerate(dag.inputs[v]):
                assert node_done[j], f"node {v} ran before dep {j}"
                slot = step.in_slots[bi, ci]
                assert slot_holder.get(slot) == j, (
                    f"slot {slot} was reclaimed before {v} consumed {j}"
                )
        for bi, v in enumerate(step.node_ids):
            node_done[v] = True
            slot_holder[step.out_slots[bi]] = v
    assert node_done.all(), "not every node executed"
    # answers live at the end
    for qi, a in enumerate(dag.answer_node):
        assert slot_holder[sched.answer_slots[qi]] == a


def test_schedule_valid_and_slots_safe(mixed_queries):
    dag = build_batched_dag([b.query for b in mixed_queries])
    for policy in ("max_fillness", "fifo"):
        sched = schedule(dag, b_max=32, policy=policy)
        _simulate(dag, sched)


def test_slot_reuse_reduces_peak(mixed_queries):
    dag = build_batched_dag([b.query for b in mixed_queries])
    with_reuse = schedule(dag, b_max=64, reuse_slots=True)
    without = schedule(dag, b_max=64, reuse_slots=False)
    assert with_reuse.n_slots < without.n_slots
    assert without.n_slots == dag.n_nodes
    _simulate(dag, with_reuse)


def _two_pool_dag():
    """10 EMBEDs; node 10 = INTERSECT(0,1) (discovered first, size-1 pool);
    nodes 11..18 = PROJECT(2..9) (size-8 pool). After the embed step both
    pools are ready: Max-Fillness must pick PROJECT, FIFO picks INTERSECT."""
    from repro.core.querydag import BatchedDAG

    ops = [int(OpType.EMBED)] * 10 + [int(OpType.INTERSECT)] + [int(OpType.PROJECT)] * 8
    inputs = [()] * 10 + [(0, 1)] + [(i,) for i in range(2, 10)]
    n = len(ops)
    n_consumers = np.zeros(n, dtype=np.int64)
    for inp in inputs:
        for j in inp:
            n_consumers[j] += 1
    answers = np.array([10, 18])
    n_consumers[answers] += 1
    return BatchedDAG(
        op=np.array(ops, np.int8),
        rel=np.where(np.array(ops) == int(OpType.PROJECT), 0, -1).astype(np.int64),
        anchor=np.where(np.array(ops) == int(OpType.EMBED), 1, -1).astype(np.int64),
        query_id=np.zeros(n, np.int64),
        inputs=inputs,
        n_consumers=n_consumers,
        answer_node=answers,
        patterns=["x", "y"],
    )


def test_max_fillness_picks_largest_pool():
    dag = _two_pool_dag()
    mf = schedule(dag, b_max=64, policy="max_fillness")
    ff = schedule(dag, b_max=64, policy="fifo")
    # step 0 is the embed pool in both; step 1 differs by policy
    assert mf.steps[1].op == OpType.PROJECT
    assert ff.steps[1].op == OpType.INTERSECT
    _simulate(dag, mf)
    _simulate(dag, ff)


def test_bucket_size():
    assert bucket_size(1, 512) == 1
    assert bucket_size(3, 512) == 4
    assert bucket_size(512, 512) == 512
    assert bucket_size(900, 512) == 512
    assert bucket_size(0, 512) == 1


def test_b_max_respected(mixed_queries):
    dag = build_batched_dag([b.query for b in mixed_queries] * 8)
    sched = schedule(dag, b_max=16)
    assert all(s.n <= 16 for s in sched.steps)
    _simulate(dag, sched)


def test_equivalence_classes(mixed_queries):
    """Pools are homogeneous in (op, cardinality) — Eq. 8."""
    dag = build_batched_dag([b.query for b in mixed_queries])
    sched = schedule(dag, b_max=128)
    for s in sched.steps:
        assert (dag.op[s.node_ids] == int(s.op)).all()
        for v in s.node_ids:
            card = len(dag.inputs[v])
            assert card == s.cardinality or s.op == OpType.EMBED
