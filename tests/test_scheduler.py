import numpy as np
import pytest

from repro.core import OpType, build_batched_dag, schedule
from repro.core.scheduler import bucket_size


def _simulate(dag, sched):
    """Replay the schedule checking dependency order and slot liveness."""
    produced = {}      # node -> value (node id itself)
    slot_holder = {}   # slot -> node currently owning it
    node_done = np.zeros(dag.n_nodes, bool)
    for step in sched.steps:
        for bi, v in enumerate(step.node_ids):
            # deps must be complete AND their slots still hold their value
            for ci, j in enumerate(dag.inputs[v]):
                assert node_done[j], f"node {v} ran before dep {j}"
                slot = step.in_slots[bi, ci]
                assert slot_holder.get(slot) == j, (
                    f"slot {slot} was reclaimed before {v} consumed {j}"
                )
        for bi, v in enumerate(step.node_ids):
            node_done[v] = True
            slot_holder[step.out_slots[bi]] = v
    assert node_done.all(), "not every node executed"
    # answers live at the end
    for qi, a in enumerate(dag.answer_node):
        assert slot_holder[sched.answer_slots[qi]] == a


def test_schedule_valid_and_slots_safe(mixed_queries):
    dag = build_batched_dag([b.query for b in mixed_queries])
    for policy in ("max_fillness", "fifo"):
        sched = schedule(dag, b_max=32, policy=policy)
        _simulate(dag, sched)


def test_slot_reuse_reduces_peak(mixed_queries):
    dag = build_batched_dag([b.query for b in mixed_queries])
    with_reuse = schedule(dag, b_max=64, reuse_slots=True)
    without = schedule(dag, b_max=64, reuse_slots=False)
    assert with_reuse.n_slots < without.n_slots
    assert without.n_slots == dag.n_nodes
    _simulate(dag, with_reuse)


def _two_pool_dag():
    """10 EMBEDs; node 10 = INTERSECT(0,1) (discovered first, size-1 pool);
    nodes 11..18 = PROJECT(2..9) (size-8 pool). After the embed step both
    pools are ready: Max-Fillness must pick PROJECT, FIFO picks INTERSECT."""
    from repro.core.querydag import BatchedDAG

    ops = [int(OpType.EMBED)] * 10 + [int(OpType.INTERSECT)] + [int(OpType.PROJECT)] * 8
    inputs = [()] * 10 + [(0, 1)] + [(i,) for i in range(2, 10)]
    n = len(ops)
    n_consumers = np.zeros(n, dtype=np.int64)
    for inp in inputs:
        for j in inp:
            n_consumers[j] += 1
    answers = np.array([10, 18])
    n_consumers[answers] += 1
    return BatchedDAG(
        op=np.array(ops, np.int8),
        rel=np.where(np.array(ops) == int(OpType.PROJECT), 0, -1).astype(np.int64),
        anchor=np.where(np.array(ops) == int(OpType.EMBED), 1, -1).astype(np.int64),
        query_id=np.zeros(n, np.int64),
        inputs=inputs,
        n_consumers=n_consumers,
        answer_node=answers,
        patterns=["x", "y"],
    )


def test_max_fillness_picks_largest_pool():
    dag = _two_pool_dag()
    mf = schedule(dag, b_max=64, policy="max_fillness")
    ff = schedule(dag, b_max=64, policy="fifo")
    # step 0 is the embed pool in both; step 1 differs by policy
    assert mf.steps[1].op == OpType.PROJECT
    assert ff.steps[1].op == OpType.INTERSECT
    _simulate(dag, mf)
    _simulate(dag, ff)


def test_bucket_size():
    assert bucket_size(1, 512) == 1
    assert bucket_size(3, 512) == 4
    assert bucket_size(512, 512) == 512
    assert bucket_size(900, 512) == 512
    assert bucket_size(0, 512) == 1


def test_bucket_size_boundaries():
    """n=0, n=b_max and non-pow2 b_max: the pow2 padding must respect the
    b_max cap (a padded size above b_max would desync slot-array padding
    from the pools the scheduler actually forms)."""
    # exact boundary: n == b_max for every b_max, pow2 or not
    for b in (1, 6, 16, 100, 512):
        assert bucket_size(b, b) == b
        assert bucket_size(b + 1, b) == b
        assert bucket_size(0, b) == 1
    # non-pow2 cap: next pow2 would overshoot the cap
    assert bucket_size(5, 6) == 6
    assert bucket_size(3, 6) == 4
    assert bucket_size(65, 100) == 100
    assert bucket_size(64, 100) == 64
    # padded size always covers the real rows
    for b in (1, 3, 6, 7, 100):
        for n in range(0, b + 2):
            assert n <= bucket_size(n, b) or n > b


def test_schedule_valid_with_non_pow2_b_max(mixed_queries):
    """Schedules stay executable (deps + slot liveness) when b_max is not a
    power of two, including b_max=1 (every pool a singleton)."""
    dag = build_batched_dag([b.query for b in mixed_queries])
    for b_max in (1, 3, 6, 7):
        sched = schedule(dag, b_max=b_max)
        assert all(s.n <= b_max for s in sched.steps)
        assert all(s.padded_n <= b_max or s.padded_n == 1 for s in sched.steps)
        _simulate(dag, sched)


def test_slot_allocator_reuses_lowest_free_slot_first():
    """The free list is a min-heap: reclaimed slots come back lowest-id
    first, so workspace rows stay dense and the peak never grows while any
    freed slot remains."""
    from repro.core.scheduler import _SlotAllocator

    a = _SlotAllocator()
    assert [a.alloc() for _ in range(8)] == list(range(8))
    assert a.peak == 8
    a.release(5)
    a.release(2)
    a.release(7)
    assert [a.alloc(), a.alloc(), a.alloc()] == [2, 5, 7]
    assert a.peak == 8          # reuse never bumps the peak
    assert a.alloc() == 8       # free list drained -> fresh slot
    assert a.peak == 9


def test_b_max_respected(mixed_queries):
    dag = build_batched_dag([b.query for b in mixed_queries] * 8)
    sched = schedule(dag, b_max=16)
    assert all(s.n <= 16 for s in sched.steps)
    _simulate(dag, sched)


def test_equivalence_classes(mixed_queries):
    """Pools are homogeneous in (op, cardinality) — Eq. 8."""
    dag = build_batched_dag([b.query for b in mixed_queries])
    sched = schedule(dag, b_max=128)
    for s in sched.steps:
        assert (dag.op[s.node_ids] == int(s.op)).all()
        for v in s.node_ids:
            card = len(dag.inputs[v])
            assert card == s.cardinality or s.op == OpType.EMBED
