"""Hypothesis property tests on the system's invariants.

The whole module skips when hypothesis isn't installed (it's an optional
test dependency: ``pip install -e ".[test]"``).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import PATTERN_NAMES, TEMPLATES, QueryInstance, build_batched_dag, schedule
from repro.core.scheduler import bucket_size
from repro.lm.moe import combine_from_experts, pack_by_expert

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True)
hypothesis.settings.load_profile("ci")


@given(st.integers(0, 4096), st.sampled_from([16, 64, 512]))
def test_bucket_size_properties(n, b_max):
    b = bucket_size(n, b_max)
    assert b >= min(n, b_max)          # fits (after chunking at b_max)
    assert b <= max(b_max, 1)
    if 0 < n <= b_max:
        assert b < 2 * n or b == 1     # at most 2x padding waste


@st.composite
def query_batches(draw):
    pats = draw(st.lists(st.sampled_from(PATTERN_NAMES), min_size=1, max_size=24))
    rng = np.random.default_rng(draw(st.integers(0, 1000)))
    qs = []
    for p in pats:
        t = TEMPLATES[p]
        qs.append(QueryInstance(p, rng.integers(0, 100, t.n_anchors),
                                rng.integers(0, 10, t.n_relations)))
    return qs


@given(query_batches(), st.sampled_from([4, 16, 128]),
       st.sampled_from(["max_fillness", "fifo"]))
def test_schedule_invariants(queries, b_max, policy):
    """Every node executes exactly once, deps-before-use, slots never clobbered
    while live, answers reachable — for ANY pattern mixture and B_max."""
    dag = build_batched_dag(queries)
    sched = schedule(dag, b_max=b_max, policy=policy)
    executed = np.zeros(dag.n_nodes, bool)
    slot_holder = {}
    for step in sched.steps:
        assert step.n <= b_max
        for bi, v in enumerate(step.node_ids):
            assert not executed[v], "node scheduled twice"
            for ci, j in enumerate(dag.inputs[v]):
                assert executed[j]
                assert slot_holder.get(step.in_slots[bi, ci]) == j
        for bi, v in enumerate(step.node_ids):
            executed[v] = True
            slot_holder[step.out_slots[bi]] = v
    assert executed.all()
    for qi, a in enumerate(dag.answer_node):
        assert slot_holder[sched.answer_slots[qi]] == a
    # peak slots never exceeds node count; reuse never loses correctness
    assert sched.n_slots <= dag.n_nodes


@given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 4),
       st.integers(0, 1000))
def test_moe_pack_combine_conservation(t, e, k, seed):
    """With ample capacity, pack+identity+combine reproduces gate-weighted x;
    with any capacity, outputs of dropped tokens are exactly zero."""
    k = min(k, e)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, 4)), jnp.float32)
    eidx = jnp.asarray(rng.integers(0, e, (t, k)))
    gates = jnp.asarray(rng.dirichlet(np.ones(k), size=t), jnp.float32)
    cap_full = t * k
    packed, meta = pack_by_expert(x, eidx, gates, e, cap_full)
    y = combine_from_experts(packed, meta, t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-4, atol=1e-5)

    cap_small = max(1, t // 4)
    packed2, meta2 = pack_by_expert(x, eidx, gates, e, cap_small)
    y2 = combine_from_experts(packed2, meta2, t)
    assert np.isfinite(np.asarray(y2)).all()
    # each packed row is either zero or one of the original rows
    pk = np.asarray(packed2).reshape(-1, 4)
    xs = np.asarray(x)
    for row in pk:
        if np.abs(row).sum() > 0:
            assert np.min(np.abs(xs - row).sum(axis=1)) < 1e-5


@given(st.integers(0, 1000))
def test_quantize_roundtrip_bound(seed):
    from repro.training.compression import dequantize_int8, quantize_int8

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6  # half-ulp rounding bound


def _filtered_ranks_oracle(scores: np.ndarray, answers: np.ndarray):
    """Brute-force twin of ``training/eval.py::filtered_ranks`` with the
    stable-argsort tie rule made explicit: entity ``e`` beats answer ``a``
    iff score[e] > score[a], or the scores tie and e has the smaller index
    (stable sort of -scores keeps index order within a tie)."""
    out = []
    ans = set(int(a) for a in answers)
    for a in answers:
        beats = sum(
            1
            for e in range(len(scores))
            if e not in ans
            and (scores[e] > scores[a] or (scores[e] == scores[a] and e < a))
        )
        out.append(1 + beats)
    return np.sort(np.array(out, dtype=np.int64))


@given(
    st.lists(st.integers(0, 4), min_size=2, max_size=24),  # ints force ties
    st.integers(0, 1000),
)
def test_filtered_ranks_vs_bruteforce(score_ints, seed):
    from repro.training.eval import filtered_ranks

    scores = np.array(score_ints, dtype=np.float64)
    rng = np.random.default_rng(seed)
    n_ans = int(rng.integers(1, len(scores) + 1))
    answers = rng.choice(len(scores), size=n_ans, replace=False)
    got = filtered_ranks(scores, answers)
    np.testing.assert_array_equal(got, _filtered_ranks_oracle(scores, answers))
    # filtered ranks are valid positions among (non-other-answer) entities
    assert got.min() >= 1
    assert got.max() <= len(scores) - len(answers) + 1


def test_filtered_ranks_all_answers_tied():
    """Every answer tied at the top rank filters to 1, 1, ..., 1."""
    from repro.training.eval import filtered_ranks

    scores = np.array([5.0, 5.0, 5.0, 1.0])
    np.testing.assert_array_equal(
        filtered_ranks(scores, np.array([0, 1, 2])), [1, 1, 1])


@given(
    st.integers(1, 5),                       # rows
    st.integers(1, 24),                      # entities
    st.integers(1, 30),                      # k (may exceed E)
    st.integers(0, 3),                       # score vocabulary -> tie density
    st.integers(0, 1000),
)
def test_topk_desc_vs_bruteforce(b, e, k, vocab, seed):
    """``topk_desc`` must return a true top-k set in descending score order
    for ANY tie structure and any k, including k >= E. Under ties the
    SELECTED IDS may differ from a full stable argsort (argpartition breaks
    ties arbitrarily), so the oracle checks the score multiset + the top-k
    set property, not id equality."""
    from repro.serving import topk_desc

    rng = np.random.default_rng(seed)
    scores = rng.integers(0, vocab + 1, size=(b, e)).astype(np.float64)
    idx = topk_desc(scores, k)
    kk = min(k, e)
    assert idx.shape == (b, kk)
    oracle = np.argsort(-scores, axis=1, kind="stable")[:, :kk]
    for i in range(b):
        row = idx[i]
        assert len(set(row.tolist())) == kk          # no duplicates
        picked = scores[i, row]
        assert (np.diff(picked) <= 0).all()          # descending
        # identical score multiset as the brute-force top-k ...
        np.testing.assert_array_equal(np.sort(picked),
                                      np.sort(scores[i, oracle[i]]))
        # ... and nothing outside the selection beats anything inside
        rest = np.delete(scores[i], row)
        if len(rest):
            assert rest.max() <= picked.min()
    if k >= e:  # full ranking: a permutation ordering every entity
        assert set(idx[0].tolist()) == set(range(e))


@given(query_batches())
def test_answer_slots_survive_reuse(queries):
    """Slot reuse must never hand an answer's slot to another node."""
    dag = build_batched_dag(queries)
    sched = schedule(dag, b_max=32, reuse_slots=True)
    ans = set(sched.answer_slots.tolist())
    owners = {}
    for step in sched.steps:
        for bi, v in enumerate(step.node_ids):
            s = int(step.out_slots[bi])
            owners.setdefault(s, []).append(v)
    for qi, a in enumerate(dag.answer_node):
        s = int(sched.answer_slots[qi])
        assert owners[s][-1] == a  # the answer is the LAST writer of its slot
