"""Hypothesis property tests on the system's invariants.

The whole module skips when hypothesis isn't installed (it's an optional
test dependency: ``pip install -e ".[test]"``).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import PATTERN_NAMES, TEMPLATES, QueryInstance, build_batched_dag, schedule
from repro.core.scheduler import bucket_size
from repro.lm.moe import combine_from_experts, pack_by_expert

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True)
hypothesis.settings.load_profile("ci")


@given(st.integers(0, 4096), st.sampled_from([16, 64, 512]))
def test_bucket_size_properties(n, b_max):
    b = bucket_size(n, b_max)
    assert b >= min(n, b_max)          # fits (after chunking at b_max)
    assert b <= max(b_max, 1)
    if 0 < n <= b_max:
        assert b < 2 * n or b == 1     # at most 2x padding waste


@st.composite
def query_batches(draw):
    pats = draw(st.lists(st.sampled_from(PATTERN_NAMES), min_size=1, max_size=24))
    rng = np.random.default_rng(draw(st.integers(0, 1000)))
    qs = []
    for p in pats:
        t = TEMPLATES[p]
        qs.append(QueryInstance(p, rng.integers(0, 100, t.n_anchors),
                                rng.integers(0, 10, t.n_relations)))
    return qs


@given(query_batches(), st.sampled_from([4, 16, 128]),
       st.sampled_from(["max_fillness", "fifo"]))
def test_schedule_invariants(queries, b_max, policy):
    """Every node executes exactly once, deps-before-use, slots never clobbered
    while live, answers reachable — for ANY pattern mixture and B_max."""
    dag = build_batched_dag(queries)
    sched = schedule(dag, b_max=b_max, policy=policy)
    executed = np.zeros(dag.n_nodes, bool)
    slot_holder = {}
    for step in sched.steps:
        assert step.n <= b_max
        for bi, v in enumerate(step.node_ids):
            assert not executed[v], "node scheduled twice"
            for ci, j in enumerate(dag.inputs[v]):
                assert executed[j]
                assert slot_holder.get(step.in_slots[bi, ci]) == j
        for bi, v in enumerate(step.node_ids):
            executed[v] = True
            slot_holder[step.out_slots[bi]] = v
    assert executed.all()
    for qi, a in enumerate(dag.answer_node):
        assert slot_holder[sched.answer_slots[qi]] == a
    # peak slots never exceeds node count; reuse never loses correctness
    assert sched.n_slots <= dag.n_nodes


@given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 4),
       st.integers(0, 1000))
def test_moe_pack_combine_conservation(t, e, k, seed):
    """With ample capacity, pack+identity+combine reproduces gate-weighted x;
    with any capacity, outputs of dropped tokens are exactly zero."""
    k = min(k, e)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, 4)), jnp.float32)
    eidx = jnp.asarray(rng.integers(0, e, (t, k)))
    gates = jnp.asarray(rng.dirichlet(np.ones(k), size=t), jnp.float32)
    cap_full = t * k
    packed, meta = pack_by_expert(x, eidx, gates, e, cap_full)
    y = combine_from_experts(packed, meta, t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-4, atol=1e-5)

    cap_small = max(1, t // 4)
    packed2, meta2 = pack_by_expert(x, eidx, gates, e, cap_small)
    y2 = combine_from_experts(packed2, meta2, t)
    assert np.isfinite(np.asarray(y2)).all()
    # each packed row is either zero or one of the original rows
    pk = np.asarray(packed2).reshape(-1, 4)
    xs = np.asarray(x)
    for row in pk:
        if np.abs(row).sum() > 0:
            assert np.min(np.abs(xs - row).sum(axis=1)) < 1e-5


@given(st.integers(0, 1000))
def test_quantize_roundtrip_bound(seed):
    from repro.training.compression import dequantize_int8, quantize_int8

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6  # half-ulp rounding bound


@given(query_batches())
def test_answer_slots_survive_reuse(queries):
    """Slot reuse must never hand an answer's slot to another node."""
    dag = build_batched_dag(queries)
    sched = schedule(dag, b_max=32, reuse_slots=True)
    ans = set(sched.answer_slots.tolist())
    owners = {}
    for step in sched.steps:
        for bi, v in enumerate(step.node_ids):
            s = int(step.out_slots[bi])
            owners.setdefault(s, []).append(v)
    for qi, a in enumerate(dag.answer_node):
        s = int(sched.answer_slots[qi])
        assert owners[s][-1] == a  # the answer is the LAST writer of its slot
