"""Multi-replica serving tier: affinity routing, tenant admission, hot swap
(DESIGN.md §ServingTier).

Property coverage the ISSUE pins:

* rendezvous assignment is deterministic per topology key and remaps at
  most ~1/N of topologies on replica join/leave;
* spill triggers ONLY above the queue-depth threshold, to ranked
  alternates only;
* tenant quotas and priority classes: low-priority excess is shed with a
  typed ``ShedError`` (never blocking), quotas release on completion;
* hot model swap: post-swap results are bitwise what a fresh pool started
  with the new params serves, and in-flight requests admitted pre-swap
  complete on the params they were admitted under;
* the ``tenant=``/``replica=`` metric labels land in the registry without
  touching the historical unlabeled keys.
"""
import queue as queue_mod
import threading
import time
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.launch.serve import serve_batch
from repro.models import ModelConfig, make_model
from repro.core.executor import PooledExecutor
from repro.obs.registry import get_registry
from repro.serving import (ReplicaPool, Router, RouterConfig, ServingConfig,
                           ServingEngine, ShedError, TenantSpec,
                           check_against_offline, make_workload,
                           query_topology_key, rendezvous_rank,
                           run_closed_loop, run_tenant_mix, TenantLoad)


@pytest.fixture(scope="module")
def served(tiny_kg):
    model = make_model("gqe", ModelConfig(dim=16, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    return tiny_kg, model, params


def _oracle(model, params):
    ex = PooledExecutor(model, b_max=256)
    return lambda qs: serve_batch(model, params, ex, qs)[0]


# ---------------------------------------------------------------------------
# Rendezvous affinity properties
# ---------------------------------------------------------------------------

def test_topology_key_deterministic_and_binding_free(tiny_kg):
    qs = make_workload(tiny_kg, 40, seed=5)
    for q in qs:
        assert query_topology_key(q) == query_topology_key(q)
    # Affinity groups by POST-CSE shape: queries of one pattern share a
    # topology unless within-query merges (duplicate anchor/relation in two
    # branches) collapse the plan — then the merged shape is its own
    # topology, exactly as the schedule/plan/jit caches see it. Either way
    # the per-pattern topology set is tiny and binding-independent beyond
    # the merge structure.
    by_pattern = {}
    for q in qs:
        by_pattern.setdefault(q.pattern, []).append(query_topology_key(q))
    for pattern, topos in by_pattern.items():
        assert 1 <= len(set(topos)) <= 2, (pattern, set(topos))


def test_rendezvous_deterministic():
    topos = [(("t", i), (i,)) for i in range(50)]
    for topo in topos:
        r1 = rendezvous_rank(topo, [0, 1, 2, 3])
        r2 = rendezvous_rank(topo, [3, 2, 1, 0])  # order-insensitive
        assert r1 == r2
        assert sorted(r1) == [0, 1, 2, 3]


def test_rendezvous_remap_fraction_on_join_and_leave():
    topos = [((i, i + 1), (0,)) for i in range(200)]
    before = {t: rendezvous_rank(t, [0, 1, 2, 3])[0] for t in topos}
    # Join: adding replica 4 steals ~1/5 of topologies; NOTHING else moves.
    after_join = {t: rendezvous_rank(t, [0, 1, 2, 3, 4])[0] for t in topos}
    moved = [t for t in topos if before[t] != after_join[t]]
    assert all(after_join[t] == 4 for t in moved)
    assert len(moved) / len(topos) < 2 / 5  # ~1/5 expected, loose bound
    # Leave: removing replica 2 remaps exactly the topologies it owned.
    after_leave = {t: rendezvous_rank(t, [0, 1, 3])[0] for t in topos}
    for t in topos:
        if before[t] != 2:
            assert after_leave[t] == before[t]


# ---------------------------------------------------------------------------
# Spill + tenant admission against a stub pool (exact queue-depth control)
# ---------------------------------------------------------------------------

class StubReplica:
    def __init__(self):
        self.depth = 0
        self.full = False
        self.submitted = []

    def queue_depth(self):
        return self.depth

    def submit(self, q, top_k=None, timeout=None):
        if self.full:
            raise queue_mod.Full()
        f = Future()
        self.submitted.append((q, f, timeout))
        return f


class StubPool:
    def __init__(self, n):
        self._reps = {i: StubReplica() for i in range(n)}
        self.membership_token = 0

    def replicas(self):
        return dict(self._reps)

    def stats(self):
        return {}

    def update_params(self, params):
        pass

    def close(self, **kw):
        pass


@pytest.fixture
def stub_router(tiny_kg):
    pool = StubPool(4)
    router = Router(pool, tenants=[
        TenantSpec("gold", "high"),
        TenantSpec("bronze", "low"),
        TenantSpec("capped", "high", max_inflight=2),
    ], cfg=RouterConfig(spill_depth=4, spill_width=1))
    return pool, router, make_workload(tiny_kg, 30, seed=7)


def _primary(router, q):
    return router._ranking(router._topology(q))[0]


def test_no_spill_at_or_below_threshold(stub_router):
    pool, router, qs = stub_router
    q = qs[0]
    rid = _primary(router, q)
    pool._reps[rid].depth = 4  # == spill_depth: NOT above, no spill
    router.submit(q, tenant="gold")
    assert len(pool._reps[rid].submitted) == 1
    assert int(router._spilled) == 0


def test_spill_above_threshold_to_ranked_alternate(stub_router):
    pool, router, qs = stub_router
    q = qs[0]
    rank = router._ranking(router._topology(q))
    pool._reps[rank[0]].depth = 5  # above spill_depth=4
    router.submit(q, tenant="gold")
    assert len(pool._reps[rank[1]].submitted) == 1
    assert int(router._spilled) == 1
    # All alternates loaded too -> sticks with the affinity target (bounded
    # spill never sprays beyond spill_width ranked alternates).
    pool._reps[rank[1]].depth = 5
    router.submit(q, tenant="gold")
    assert len(pool._reps[rank[0]].submitted) == 1
    assert int(router._spilled) == 1


def test_low_priority_shed_never_blocks(stub_router):
    pool, router, qs = stub_router
    q = qs[0]
    rank = router._ranking(router._topology(q))
    # Loaded replica: shed by depth check before any enqueue attempt.
    for rid in rank[:2]:
        pool._reps[rid].depth = 5
    t0 = time.perf_counter()
    with pytest.raises(ShedError) as ei:
        router.submit(q, tenant="bronze")
    assert ei.value.reason == "backpressure"
    assert time.perf_counter() - t0 < 0.1
    # Full admission queue: the non-blocking enqueue converts queue.Full
    # into the same typed shed.
    for rid in rank[:2]:
        pool._reps[rid].depth = 0
        pool._reps[rid].full = True
    with pytest.raises(ShedError) as ei:
        router.submit(q, tenant="bronze")
    assert ei.value.reason == "backpressure"
    # High priority on the same loaded pool is admitted (blocking contract
    # delegated to the engine's bounded queue).
    for rid in rank[:2]:
        pool._reps[rid].full = False
        pool._reps[rid].depth = 5
    router.submit(q, tenant="gold")
    st = router.stats()
    assert st["tenants"]["bronze"]["shed"]["backpressure"] == 2
    assert st["tenants"]["bronze"]["completed"] == 0
    assert st["tenants"]["gold"]["submitted"] == 1


def test_quota_shed_and_release(stub_router):
    pool, router, qs = stub_router
    f1 = router.submit(qs[0], tenant="capped")
    router.submit(qs[1], tenant="capped")
    with pytest.raises(ShedError) as ei:
        router.submit(qs[2], tenant="capped")
    assert ei.value.reason == "quota"
    assert router.tenant_inflight("capped") == 2
    f1.set_result({"latency_ms": 1.0})
    assert router.tenant_inflight("capped") == 1
    router.submit(qs[3], tenant="capped")  # slot released
    assert router.stats()["tenants"]["capped"]["shed"]["quota"] == 1


def test_unknown_tenant_rejected(stub_router):
    _, router, qs = stub_router
    with pytest.raises(KeyError):
        router.submit(qs[0], tenant="nobody")


def test_membership_change_invalidates_ranking(stub_router):
    pool, router, qs = stub_router
    q = qs[0]
    r0 = router._ranking(router._topology(q))
    del pool._reps[r0[0]]
    pool.membership_token += 1
    r1 = router._ranking(router._topology(q))
    assert r0[0] not in r1 and r1 == [rid for rid in r0 if rid != r0[0]]


# ---------------------------------------------------------------------------
# Real pool: routing parity, hot swap, labels
# ---------------------------------------------------------------------------

def test_router_parity_with_offline_oracle(served):
    kg, model, params = served
    qs = make_workload(kg, 24, seed=9)
    pool = ReplicaPool(model, params, n_replicas=2,
                       cfg=ServingConfig(max_batch=8, max_wait_ms=2.0,
                                         record_batches=True),
                       mat_budget_rows=64)
    with Router(pool) as router:
        rep = run_closed_loop(router, qs, concurrency=8)
        assert all(r is not None for r in rep.results)
        serve_fn = _oracle(model, params)
        checked = sum(
            check_against_offline(r.engine.batch_log, serve_fn)
            for r in pool.replicas().values())
        assert checked >= len(qs)  # >= because of padding-free uniques


def test_hot_swap_matches_fresh_pool(served):
    kg, model, params = served
    params_b = model.init_params(jax.random.PRNGKey(7), kg.n_entities,
                                 kg.n_relations)
    qs = make_workload(kg, 24, seed=13)
    cfg = ServingConfig(max_batch=8, max_wait_ms=2.0, record_batches=True)
    pool = ReplicaPool(model, params, n_replicas=2, cfg=cfg,
                       mat_budget_rows=64)
    with Router(pool) as router:
        run_closed_loop(router, qs, concurrency=8)   # warm on old params
        router.update_params(params_b)               # hot swap, no drain
        pool.reset_counters(clear_log=True)
        after = run_closed_loop(router, qs, concurrency=8)
        # Every post-swap batch is bitwise the offline oracle on the NEW
        # params (composition-wise — the strongest form of "fresh pool").
        serve_fn = _oracle(model, params_b)
        for r in pool.replicas().values():
            check_against_offline(r.engine.batch_log, serve_fn)
    fresh = ReplicaPool(model, params_b, n_replicas=2, cfg=cfg,
                        mat_budget_rows=64)
    with Router(fresh) as router2:
        ref = run_closed_loop(router2, qs, concurrency=8)
    for got, want in zip(after.results, ref.results):
        assert got["top_entities"] == want["top_entities"]
        assert got["scores"] == want["scores"]


def test_inflight_pre_swap_served_on_admitted_params(served):
    kg, model, params = served
    params_b = model.init_params(jax.random.PRNGKey(11), kg.n_entities,
                                 kg.n_relations)
    qs = make_workload(kg, 16, seed=17)
    eng = ServingEngine(model, params, started=False,
                        cfg=ServingConfig(max_batch=8, max_wait_ms=2.0,
                                          pin_params_on_admit=True))
    try:
        pre = [eng.submit(q) for q in qs[:8]]     # admitted under params A
        eng.update_params(params_b)
        post = [eng.submit(q) for q in qs[8:]]    # admitted under params B
        eng.start()
        got_pre = [f.result(timeout=60) for f in pre]
        got_post = [f.result(timeout=60) for f in post]
    finally:
        eng.close()
    oracle_a = _oracle(model, params)(qs[:8])
    oracle_b = _oracle(model, params_b)(qs[8:])
    for got, want in zip(got_pre, oracle_a):
        assert got["top_entities"] == want["top_entities"]
        assert got["scores"] == want["scores"]
    for got, want in zip(got_post, oracle_b):
        assert got["top_entities"] == want["top_entities"]
        assert got["scores"] == want["scores"]
    assert eng.stats()["params_version"] == 1


def test_default_engine_has_no_params_version_key(served):
    kg, model, params = served
    eng = ServingEngine(model, params, started=False)
    try:
        assert "params_version" not in eng.stats()
    finally:
        eng.close(drain=False)


def test_pin_params_rejects_sem_cache_and_kg(served):
    kg, model, params = served
    cfg = ServingConfig(pin_params_on_admit=True)
    with pytest.raises(ValueError):
        ServingEngine(model, params, cfg=cfg, kg=kg, started=False)


def test_tenant_and_replica_metric_labels(served):
    kg, model, params = served
    qs = make_workload(kg, 16, seed=19)
    pool = ReplicaPool(model, params, n_replicas=2,
                       cfg=ServingConfig(max_batch=8, max_wait_ms=2.0))
    router = Router(pool, tenants=[TenantSpec("gold", "high"),
                                   TenantSpec("bronze", "low")])
    # A live single-engine (unlabeled) instance alongside the tier: its keys
    # must stay the historical unlabeled ones, unpolluted by the labels.
    plain = ServingEngine(model, params, started=False)
    with router:
        reports = run_tenant_mix(router, [
            TenantLoad("gold", qs[:8], qps=0.0),
            TenantLoad("bronze", qs[8:], qps=0.0),
        ])
        snap = get_registry().snapshot()
    plain.close(drain=False)
    assert reports["gold"].completed == 8
    assert reports["gold"].failures == 0
    # New labeled keys exist...
    assert snap.get("serving_submitted{tenant=gold}", 0) == 8
    assert "serving_latency_ms{tenant=gold}_count" in snap
    assert "serving_shed{reason=backpressure,tenant=bronze}" in snap
    assert any(k.startswith("serving_batches{replica=") for k in snap)
    # ...and the historical unlabeled keys still do (single-engine path),
    # with the labeled tier traffic NOT aliasing into them.
    assert snap.get("serving_submitted") == 0
