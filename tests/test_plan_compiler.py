"""Plan-IR compiler tests (DESIGN.md §Compiler): cross-query subexpression
sharing must be SEMANTICALLY INVISIBLE — bitwise-identical encode outputs vs
the no-CSE ablation — while strictly shrinking pooled rows and peak slot
liveness, with schedule caching keyed on the deduped topology."""
import jax
import numpy as np
import pytest

from repro.core import (PooledExecutor, build_plan, compile_batch,
                        plan_to_dag, schedule)
from repro.core.patterns import QueryInstance
from repro.models import ModelConfig, make_model, model_names


def _overlap_batch(rng, n, n_entities=40, n_relations=6, anchor_pool=6,
                   rel_pool=3):
    """Random mixed batch drawing anchors/relations from SMALL pools so
    prefix chains collide across queries (the 2p/3p/ip/pi overlap case)."""
    anchors = rng.integers(0, n_entities, size=anchor_pool)
    rels = rng.integers(0, n_relations, size=rel_pool)
    patterns = ["1p", "2p", "3p", "2i", "pi", "ip", "2u", "2in"]
    out = []
    from repro.core.patterns import TEMPLATES

    for _ in range(n):
        pat = patterns[rng.integers(len(patterns))]
        tpl = TEMPLATES[pat]
        out.append(QueryInstance(
            pat,
            anchors[rng.integers(anchor_pool, size=tpl.n_anchors)].copy(),
            rels[rng.integers(rel_pool, size=tpl.n_relations)].copy(),
        ))
    return out


# ------------------------------------------------------------------ CSE core
def test_cse_encode_bitwise_property():
    """Property over seeded random overlapping batches: encode with CSE on
    == off, BITWISE, and peak slots with CSE <= without."""
    model = make_model("gqe", ModelConfig(dim=8))
    params = model.init_params(jax.random.PRNGKey(0), 40, 6)
    ex_on = PooledExecutor(model, b_max=16, cse=True)
    ex_off = PooledExecutor(model, b_max=16, cse=False)
    rng = np.random.default_rng(7)
    saved_any = False
    for trial in range(8):
        queries = _overlap_batch(rng, n=int(rng.integers(2, 20)))
        p_on = ex_on.prepare(queries)
        p_off = ex_off.prepare(queries)
        assert p_on.sched.n_slots <= p_off.sched.n_slots
        assert p_on.report.nodes_after <= p_on.report.nodes_before
        assert p_on.report.nodes_before == p_off.report.nodes_before
        assert p_off.report.pooled_rows_saved == 0
        saved_any |= p_on.report.pooled_rows_saved > 0
        a = np.asarray(ex_on.encode(params, queries))
        b = np.asarray(ex_off.encode(params, queries))
        assert np.array_equal(a, b), f"trial {trial}: CSE changed the bits"
    assert saved_any, "overlap workload never shared a subexpression"


@pytest.mark.parametrize("name", model_names())
def test_cse_encode_bitwise_all_families(name):
    model = make_model(name, ModelConfig(dim=8))
    params = model.init_params(jax.random.PRNGKey(1), 40, 6)
    queries = _overlap_batch(np.random.default_rng(3), n=12)
    on = np.asarray(PooledExecutor(model, b_max=16, cse=True).encode(params, queries))
    off = np.asarray(PooledExecutor(model, b_max=16, cse=False).encode(params, queries))
    assert np.array_equal(on, off)


def test_duplicate_queries_alias_one_answer_slot():
    """Exact-duplicate queries collapse to ONE subtree; every duplicate's
    answer-map entry aliases the same workspace slot and the final gather
    fans the single computed row out per query."""
    q = QueryInstance("2p", np.array([3]), np.array([1, 2]))
    other = QueryInstance("1p", np.array([5]), np.array([0]))
    plan = compile_batch([q, q, other, q], model_name="m")
    assert plan.report.nodes_before == 3 * 3 + 2
    assert plan.report.nodes_after == 3 + 2
    slots = plan.answer_slots[np.argsort(plan.order)]  # original order
    assert slots[0] == slots[1] == slots[3]
    assert slots[2] != slots[0]


def test_shared_prefix_interns_subchain():
    """A 1p query that is the prefix of a co-batched 2p shares the 2p's
    EMBED and first PROJECT nodes."""
    two_p = QueryInstance("2p", np.array([4]), np.array([1, 2]))
    one_p = QueryInstance("1p", np.array([4]), np.array([1]))
    plan = build_plan([one_p, two_p])
    assert plan.nodes_before == 2 + 3
    assert plan.n_nodes == 3          # E(4), P(1), P(2)
    # the 1p answer is the 2p's intermediate node
    dag = plan_to_dag(plan)
    assert dag.answer_node[0] in dag.inputs[dag.answer_node[1]]
    # shared nodes keep their slots live for every consumer (Eq. 7 across
    # queries): the schedule must still be executable
    sched = schedule(dag, b_max=8)
    assert sched.n_nodes == 3


def test_topology_key_shared_across_bindings():
    """Two batches with different entity/relation ids but the same deduped
    SHAPE share one schedule-cache entry; a batch whose sharing pattern
    differs does not."""
    ex = PooledExecutor(make_model("gqe", ModelConfig(dim=8)), b_max=16)
    b1 = [QueryInstance("1p", np.array([0]), np.array([0])),
          QueryInstance("1p", np.array([1]), np.array([1]))]
    b2 = [QueryInstance("1p", np.array([2]), np.array([2])),
          QueryInstance("1p", np.array([3]), np.array([3]))]
    p1 = ex.prepare(b1)
    p2 = ex.prepare(b2)
    assert p1.structure_key == p2.structure_key
    assert len(ex._sched_cache) == 1
    assert ex._sched_cache.stats()["hits"] == 1
    # same two queries but now duplicates -> different post-CSE shape
    b3 = [QueryInstance("1p", np.array([5]), np.array([4])),
          QueryInstance("1p", np.array([5]), np.array([4]))]
    p3 = ex.prepare(b3)
    assert p3.structure_key != p1.structure_key
    assert len(ex._sched_cache) == 2


def test_topology_key_permutation_invariant(mixed_queries):
    """Canonical full-key ordering makes permuted batches compile to the
    identical plan (one cache entry, same program signature)."""
    ex = PooledExecutor(make_model("gqe", ModelConfig(dim=8)), b_max=32)
    queries = [b.query for b in mixed_queries]
    p1 = ex.prepare(queries)
    p2 = ex.prepare(list(reversed(queries)))
    assert p1.structure_key == p2.structure_key
    assert p1.signature == p2.signature
    assert len(ex._sched_cache) == 1


def test_order_restored_with_duplicates():
    """encode() returns rows in ORIGINAL submission order even when CSE
    aliased some of them."""
    model = make_model("q2b", ModelConfig(dim=8))
    params = model.init_params(jax.random.PRNGKey(0), 40, 6)
    ex = PooledExecutor(model, b_max=16)
    qa = QueryInstance("1p", np.array([7]), np.array([2]))
    qb = QueryInstance("2p", np.array([7]), np.array([2, 3]))
    out = np.asarray(ex.encode(params, [qb, qa, qb, qa]))
    assert np.array_equal(out[0], out[2])
    assert np.array_equal(out[1], out[3])
    assert not np.array_equal(out[0], out[1])
    solo = np.asarray(ex.encode(params, [qa]))
    assert np.array_equal(out[1], solo[0])


def test_bind_arrays_match_per_step_gather(mixed_queries):
    """The vectorized bind rebuild (one gather + flat scatter) must equal
    the per-step formula it replaced."""
    queries = [b.query for b in mixed_queries]
    plan = compile_batch(queries, model_name="m", b_max=32)
    dag = plan_to_dag(build_plan([queries[i] for i in plan.order]))
    for s, bind in zip(plan.sched.steps, plan.bind_arrays):
        want_rel = np.zeros(s.padded_n, dtype=np.int64)
        want_rel[: s.n] = dag.rel[s.node_ids].clip(min=0)
        want_anc = np.zeros(s.padded_n, dtype=np.int64)
        want_anc[: s.n] = dag.anchor[s.node_ids].clip(min=0)
        assert np.array_equal(bind["rel_ids"], want_rel)
        assert np.array_equal(bind["anchor_ids"], want_anc)
        assert bind["rel_ids"].dtype == np.int64


def test_no_cse_keeps_per_query_nodes(mixed_queries):
    from repro.core.patterns import TEMPLATES

    queries = [b.query for b in mixed_queries]
    plan = compile_batch(queries, model_name="m", b_max=32, cse=False)
    want = sum(len(TEMPLATES[q.pattern].nodes) for q in queries)
    assert plan.report.nodes_after == want
    assert plan.report.pooled_rows_saved == 0
    assert plan.sched.n_nodes == want


def test_compile_empty_batch():
    plan = compile_batch([], model_name="m")
    assert plan.sched.steps == []
    assert len(plan.answer_slots) == 0
    assert plan.report.nodes_before == 0
