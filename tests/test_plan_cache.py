"""Cross-batch plan reuse + materialized-subquery staleness suite.

Pins the two caches that make CSE strictly dominate (DESIGN.md §Compiler):

* ``PlanCache`` — compiled plans persist across ``prepare()`` calls. An
  exact-key replay is ONE dict lookup: no canonicalization, no hash-consing,
  no schedule lookup. A permuted batch hits the canonical level and only
  rebinds the order permutation.
* ``MaterializedSubqueryCache`` — encoded rows persist across batches,
  version-stamped so no interleaving of {param update, KG write, eviction
  pressure, version pinning} can ever serve a stale row: cached-path encode
  output is asserted BITWISE against a fresh no-cache executor for every
  model family in the zoo.
"""
import jax
import numpy as np
import pytest

from repro.core import (MaterializedSubqueryCache, PooledExecutor)
from repro.data.kg import generate_synthetic_kg
from repro.models import ModelConfig, make_model, model_names
from repro.sampling import OnlineSampler
from repro.serving import (ServingConfig, ServingEngine, make_workload)
from repro.training import NGDBTrainer, TrainConfig


def _model_params(kg, name="gqe", dim=8, seed=0):
    model = make_model(name, ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(seed), kg.n_entities,
                               kg.n_relations)
    return model, params


def _retraces(tr):
    cs = tr.compile_cache_stats()
    return (int(cs["train_step"]["misses"])
            + sum(int(cs[k]["misses"])
                  for k in ("schedule", "encode", "encode_jit")))


# ------------------------------------------------------------ plan cache unit
def test_exact_hit_skips_canonicalization(tiny_kg, mixed_queries):
    """A repeated batch is served from the exact level without touching the
    canonicalize/sort path; a PERMUTED batch hits the canonical level (one
    extra canonicalize, zero rebuilds) and still restores caller order."""
    model, params = _model_params(tiny_kg)
    ex = PooledExecutor(model, b_max=64)
    qs = [b.query for b in mixed_queries][:12]
    plan1 = ex.prepare(qs)
    pc = ex.sharing_stats()["plan_cache"]
    assert (pc["misses"], pc["canonicalize_calls"]) == (1, 1)
    plan2 = ex.prepare(qs)                      # exact replay
    pc = ex.sharing_stats()["plan_cache"]
    assert (pc["hits"], pc["misses"], pc["canonicalize_calls"]) == (1, 1, 1)
    assert plan2 is plan1                       # the cached object itself
    plan3 = ex.prepare(list(reversed(qs)))      # permuted: canonical hit
    pc = ex.sharing_stats()["plan_cache"]
    assert pc["misses"] == 1                    # no rebuild
    assert pc["canonicalize_calls"] == 2
    assert plan3.signature == plan1.signature
    # order restoration through the canonical-hit path is bitwise
    a = np.asarray(ex.encode(params, qs))
    b = np.asarray(ex.encode(params, list(reversed(qs))))
    np.testing.assert_array_equal(b, a[::-1])


def test_cross_batch_replay_hit_rate(tiny_kg):
    """Replaying a multi-batch workload: pass 2 is 100% exact hits with the
    canonicalize count frozen — the compiler is off the steady-state path."""
    model, _ = _model_params(tiny_kg)
    ex = PooledExecutor(model, b_max=64)
    sampler = OnlineSampler(tiny_kg, seed=2)
    batches = [[s.query for s in sampler.sample_batch(16)] for _ in range(6)]
    for b in batches:
        ex.prepare(b)
    ex.reset_cache_counters()
    for b in batches:
        ex.prepare(b)
    pc = ex.sharing_stats()["plan_cache"]
    assert pc["misses"] == 0
    assert pc["hit_rate"] >= 0.9
    assert pc["canonicalize_calls"] == 0


@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("cse", [True, False])
def test_trainer_zero_steady_state_retraces(tiny_kg, cse, pipeline):
    """Warm a trainer on a fixed batch list, reset counters, replay: zero
    retraces (train_step/schedule/encode caches all hit) AND the plan cache
    serves every prepare without canonicalizing, in all four
    {sync, pipelined} x {cse, no-cse} configurations."""
    cfg = TrainConfig(batch_size=16, n_negatives=4, b_max=64, seed=0,
                      cse=cse, pipeline=pipeline, prefetch=1)
    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    tr = NGDBTrainer(model, tiny_kg, cfg)
    batches = [tr.sampler.sample_batch(16) for _ in range(3)]
    tr.train(3, log_every=0, batches=batches)   # warm every signature
    tr._train_fns.reset_counters()
    tr.executor.reset_cache_counters()
    tr.train(3, log_every=0, batches=batches)
    assert _retraces(tr) == 0, tr.compile_cache_stats()
    pc = tr.executor.sharing_stats()["plan_cache"]
    assert pc["hit_rate"] >= 0.9
    assert pc["canonicalize_calls"] == 0


def test_engine_replay_reuses_plans_across_engine_instances(tiny_kg):
    """Two serving engines sharing ONE executor: the second engine's replay
    of the same workload runs at zero retraces and 100% plan-cache hits —
    the cache outlives the engine, not just the batch."""
    model, params = _model_params(tiny_kg)
    ex = PooledExecutor(model, b_max=64)
    workload = make_workload(tiny_kg, 24, seed=5)

    def replay():
        # started=False + pre-queued submits: the batcher drains greedily
        # into deterministic max_batch chunks, so both passes execute the
        # SAME micro-batch compositions.
        eng = ServingEngine(model, params, executor=ex, started=False,
                            cfg=ServingConfig(max_batch=8, max_wait_ms=1e3))
        futs = eng.submit_many(workload)
        eng.start()
        res = [f.result(timeout=120) for f in futs]
        eng.close()
        return eng, res

    _, r1 = replay()
    ex.reset_cache_counters()
    eng2, r2 = replay()
    assert eng2.retraces() == 0, eng2.stats()["caches"]
    pc = ex.sharing_stats()["plan_cache"]
    assert pc["misses"] == 0 and pc["canonicalize_calls"] == 0
    assert pc["hit_rate"] == 1.0
    strip = lambda rs: [{k: v for k, v in r.items()  # noqa: E731
                         if k not in ("latency_ms", "batch_size")}
                        for r in rs]
    assert strip(r1) == strip(r2)


# ----------------------------------------------------- materialized staleness
@pytest.mark.parametrize("name", model_names())
def test_materialized_rows_never_stale(name):
    """Staleness property test: under a seeded random interleaving of
    {encode, param update, KG write, eviction pressure, version pin}, the
    cached-path encode is BITWISE a fresh no-cache compute, for every model
    family. A single served-stale row (old params or old KG version) would
    break the array equality."""
    kg = generate_synthetic_kg(80, 6, 600, seed=3)
    model, params = _model_params(kg, name=name)
    mat = MaterializedSubqueryCache(24)
    mat.watch_kg(kg)
    ex = PooledExecutor(model, b_max=32, mat_cache=mat)
    oracle = PooledExecutor(model, b_max=32)    # cache-free fresh compute
    pool = [s.query for s in OnlineSampler(kg, seed=11).sample_batch(40)]
    rng = np.random.default_rng(7)
    ops = ("encode", "encode", "param_update", "kg_write",
           "evict_pressure", "pin")
    for step in range(40):
        op = "encode" if step == 0 else ops[int(rng.integers(len(ops)))]
        if op == "encode":
            qs = [pool[i] for i in rng.integers(len(pool), size=8)]
            got = np.asarray(ex.encode(params, qs))
            want = np.asarray(oracle.encode(params, qs))
            np.testing.assert_array_equal(got, want)
        elif op == "param_update":
            params = {k: (v * np.float32(1.001)
                          if np.issubdtype(np.asarray(v).dtype, np.floating)
                          else v)
                      for k, v in params.items()}
            mat.bump_version("param_update")
        elif op == "kg_write":
            # add_triples notifies the watch_kg listener -> version bump
            v0 = mat.version
            kg.add_triples([[int(rng.integers(80)), int(rng.integers(6)),
                             int(rng.integers(80))]])
            assert mat.version == v0 + 1
        elif op == "evict_pressure":
            # encode more distinct queries than the 24-row budget holds
            idx = rng.choice(len(pool), size=30, replace=False)
            ex.encode(params, [pool[i] for i in idx])
        else:  # pin: inserts computed under a superseded version are dropped
            v = mat.version
            mat.bump_version("test_pin")
            stored = mat.insert([("bogus",)],
                                np.zeros((1, model.state_dim), np.float32),
                                version=v)
            assert stored == 0
            assert mat.lookup([("bogus",)]) == {}
    mat.check_consistent()
    st = mat.stats()
    assert st["invalidations"] > 0
    assert st["hits"] > 0          # the cache did serve rows, validly


def test_kg_write_invalidates_adjacency_views():
    """``add_triples`` must rebuild the CSR index and drop every cached
    adjacency view — a stale ``cached_property`` would quietly answer
    queries against the pre-write graph."""
    kg = generate_synthetic_kg(50, 4, 300, seed=1)
    deg0 = kg.out_degree.copy()
    n0 = len(kg)
    h = int(np.setdiff1d(np.arange(50), kg.triples[:, 0])[0]) \
        if len(np.setdiff1d(np.arange(50), kg.triples[:, 0])) else 0
    kg.add_triples([[h, 0, 1], [h, 0, 2]])
    assert len(kg) == n0 + 2
    assert kg.out_degree[h] == deg0[h] + 2
    assert set(kg.neighbors(h, 0)) >= {1, 2}
    assert kg.version == 1
    with pytest.raises(ValueError):
        kg.add_triples([[99, 0, 0]])    # entity out of range
    with pytest.raises(ValueError):
        kg.add_triples([[0, 9, 0]])     # relation out of range
    assert kg.version == 1              # failed writes don't bump


@pytest.mark.parametrize("name", model_names())
def test_pinned_graph_versions_replay_snapshot_oracle(name):
    """§LiveStore staleness property test: under a seeded interleaving of
    {pinned serve, unpinned serve, KG write, param update} against a LIVE
    engine (kg= attached, mat cache keyed by graph version), every served
    row equals the snapshot-pinned oracle — ``serve_batch`` run cache-free
    with the params that were live when the pinned version was admitted —
    for every model family. One row computed from the wrong params/version
    pairing breaks the equality."""
    from repro.launch.serve import serve_batch

    kg = generate_synthetic_kg(80, 6, 600, seed=3)
    model, params = _model_params(kg, name=name)
    mat = MaterializedSubqueryCache(32)
    mat.watch_kg(kg)
    bound = 3
    cfg = ServingConfig(max_batch=8, max_wait_ms=2.0, top_k=5,
                        max_staleness_versions=bound)
    pool = [s.query for s in OnlineSampler(kg, seed=11).sample_batch(30)]
    oracle_ex = PooledExecutor(model, b_max=32)     # cache-free fresh compute
    strip = lambda r: {k: v for k, v in r.items()   # noqa: E731
                       if k not in ("latency_ms", "batch_size")}
    params_at = {0: params}     # our own mirror of the engine's retention map
    cur = params
    rng = np.random.default_rng(13)
    ops = ("pinned", "pinned", "unpinned", "kg_write", "param_update")
    lagged = 0
    with ServingEngine(model, params, cfg=cfg, kg=kg, mat_cache=mat,
                       executor=PooledExecutor(model, b_max=32)) as eng:
        for step in range(16):
            op = "pinned" if step == 0 else ops[int(rng.integers(len(ops)))]
            if op == "kg_write":
                kg.add_triples([[int(rng.integers(80)), int(rng.integers(6)),
                                 int(rng.integers(80))]])
                # the engine's write listener registers the live params
                # under the new version; mirror that bookkeeping
                params_at[kg.graph_version] = cur
            elif op == "param_update":
                cur = {k: (v * np.float32(1.001)
                           if np.issubdtype(np.asarray(v).dtype, np.floating)
                           else v)
                       for k, v in cur.items()}
                eng.update_params(cur)
                params_at[kg.graph_version] = cur
            else:
                qs = [pool[i] for i in rng.integers(len(pool), size=4)]
                pin = None
                if op == "pinned":
                    # half the pins take the OLDEST admissible version so
                    # lagged replay is actually exercised, not just lag 0
                    lo = max(0, kg.graph_version - bound)
                    pin = (lo if rng.random() < 0.5
                           else int(rng.integers(lo, kg.graph_version + 1)))
                    lagged += int(pin < kg.graph_version)
                futs = [eng.submit(q, pin_version=pin) for q in qs]
                got = [strip(f.result(timeout=120)) for f in futs]
                oracle_params = params_at[pin if pin is not None
                                          else kg.graph_version]
                want, _ = serve_batch(model, oracle_params, oracle_ex, qs,
                                      top_k=5)
                assert got == [strip(w) for w in want]
        st = eng.stats()
    assert kg.graph_version > 0 and lagged > 0   # interleaving did exercise
    assert st["failures"] == 0 and st["stale_sheds"] == 0
    assert sum(st["version_lag_served"].values()) == st["completed"]


def test_insert_at_pinned_version_drops_after_bump():
    """The encode-under-old-params race, distilled: a batch snapshots
    (params, version), an update lands, its insert must be dropped whole."""
    mat = MaterializedSubqueryCache(8)
    rows = np.ones((2, 4), np.float32)
    v = mat.version
    assert mat.insert([("a",), ("b",)], rows, version=v) == 2
    assert len(mat.lookup([("a",), ("b",)])) == 2
    mat.bump_version("param_update")
    assert mat.insert([("c",)], rows[:1], version=v) == 0
    assert mat.stats()["stale_drops"] == 1
    assert mat.lookup([("c",)]) == {}
    # and the pre-bump rows are unservable at the new version
    assert mat.lookup([("a",), ("b",)]) == {}
