"""Sharding rules + compression + pipeline + (subprocess) multi-device SPMD."""
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import _fit, param_spec


class FakeMesh:
    """Duck-typed mesh for rule tests (shape dict + axis_names)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def test_fit_divisibility():
    assert _fit(64, "model", MESH) == "model"
    assert _fit(20, "model", MESH) is None           # whisper's 20 heads
    assert _fit(1500, ("data", "model"), MESH) is None
    assert _fit(512, ("data", "model"), MESH) == ("data", "model")
    assert _fit(32, ("data", "model"), MESH) == "data"  # prefix fallback


def test_param_spec_rules():
    P = jax.sharding.PartitionSpec
    # 2D-sharded matrices (leading stack dims replicated)
    assert param_spec("wq", (80, 8192, 8192), MESH) == P(None, "data", "model")
    assert param_spec("wo", (80, 8192, 8192), MESH) == P(None, "model", "data")
    assert param_spec("embed", (152064, 8192), MESH) == P("model", "data")
    # whisper: 20*64=1280 head dim divides, d_model=1280 divides
    assert param_spec("wq", (32, 1280, 1280), MESH) == P(None, "data", "model")
    # qwen2-0.5b kv: 2*64=128 divides 16; d_model 896 divides 16
    assert param_spec("wk", (24, 896, 128), MESH) == P(None, "data", "model")
    # NON-divisible: 14 heads * 64 = 896 ok; but a 20-dim vector is not
    assert param_spec("A_log", (48, 20), MESH) == P(None, None)
    assert param_spec("A_log", (48, 64), MESH) == P(None, "model")
    # norms replicate
    assert param_spec("ln1", (80, 8192), MESH) == P()
    # MoE EP vs TP
    assert param_spec("moe_up", (56, 8, 6144, 16384), MESH, "tp") == P(
        None, None, "data", "model")
    assert param_spec("moe_up", (32, 16, 4096, 14336), MESH, "ep") == P(
        None, "model", "data", None)


def test_fsdp_profile_spec():
    from repro.distributed.sharding import dp_axes, fsdp_param_spec

    P = jax.sharding.PartitionSpec
    # largest divisible dim gets the full flattened axis set
    assert fsdp_param_spec("wq", (36, 2560, 4096), MESH) == P(
        None, None, ("data", "model"))
    # small vectors replicate
    assert fsdp_param_spec("ln1", (2560,), MESH) == P()
    # non-divisible largest dim falls through to the next candidate
    assert fsdp_param_spec("embed", (1500, 4096), MESH) == P(
        None, ("data", "model"))

    class M:  # fake mesh with pod axis
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert dp_axes(M(), "fsdp") == ("pod", "data", "model")
    assert dp_axes(M(), "2d") == ("pod", "data")


def test_cache_sharding_specs_decode():
    from repro.distributed.sharding import cache_shardings
    # needs a real mesh: single-device mesh exercises the no-axis fallbacks
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"k": jax.ShapeDtypeStruct((4, 8, 128, 2, 16), jnp.bfloat16)}
    sh = cache_shardings(tree, mesh)
    assert sh["k"].spec[1] is not None or mesh.shape["data"] == 1


def test_compressed_psum_single_axis():
    from repro.lm.moe import shard_map
    from repro.training.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("x",))
    g = jnp.asarray(np.random.default_rng(0).normal(size=(32,)), jnp.float32)
    err = jnp.zeros_like(g)

    def f(g, e):
        return compressed_psum(g, "x", e)

    out, new_err = shard_map(
        f, mesh, in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
    )(g, err)
    # single peer: mean == dequantized value; error feedback = quant residual
    np.testing.assert_allclose(np.asarray(out + new_err), np.asarray(g),
                               rtol=1e-5, atol=1e-6)
    assert float(jnp.abs(new_err).max()) < float(jnp.abs(g).max()) / 64


def test_prefetcher(tiny_kg):
    from repro.data.pipeline import BatchPrefetcher
    from repro.sampling import OnlineSampler

    s = OnlineSampler(tiny_kg, patterns=("1p", "2i"), seed=0)
    pf = BatchPrefetcher(s, batch_size=4, depth=2, workers=2)
    try:
        batches = [pf.next(timeout=60) for _ in range(3)]
        assert all(len(b) == 4 for b in batches)
    finally:
        pf.close()


def test_elastic_restore_subprocess():
    """Fault-tolerance/elasticity: a checkpoint written under an 8-device
    (4,2) mesh restores onto a shrunk 2-device mesh with different shardings
    and identical values (mesh-shape-agnostic restore)."""
    script = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training.checkpoint import save_checkpoint, load_checkpoint

d = tempfile.mkdtemp()
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh_a, P("data", "model")))
save_checkpoint(d, 7, {"params": {"w": w}})

# "failure": come back with only 2 devices in a different topology
mesh_b = jax.make_mesh((2,), ("data",))
sh = {"params": {"w": NamedSharding(mesh_b, P(None, "data"))}}
step, tree, _ = load_checkpoint(d, template={"params": {"w": w}}, shardings=sh)
ok = step == 7 and np.array_equal(np.asarray(tree["params"]["w"]),
                                  np.arange(64.0).reshape(8, 8))
resharded = tree["params"]["w"].sharding.spec == P(None, "data")
print("OK", ok and resharded)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "OK True" in r.stdout, (r.stdout, r.stderr[-2000:])


def test_gpipe_matches_sequential_subprocess():
    """2-stage pipeline over a 2-device 'pod' axis == sequential execution."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline_parallel import gpipe_forward

mesh = jax.make_mesh((2,), ("pod",))
rng = np.random.default_rng(0)
S, M, mb, d = 2, 4, 3, 8
ws = jnp.asarray(rng.normal(size=(S, d, d)) * 0.3, jnp.float32)
xs = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

def stage_fn(w, x):
    return jnp.tanh(x @ w)

out = gpipe_forward(stage_fn, ws, xs, mesh, axis="pod")
ref = jnp.stack([stage_fn(ws[1], stage_fn(ws[0], xs[m])) for m in range(M)])
err = float(jnp.max(jnp.abs(out - ref)))
print("OK", err < 1e-5, err)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "OK True" in r.stdout, (r.stdout, r.stderr[-2000:])


def test_bubble_fraction():
    from repro.distributed import bubble_fraction

    assert bubble_fraction(2, 8) == pytest.approx(1 / 9)
    assert bubble_fraction(1, 8) == 0.0


@pytest.mark.slow
def test_spmd_16dev_subprocess():
    """End-to-end SPMD on 16 placeholder devices: per-device flops scale and
    train step lowers+compiles with the production sharding rules."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.lm.config import LMConfig
from repro.lm.model import abstract_params
from repro.lm.steps import make_train_step
from repro.training.optim import adam_init
from repro.distributed.sharding import tree_param_shardings, batch_shardings, dp_axes

cfg = LMConfig(name="tiny", n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
               d_ff=512, vocab_size=1024, head_dim=64, remat=False)
mesh = jax.make_mesh((4, 4), ("data", "model"))
params = abstract_params(cfg)
opt = jax.eval_shape(adam_init, params)
batch = {"tokens": jax.ShapeDtypeStruct((16, 128), jnp.int32),
         "labels": jax.ShapeDtypeStruct((16, 128), jnp.int32)}
ts = make_train_step(cfg, mesh, dp_axes(mesh))
with mesh:
    c = jax.jit(ts, in_shardings=(tree_param_shardings(params, mesh),
                                  tree_param_shardings(opt, mesh),
                                  batch_shardings(batch, mesh))
                ).lower(params, opt, batch).compile()
print("OK", c.cost_analysis() is not None)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "OK" in r.stdout, r.stderr[-2000:]
