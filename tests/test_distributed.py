"""Sharding rules + compression + pipeline + (subprocess) multi-device SPMD."""
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import _fit, param_spec


class FakeMesh:
    """Duck-typed mesh for rule tests (shape dict + axis_names)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def test_fit_divisibility():
    assert _fit(64, "model", MESH) == "model"
    assert _fit(20, "model", MESH) is None           # whisper's 20 heads
    assert _fit(1500, ("data", "model"), MESH) is None
    assert _fit(512, ("data", "model"), MESH) == ("data", "model")
    assert _fit(32, ("data", "model"), MESH) == "data"  # prefix fallback


def test_param_spec_rules():
    P = jax.sharding.PartitionSpec
    # 2D-sharded matrices (leading stack dims replicated)
    assert param_spec("wq", (80, 8192, 8192), MESH) == P(None, "data", "model")
    assert param_spec("wo", (80, 8192, 8192), MESH) == P(None, "model", "data")
    assert param_spec("embed", (152064, 8192), MESH) == P("model", "data")
    # whisper: 20*64=1280 head dim divides, d_model=1280 divides
    assert param_spec("wq", (32, 1280, 1280), MESH) == P(None, "data", "model")
    # qwen2-0.5b kv: 2*64=128 divides 16; d_model 896 divides 16
    assert param_spec("wk", (24, 896, 128), MESH) == P(None, "data", "model")
    # NON-divisible: 14 heads * 64 = 896 ok; but a 20-dim vector is not
    assert param_spec("A_log", (48, 20), MESH) == P(None, None)
    assert param_spec("A_log", (48, 64), MESH) == P(None, "model")
    # norms replicate
    assert param_spec("ln1", (80, 8192), MESH) == P()
    # MoE EP vs TP
    assert param_spec("moe_up", (56, 8, 6144, 16384), MESH, "tp") == P(
        None, None, "data", "model")
    assert param_spec("moe_up", (32, 16, 4096, 14336), MESH, "ep") == P(
        None, "model", "data", None)


def test_fsdp_profile_spec():
    from repro.distributed.sharding import dp_axes, fsdp_param_spec

    P = jax.sharding.PartitionSpec
    # largest divisible dim gets the full flattened axis set
    assert fsdp_param_spec("wq", (36, 2560, 4096), MESH) == P(
        None, None, ("data", "model"))
    # small vectors replicate
    assert fsdp_param_spec("ln1", (2560,), MESH) == P()
    # non-divisible largest dim falls through to the next candidate
    assert fsdp_param_spec("embed", (1500, 4096), MESH) == P(
        None, ("data", "model"))

    class M:  # fake mesh with pod axis
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert dp_axes(M(), "fsdp") == ("pod", "data", "model")
    assert dp_axes(M(), "2d") == ("pod", "data")


def test_cache_sharding_specs_decode():
    from repro.distributed.sharding import cache_shardings
    # needs a real mesh: single-device mesh exercises the no-axis fallbacks
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"k": jax.ShapeDtypeStruct((4, 8, 128, 2, 16), jnp.bfloat16)}
    sh = cache_shardings(tree, mesh)
    assert sh["k"].spec[1] is not None or mesh.shape["data"] == 1


def test_compressed_psum_single_axis():
    from repro.lm.moe import shard_map
    from repro.training.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("x",))
    g = jnp.asarray(np.random.default_rng(0).normal(size=(32,)), jnp.float32)
    err = jnp.zeros_like(g)

    def f(g, e):
        return compressed_psum(g, "x", e)

    out, new_err = shard_map(
        f, mesh, in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
    )(g, err)
    # single peer: mean == dequantized value; error feedback = quant residual
    np.testing.assert_allclose(np.asarray(out + new_err), np.asarray(g),
                               rtol=1e-5, atol=1e-6)
    assert float(jnp.abs(new_err).max()) < float(jnp.abs(g).max()) / 64


def test_make_host_mesh_divisible():
    from repro.launch.mesh import make_host_mesh

    n = len(jax.devices())
    mesh = make_host_mesh(model_parallel=1)  # 1 divides any device count
    assert mesh.shape == {"data": n, "model": 1}


def test_make_host_mesh_indivisible_raises():
    from repro.launch.mesh import make_host_mesh

    n = len(jax.devices())
    bad = n + 1  # > n, so it can never divide n
    with pytest.raises(ValueError) as ei:
        make_host_mesh(model_parallel=bad)
    msg = str(ei.value)
    assert str(n) in msg                      # carries the device count
    assert "xla_force_host_platform_device_count" in msg  # fallback hint
    with pytest.raises(ValueError):
        make_host_mesh(model_parallel=0)


def test_execution_context_single_device_is_noop():
    from repro.distributed.context import ExecutionContext

    ctx = ExecutionContext.single_device()
    assert not ctx.is_sharded
    assert ctx.n_devices == 1 and ctx.dp_size == 1
    assert ctx.param_shardings({"entity": jnp.zeros((4, 4))}) is None
    assert ctx.batch_sharding((8,)) is None and ctx.replicated() is None
    x = np.arange(6.0).reshape(3, 2)
    y = ctx.put_batch(x)
    assert isinstance(y, jax.Array) and np.array_equal(np.asarray(y), x)
    z = jnp.ones((5, 2))
    assert ctx.constrain_batch(z) is z         # no constraint inserted
    assert ctx.donate_argnums(0, 1) == (0, 1)
    import dataclasses

    no_donate = dataclasses.replace(ctx, donate_params=False)
    assert no_donate.donate_argnums(0, 1) == ()


def test_parse_mesh_spec():
    from repro.distributed.context import parse_mesh_spec

    assert parse_mesh_spec("data=8") == {"data": 8, "model": 1}
    assert parse_mesh_spec("data=4,model=2") == {"data": 4, "model": 2}
    assert parse_mesh_spec("pod=2,data=4") == {"pod": 2, "data": 4, "model": 1}
    for bad in ("batch=4", "data=0", "data=x", "data=2,data=2", "model=2", ""):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)


def test_make_execution_context_device_budget():
    from repro.distributed.context import make_execution_context

    assert not make_execution_context(None).is_sharded
    n = len(jax.devices())
    ctx = make_execution_context(f"data={n}")
    assert ctx.is_sharded and ctx.n_devices == n
    with pytest.raises(ValueError) as ei:
        make_execution_context(f"data={n + 1}")
    assert "xla_force_host_platform_device_count" in str(ei.value)


def test_execution_context_sharding_helpers():
    from repro.distributed.context import make_execution_context

    P = jax.sharding.PartitionSpec
    ctx = make_execution_context("data=1", profile="fsdp")
    # batch axis binds only when the leading dim divides the DP ways
    assert ctx.batch_sharding((8, 3)).spec[0] is not None
    assert ctx.batch_sharding(()).spec == P()
    # frozen cache buffers replicate in every profile (collective-free apply)
    assert ctx.param_sharding("sem_cache", (4096, 256)).spec == P()
    assert ctx.param_sharding("sem_slot", (1 << 20,)).spec == P()
    # the big tables DO shard under fsdp
    assert ctx.param_sharding("entity", (4096, 64)).spec[0] is not None
    put = ctx.put_batch(np.zeros((8, 2), np.float32))
    assert put.sharding.spec[0] is not None


def test_prefetcher(tiny_kg):
    from repro.data.pipeline import BatchPrefetcher
    from repro.sampling import OnlineSampler

    s = OnlineSampler(tiny_kg, patterns=("1p", "2i"), seed=0)
    pf = BatchPrefetcher(s, batch_size=4, depth=2, workers=2)
    try:
        batches = [pf.next(timeout=60) for _ in range(3)]
        assert all(len(b) == 4 for b in batches)
    finally:
        pf.close()


def test_elastic_restore_subprocess():
    """Fault-tolerance/elasticity: a checkpoint written under an 8-device
    (4,2) mesh restores onto a shrunk 2-device mesh with different shardings
    and identical values (mesh-shape-agnostic restore)."""
    script = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training.checkpoint import save_checkpoint, load_checkpoint

d = tempfile.mkdtemp()
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh_a, P("data", "model")))
save_checkpoint(d, 7, {"params": {"w": w}})

# "failure": come back with only 2 devices in a different topology
mesh_b = jax.make_mesh((2,), ("data",))
sh = {"params": {"w": NamedSharding(mesh_b, P(None, "data"))}}
step, tree, _ = load_checkpoint(d, template={"params": {"w": w}}, shardings=sh)
ok = step == 7 and np.array_equal(np.asarray(tree["params"]["w"]),
                                  np.arange(64.0).reshape(8, 8))
resharded = tree["params"]["w"].sharding.spec == P(None, "data")
print("OK", ok and resharded)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "OK True" in r.stdout, (r.stdout, r.stderr[-2000:])


def test_gpipe_matches_sequential_subprocess():
    """2-stage pipeline over a 2-device 'pod' axis == sequential execution."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline_parallel import gpipe_forward

mesh = jax.make_mesh((2,), ("pod",))
rng = np.random.default_rng(0)
S, M, mb, d = 2, 4, 3, 8
ws = jnp.asarray(rng.normal(size=(S, d, d)) * 0.3, jnp.float32)
xs = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

def stage_fn(w, x):
    return jnp.tanh(x @ w)

out = gpipe_forward(stage_fn, ws, xs, mesh, axis="pod")
ref = jnp.stack([stage_fn(ws[1], stage_fn(ws[0], xs[m])) for m in range(M)])
err = float(jnp.max(jnp.abs(out - ref)))
print("OK", err < 1e-5, err)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "OK True" in r.stdout, (r.stdout, r.stderr[-2000:])


def test_bubble_fraction():
    from repro.distributed import bubble_fraction

    assert bubble_fraction(2, 8) == pytest.approx(1 / 9)
    assert bubble_fraction(1, 8) == 0.0


@pytest.mark.slow
def test_cse_encode_parity_under_mesh_subprocess():
    """Plan-compiler CSE under a data-sharded mesh: the deduped plan's
    workspace must stay DP-aligned (rows round up to the data ways even
    after CSE shrinks peak slots), and encode must equal the no-CSE path
    bitwise on the same mesh."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.core import PooledExecutor
from repro.core.patterns import QueryInstance
from repro.distributed.context import make_execution_context
from repro.models import ModelConfig, make_model

ctx = make_execution_context("data=4", profile="fsdp")
model = make_model("gqe", ModelConfig(dim=8, entity_pad=4))
params = model.init_params(jax.random.PRNGKey(0), 40, 6, ctx=ctx)
rng = np.random.default_rng(5)
anchors, rels = rng.integers(0, 40, 5), rng.integers(0, 6, 3)
queries = []
for pat, na, nr in [("2p", 1, 2), ("3p", 1, 3), ("1p", 1, 1), ("ip", 2, 3),
                    ("pi", 2, 3), ("2p", 1, 2), ("1p", 1, 1)]:
    queries.append(QueryInstance(
        pat, anchors[rng.integers(5, size=na)].copy(),
        rels[rng.integers(3, size=nr)].copy()))
queries += queries[:3]  # exact duplicates across the batch
ex_on = PooledExecutor(model, b_max=8, ctx=ctx, cse=True)
ex_off = PooledExecutor(model, b_max=8, ctx=ctx, cse=False)
p_on = ex_on.prepare(queries)
assert p_on.report.pooled_rows_saved > 0, p_on.report
a = np.asarray(ex_on.encode(params, queries, compiled=True))
b = np.asarray(ex_off.encode(params, queries, compiled=True))
print("OK", bool(np.array_equal(a, b)))
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "OK True" in r.stdout, (r.stdout, r.stderr[-2000:])


@pytest.mark.slow
def test_spmd_16dev_subprocess():
    """End-to-end SPMD on 16 placeholder devices: per-device flops scale and
    train step lowers+compiles with the production sharding rules."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.lm.config import LMConfig
from repro.lm.model import abstract_params
from repro.lm.steps import make_train_step
from repro.training.optim import adam_init
from repro.distributed.sharding import tree_param_shardings, batch_shardings, dp_axes

cfg = LMConfig(name="tiny", n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
               d_ff=512, vocab_size=1024, head_dim=64, remat=False)
mesh = jax.make_mesh((4, 4), ("data", "model"))
params = abstract_params(cfg)
opt = jax.eval_shape(adam_init, params)
batch = {"tokens": jax.ShapeDtypeStruct((16, 128), jnp.int32),
         "labels": jax.ShapeDtypeStruct((16, 128), jnp.int32)}
ts = make_train_step(cfg, mesh, dp_axes(mesh))
with mesh:
    c = jax.jit(ts, in_shardings=(tree_param_shardings(params, mesh),
                                  tree_param_shardings(opt, mesh),
                                  batch_shardings(batch, mesh))
                ).lower(params, opt, batch).compile()
print("OK", c.cost_analysis() is not None)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "OK" in r.stdout, r.stderr[-2000:]
