"""Observability layer tests (DESIGN.md §Observability): the metrics
registry (counters/gauges/histograms, snapshot/delta, the ONE registry-wide
reset), the span tracer (lanes, nesting across threads, async request spans,
trace-event schema validation), the JSONL metrics sink, step-time breakdown
records, and the ``repro.obs.report`` summarizers."""
import json
import threading
import time

import jax
import pytest

from repro.core import PooledExecutor
from repro.models import ModelConfig, make_model
from repro.obs import (Counter, Gauge, Histogram, MetricsSink, TRACER,
                       get_registry, read_jsonl, validate_trace)
from repro.obs.registry import MetricsRegistry, metric_key
from repro.obs.report import cache_tables, summarize_metrics, summarize_trace
from repro.serving import ServingConfig, ServingEngine, make_workload
from repro.training import AdamConfig, NGDBTrainer, TrainConfig


@pytest.fixture(autouse=True)
def _tracer_off():
    """Every test leaves the process-wide tracer disabled."""
    yield
    TRACER.disable()


def _engine(tiny_kg, dim=8, **kw):
    model = make_model("gqe", ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    return ServingEngine(model, params,
                         executor=PooledExecutor(model, b_max=64), **kw)


def _trainer(tiny_kg, dim=8, **cfg_kw):
    cfg = TrainConfig(batch_size=8, n_negatives=4, b_max=64,
                      adam=AdamConfig(lr=1e-3), seed=0, **cfg_kw)
    return NGDBTrainer(make_model("gqe", ModelConfig(dim=dim, gamma=6.0)),
                       tiny_kg, cfg)


# ------------------------------------------------------------------ registry
def test_counter_is_int_like():
    c = Counter("x_hits")
    c += 2
    c.inc(3)
    assert c == 5 and c > 4 and c <= 5 and bool(c)
    assert int(c) == 5 and float(c) == 5.0 and c / 2 == 2.5
    assert c + 1 == 6 and 1 + c == 6 and 10 - c == 5 and c - 1 == 4
    assert [0] * Counter("n") == []  # __index__
    d = Counter("y")
    d.inc(5)
    assert c == d and not (c < d)  # counter-vs-counter comparisons
    c.reset()
    assert c == 0 and not bool(c)


def test_gauge_reset_is_noop():
    g = Gauge("depth")
    g.set(7)
    g.reset()  # state, not history: reset must not fabricate depth 0
    assert g == 7


def test_histogram_window_and_summary():
    h = Histogram("lat", window=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        h.observe(v)
    assert h.count == 5 and h.sum == 15.0
    assert h.window_values() == [2.0, 3.0, 4.0, 5.0]  # bounded window
    s = h.summary()
    assert s["count"] == 5 and s["window_n"] == 4 and s["window"] == 4
    assert s["p50"] == 3.5 and s["max"] == 5.0
    with pytest.raises(ValueError):
        Histogram("bad", window=0)


def test_metric_key_sorts_labels():
    g = MetricsRegistry().group("cache", cache="encode")
    c = g.counter("hits", b="2", a="1")
    assert metric_key(c) == "cache_hits{a=1,b=2,cache=encode}"


def test_snapshot_aggregates_same_key_instances():
    reg = MetricsRegistry()
    c1 = reg.group("serving").counter("batches")
    c2 = reg.group("serving").counter("batches")  # second engine
    c1.inc(3)
    c2.inc(4)
    snap = reg.snapshot()
    assert snap["serving_batches"] == 7
    c1.inc(10)
    d = reg.delta(snap)
    assert d["serving_batches"] == 10


def test_snapshot_histogram_keys():
    reg = MetricsRegistry()
    h = reg.group("serving").histogram("latency_ms", window=8)
    h.observe(10.0)
    h.observe(20.0)
    snap = reg.snapshot()
    assert snap["serving_latency_ms_count"] == 2
    assert snap["serving_latency_ms_sum"] == 30.0
    assert snap["serving_latency_ms_window_n"] == 2
    assert snap["serving_latency_ms_p50"] == 15.0


def test_registry_holds_metrics_weakly():
    reg = MetricsRegistry()
    g = reg.group("tmp")
    c = g.counter("hits")
    c.inc()
    assert "tmp_hits" in reg.snapshot()
    del g, c  # component dies -> its metrics leave the snapshot
    assert "tmp_hits" not in reg.snapshot()


def test_group_reset_scopes_and_only():
    reg = MetricsRegistry()
    g = reg.group("eng")
    a, b = g.counter("a"), g.counter("b")
    a.inc(5)
    b.inc(5)
    g.reset(only=[a])
    assert a == 0 and b == 5
    g.reset()
    assert b == 0


def test_registry_reset_runs_hooks():
    reg = MetricsRegistry()
    fired = []

    class Comp:
        def hook(self):
            fired.append(1)

    comp = Comp()
    reg.on_reset(comp.hook)
    reg.reset()
    assert fired == [1]
    del comp  # weakly held: dead component's hook disappears
    reg.reset()
    assert fired == [1]


# ------------------------------------------- satellite: one reset, no drift
def test_registry_reset_zeroes_every_published_counter(tiny_kg):
    """Regression for counter-reset drift: after warmup, ONE registry-level
    reset() must zero every published counter together — no component-
    specific path can leave a sibling's counters running."""
    tr = _trainer(tiny_kg, materialized_rows=64)
    tr.train(3, log_every=0)
    engine = _engine(tiny_kg, dim=12, cfg=ServingConfig(max_batch=8))
    try:
        for f in engine.submit_many(make_workload(tiny_kg, 8, seed=3)):
            f.result(timeout=60)
        # warm state: counters across four+ subsystems are nonzero
        assert tr.compile_cache_stats()["train_step"]["misses"] > 0
        assert engine.stats()["submitted"] == 8
        get_registry().reset()
        # every live counter/histogram in the process is zero — checked at
        # the registry (the source of truth every stats() dict reads)
        for m in get_registry().metrics():
            if m.kind == "counter":
                assert m.read() == 0, f"{metric_key(m)} survived reset()"
            elif m.kind == "histogram":
                assert m.count == 0, f"{metric_key(m)} survived reset()"
        # and the published views agree
        cs = tr.compile_cache_stats()
        assert all(cs[k]["hits"] == 0 and cs[k]["misses"] == 0 for k in cs)
        st = engine.stats()
        assert st["submitted"] == 0 and st["completed"] == 0
        assert st["batches"] == 0 and st["coalesced"] == 0
        assert all(v == 0 for v in st["flushes"].values())
        assert st["retraces"] == 0  # re-baselined by the on_reset hook
        sh = tr.executor.sharing_stats()
        assert sh["nodes_before"] == 0 and sh["plan_cache"]["hits"] == 0
        assert sh["materialized"]["hits"] == 0
    finally:
        engine.close()


# --------------------------------------------- satellite: latency_window
def test_engine_latency_window_and_window_n(tiny_kg):
    engine = _engine(tiny_kg, dim=10, cfg=ServingConfig(max_batch=4),
                     latency_window=4)
    try:
        for f in engine.submit_many(make_workload(tiny_kg, 6, seed=5)):
            f.result(timeout=60)
        lm = engine.stats()["latency_ms"]
        assert lm["window"] == 4
        assert lm["window_n"] == 4  # 6 observed, window keeps the last 4
        assert lm["n"] == 4  # percentiles computed over the window
    finally:
        engine.close()
    with pytest.raises(ValueError):
        _engine(tiny_kg, dim=10, latency_window=0)


# -------------------------------------------------------------------- tracer
def test_disabled_tracer_is_free_and_silent():
    TRACER.disable()
    s1 = TRACER.span("a", n=1)
    s2 = TRACER.span("b")
    assert s1 is s2  # shared null context: the one-attribute-read fast path
    with s1:
        pass
    TRACER.instant("x")
    TRACER.counter("q", depth=3)
    TRACER.async_begin("r", 1)
    TRACER.async_end("r", 1)
    assert TRACER._events == []


def test_spans_nest_within_and_across_threads():
    TRACER.enable(jax_annotations=False)
    TRACER.set_lane("main dispatch")
    with TRACER.span("outer"):
        with TRACER.span("inner"):
            time.sleep(0.002)

    def worker():
        TRACER.set_lane("pipeline scheduler")
        with TRACER.span("schedule"):
            with TRACER.span("transfer"):
                time.sleep(0.002)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    obj = TRACER.to_json()
    s = validate_trace(obj)
    # superset: lane names persist process-wide, so earlier tests' threads
    # may also appear
    assert {"main dispatch", "pipeline scheduler"} <= set(s["lanes"])
    ev = {e["name"]: e for e in obj["traceEvents"] if e["ph"] == "X"}
    # children close before parents and sit inside the parent's interval,
    # on the parent's lane
    for child, parent in (("inner", "outer"), ("transfer", "schedule")):
        c, p = ev[child], ev[parent]
        assert c["tid"] == p["tid"]
        assert p["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-3
    # the two threads got distinct lanes
    assert ev["outer"]["tid"] != ev["schedule"]["tid"]


def test_set_lane_survives_enable():
    """Long-lived threads (batcher, scheduler) name their lane once at
    thread start — possibly before enable(); the name must still appear."""
    TRACER.disable()
    done = threading.Event()
    go = threading.Event()

    def worker():
        TRACER.set_lane("early bird")  # registered while disabled
        done.set()
        go.wait(5)
        with TRACER.span("work"):
            pass

    t = threading.Thread(target=worker)
    t.start()
    done.wait(5)
    TRACER.enable(jax_annotations=False)
    go.set()
    t.join()
    s = validate_trace(TRACER.to_json())
    assert "early bird" in s["lanes"]
    assert "work" in s["names"]


def test_max_events_truncation():
    TRACER.enable(jax_annotations=False, max_events=3)
    for i in range(10):
        TRACER.instant(f"e{i}")
    obj = TRACER.to_json()
    # metadata (lane names) is exempt from the cap; data events are capped
    data = [e for e in obj["traceEvents"] if e["ph"] != "M"]
    assert len(data) == 3
    assert obj["otherData"]["truncated"] is True
    validate_trace(obj)
    TRACER.enable(jax_annotations=False, max_events=2_000_000)  # restore


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({"events": []})
    with pytest.raises(ValueError, match="missing key"):
        validate_trace({"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0,
                                         "pid": 1, "tid": 1}]})  # no dur
    with pytest.raises(ValueError, match="bad dur"):
        validate_trace({"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0,
                                         "dur": -1.0, "pid": 1, "tid": 1}]})
    with pytest.raises(ValueError, match="unsupported phase"):
        validate_trace({"traceEvents": [{"name": "a", "ph": "Z"}]})
    with pytest.raises(ValueError, match="without begin"):
        validate_trace({"traceEvents": [
            {"name": "r", "ph": "e", "ts": 0.0, "id": 1, "pid": 1, "tid": 1,
             "cat": "request"}]})
    with pytest.raises(ValueError, match="unbalanced"):
        validate_trace({"traceEvents": [
            {"name": "r", "ph": "b", "ts": 0.0, "id": 1, "pid": 1, "tid": 1,
             "cat": "request"}]})


# ------------------------------------- satellite: trace ids through serving
def test_request_spans_thread_submit_to_complete(tiny_kg):
    engine = _engine(tiny_kg, dim=14, cfg=ServingConfig(max_batch=4))
    try:
        TRACER.enable(jax_annotations=False)
        for f in engine.submit_many(make_workload(tiny_kg, 6, seed=9)):
            f.result(timeout=60)
        obj = TRACER.to_json()
        TRACER.disable()
        s = validate_trace(obj)  # includes b/e balance per (cat, id, name)
        begins = [e for e in obj["traceEvents"]
                  if e["ph"] == "b" and e["name"] == "request"]
        assert len(begins) == 6
        assert len({e["id"] for e in begins}) == 6  # one async span each
        assert {"batch", "encode", "score", "select"} <= set(s["names"])
        assert "serving batcher" in s["lanes"]
    finally:
        engine.close()


def test_coalesced_requests_keep_distinct_request_spans(tiny_kg):
    """Duplicate in-flight requests share ONE computed row (one batch/encode
    span) but each keeps its own request span, so per-request latency stays
    attributable in the trace."""
    engine = _engine(tiny_kg, dim=14,
                     cfg=ServingConfig(max_batch=8, max_wait_ms=100.0))
    try:
        q = make_workload(tiny_kg, 1, seed=9)[0]
        TRACER.enable(jax_annotations=False)
        for f in engine.submit_many([q] * 8):
            f.result(timeout=60)
        obj = TRACER.to_json()
        TRACER.disable()
        validate_trace(obj)
        ids = {e["id"] for e in obj["traceEvents"]
               if e["ph"] == "b" and e["name"] == "request"}
        assert len(ids) == 8  # distinct request spans for every duplicate
        batches = [e for e in obj["traceEvents"]
                   if e["ph"] == "X" and e["name"] == "batch"]
        assert len(batches) < 8  # shared compute spans
        assert any(len(b["args"]["trace_ids"]) > 1 for b in batches)
        # every request id appears in exactly one batch's trace_ids
        covered = [i for b in batches for i in b["args"]["trace_ids"]]
        assert sorted(covered) == sorted(ids)
        assert engine.stats()["coalesced"] >= 1
    finally:
        engine.close()


# ------------------------------------------------------- sink + breakdowns
def test_metrics_sink_disabled_and_roundtrip(tmp_path):
    off = MetricsSink(None)
    assert not off.enabled
    off.write({"kind": "step"})  # no-op
    assert off.records == 0
    p = tmp_path / "m.jsonl"
    with MetricsSink(str(p)) as sink:
        assert sink.enabled
        sink.write({"kind": "step", "loss": 1.5})
        sink.write({"kind": "snapshot", "metrics": {"a": 1}})
    recs = read_jsonl(str(p))
    assert [r["kind"] for r in recs] == ["step", "snapshot"]
    assert recs[0]["loss"] == 1.5


def test_sync_trainer_writes_step_records(tiny_kg, tmp_path):
    p = tmp_path / "sync.jsonl"
    tr = _trainer(tiny_kg, metrics_path=str(p))
    tr.train(3, log_every=0)
    recs = read_jsonl(str(p))
    assert len(recs) == 3
    for r in recs:
        assert r["kind"] == "step" and r["mode"] == "sync"
        assert "loss" in r and "schedule_s" in r and "retire_s" in r
    # history records are untouched: the JSONL is a separate surface
    assert set(tr.history[0]) == {"step", "loss", "queries_per_sec"}


def test_pipelined_trainer_writes_bubble_fraction(tiny_kg, tmp_path):
    from repro.sampling import OnlineSampler

    p = tmp_path / "pipe.jsonl"
    batches = [OnlineSampler(tiny_kg, seed=17).sample_batch(8)]
    tr = _trainer(tiny_kg, pipeline=True, metrics_path=str(p))
    tr.train(4, log_every=0, batches=batches)
    recs = read_jsonl(str(p))
    assert len(recs) == 4
    for r in recs:
        assert r["mode"] == "pipelined"
        assert 0.0 <= r["bubble_frac"] <= 1.0
        assert r["wall_s"] > 0
        assert "wait_s" in r and "schedule_s" in r and "transfer_s" in r


def test_phase_counters_register_in_snapshot(tiny_kg, mixed_queries):
    tr = _trainer(tiny_kg)
    # pinned batch: step 1 is the cold compile, step 2 a warm dispatch
    tr.train(2, log_every=0, batches=[list(mixed_queries)[:8]])
    snap = get_registry().snapshot()
    assert snap["trainer_steps"] >= 2
    assert snap["trainer_phase_seconds{phase=dispatch}"] > 0
    assert snap["trainer_phase_seconds{phase=retire}"] > 0


# ------------------------------------------------------------------- report
def test_report_summarizers():
    TRACER.enable(jax_annotations=False)
    TRACER.set_lane("main dispatch")
    with TRACER.span("dispatch"):
        time.sleep(0.001)
    out = summarize_trace(TRACER.to_json())
    TRACER.disable()
    assert "main dispatch" in out and "dispatch" in out

    steps = [{"kind": "step", "mode": "pipelined", "wall_s": 0.1,
              "wait_s": 0.01, "dispatch_s": 0.08, "bubble_frac": 0.1}] * 3
    out = summarize_metrics(steps)
    assert "3 step records" in out and "pipeline bubble" in out
    assert summarize_metrics([]).startswith("metrics: no step records")

    out = cache_tables({"cache_hits{cache=encode}": 3,
                        "cache_misses{cache=encode}": 1,
                        "plan_cache_hits": 9, "plan_cache_misses": 1,
                        "unrelated_gauge": 5})
    assert "cache{cache=encode}" in out and "75.0%" in out
    assert "plan_cache" in out and "90.0%" in out


def test_report_cli_end_to_end(tiny_kg, tmp_path):
    """The exact flow the README quickstart documents: train with
    --metrics/--trace equivalents, then summarize both files."""
    from repro.obs.report import main as report_main

    p = tmp_path / "m.jsonl"
    tp = tmp_path / "t.json"
    TRACER.enable(jax_annotations=False)
    tr = _trainer(tiny_kg, metrics_path=str(p))
    tr.train(2, log_every=0)
    tr.metrics_sink.write({"kind": "snapshot",
                           "metrics": get_registry().snapshot()})
    tr.metrics_sink.close()
    TRACER.write(str(tp))
    TRACER.disable()
    validate_trace(json.load(open(tp)))
    report_main(["--trace", str(tp), "--metrics", str(p)])
