"""Launch-layer unit tests: HLO collective parser, roofline terms, shape
cells — all pure shape/string math (no 512-device compiles here; those run in
scripts/sweep_dryrun.sh and the subprocess tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    CollectiveStats,
    model_flops,
    parse_collectives,
    roofline_terms,
    _type_bytes,
    _wire_bytes,
)
from repro.lm.shapes import SHAPES, cell_supported, input_specs
from repro.lm.steps import cache_struct

_HLO = """
  %ag = bf16[16,512,1024]{2,1,0} all-gather(bf16[1,512,1024]{2,1,0} %p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar.1 = f32[256,128]{1,0} all-reduce(f32[256,128]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(f32[1024,128]{1,0} %y), replica_groups=[32,16]<=[512], dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %z), source_target_pairs={{0,1}}
  %a2a = bf16[4,4]{1,0} all-to-all(bf16[4,4]{1,0} %w), replica_groups={{0,1,2,3,4,5,6,7}}
  %done = f32[2] add(f32[2] %a, f32[2] %b)
"""


def test_type_bytes():
    assert _type_bytes("bf16[16,512,1024]{2,1,0}") == 16 * 512 * 1024 * 2
    assert _type_bytes("f32[256,128]{1,0}") == 256 * 128 * 4
    assert _type_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _type_bytes("u32[8]{0}") == 32


def test_parse_collectives():
    st = parse_collectives(_HLO, total_devices=256)
    assert st.counts == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                         "collective-permute": 1, "all-to-all": 1}
    ag = 16 * 512 * 1024 * 2
    assert st.by_type["all-gather"] == pytest.approx(ag * 15 / 16)
    ar = 256 * 128 * 4
    assert st.by_type["all-reduce"] == pytest.approx(2 * ar * 3 / 4)  # group of 4
    rs = 64 * 128 * 4
    assert st.by_type["reduce-scatter"] == pytest.approx(rs * 15)     # group of 16
    assert st.by_type["collective-permute"] == 32.0


def test_wire_bytes_factors():
    assert _wire_bytes("all-gather", 100, 1) == 0.0
    assert _wire_bytes("all-reduce", 100, 2) == pytest.approx(100.0)
    assert _wire_bytes("all-to-all", 160, 16) == pytest.approx(150.0)


def test_roofline_dominance():
    r = roofline_terms(PEAK_FLOPS, HBM_BW * 0.5, ICI_BW * 2)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(0.5)
    assert r["collective_s"] == pytest.approx(2.0)
    assert r["dominant"] == "collective"
    assert r["roofline_fraction_compute"] == pytest.approx(0.5)


def test_model_flops_train_vs_decode():
    cfg = ARCHS["qwen3-4b"]
    tr = model_flops(cfg, SHAPES["train_4k"], "train")
    dec = model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert tr == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    assert dec == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
    moe = ARCHS["mixtral-8x22b"]
    assert moe.active_param_count() < moe.param_count()


def test_all_cells_have_specs():
    """input_specs must produce well-formed ShapeDtypeStructs for every
    runnable (arch x shape) cell — 40 cells, 7 documented skips."""
    runnable, skipped = 0, 0
    for name, cfg in ARCHS.items():
        for shape in SHAPES:
            if cell_supported(cfg, shape):
                skipped += 1
                continue
            runnable += 1
            specs = input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves and all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
            cell = SHAPES[shape]
            if cell.kind == "train":
                assert specs["batch"]["labels"].shape == (cell.global_batch,
                                                          cell.seq_len)
            elif cell.kind == "decode":
                assert specs["tokens"].shape == (cell.global_batch, 1)
    assert runnable == 33 and skipped == 7


def test_long_500k_skips_match_design():
    expected_skip = {"qwen2-72b", "qwen3-4b", "qwen2-0.5b", "internlm2-20b",
                     "grok-1-314b", "llava-next-34b", "whisper-large-v3"}
    actual = {n for n, c in ARCHS.items() if cell_supported(c, "long_500k")}
    assert actual == expected_skip
    # SSM / hybrid / SWA archs must run it
    for n in ("mamba2-1.3b", "jamba-v0.1-52b", "mixtral-8x22b"):
        assert cell_supported(ARCHS[n], "long_500k") is None


def test_cache_struct_shapes():
    cfg = ARCHS["mixtral-8x22b"]
    c = cache_struct(cfg, batch=4, s_cache=32768)
    k = c["pos0"]["k"]
    # SWA: the cache is the ring window, not the full sequence
    assert k.shape == (cfg.n_layers, 4, cfg.sliding_window, cfg.n_kv_heads,
                       cfg.resolved_head_dim)
    cfg2 = ARCHS["jamba-v0.1-52b"]
    c2 = cache_struct(cfg2, batch=2, s_cache=1024)
    assert "k" in c2["pos0"] and "ssm" in c2["pos1"]
    assert c2["pos1"]["ssm"].shape[0] == cfg2.n_layers // 8
