"""Out-of-core semantic store subsystem (DESIGN.md §SemanticStore):
sharded mmap store, int8 layout, crash-safe opens, hot-set cache accounting,
and end-to-end bit-identical training vs the full-resident path."""
import os

import numpy as np
import pytest

from repro.semantic import (PTEConfig, SemanticCache, SemanticStore,
                            SemanticStoreError, SemanticStoreWriter, StubPTE,
                            dequantize_int8, precompute_semantic_table,
                            precompute_semantic_table_to_store, quantize_int8)

PTE_CFG = PTEConfig(d_l=16, n_layers=1, d_model=32, n_heads=2)


@pytest.fixture(scope="module")
def sem_table(tiny_kg):
    return precompute_semantic_table(tiny_kg, StubPTE(PTE_CFG))


# ---------------------------------------------------------------- quantizer
def test_int8_roundtrip_error_bound(rng):
    rows = rng.normal(size=(64, 32)).astype(np.float32)
    rows[7] = 0.0  # zero row must not divide by zero
    q, scale = quantize_int8(rows)
    deq = dequantize_int8(q, scale)
    assert q.dtype == np.int8 and scale.dtype == np.float32
    # |x - deq| <= scale/2 per element, scale = max|row|/127.
    bound = np.abs(rows).max(axis=1, keepdims=True) / 254.0 + 1e-7
    assert (np.abs(rows - deq) <= bound).all()
    assert (deq[7] == 0).all()


# -------------------------------------------------------------------- store
def test_streaming_store_bitwise_matches_in_memory(tiny_kg, sem_table, tmp_path):
    """fp32 store precompute == in-memory precompute, bit for bit — with a
    shard size that forces multiple shards and a ragged last shard."""
    store = precompute_semantic_table_to_store(
        tiny_kg, str(tmp_path), StubPTE(PTE_CFG), shard_rows=64)
    assert store.n_rows == tiny_kg.n_entities and store.dim == PTE_CFG.d_l
    assert len(store._shards) == 4  # 200 rows / 64 -> 3 full + 1 ragged
    got = store.read_rows(np.arange(tiny_kg.n_entities))
    np.testing.assert_array_equal(got, sem_table)
    # scattered gather order is honored
    ids = np.array([150, 3, 64, 63, 199, 0])
    np.testing.assert_array_equal(store.read_rows(ids), sem_table[ids])
    # staging file cleaned up, only shards + meta remain
    names = sorted(os.listdir(tmp_path))
    assert names == ["meta.json"] + [f"shard_{i:05d}.bin" for i in range(4)]


def test_int8_store_within_bound(tiny_kg, sem_table, tmp_path):
    store = precompute_semantic_table_to_store(
        tiny_kg, str(tmp_path), StubPTE(PTE_CFG), shard_rows=64, quant="int8")
    got = store.read_rows(np.arange(tiny_kg.n_entities))
    bound = np.abs(sem_table).max(axis=1, keepdims=True) / 254.0 + 1e-7
    assert (np.abs(got - sem_table) <= bound).all()
    assert store.disk_nbytes < tiny_kg.n_entities * PTE_CFG.d_l * 4 / 3


def test_iter_shards_covers_all_rows(tiny_kg, sem_table, tmp_path):
    store = precompute_semantic_table_to_store(
        tiny_kg, str(tmp_path), StubPTE(PTE_CFG), shard_rows=64)
    chunks = list(store.iter_shards())
    assert [lo for lo, _ in chunks] == [0, 64, 128, 192]
    np.testing.assert_array_equal(
        np.concatenate([rows for _, rows in chunks]), sem_table)


# ----------------------------------------------------------- crash safety
def test_partial_store_rejected(tiny_kg, tmp_path):
    d = str(tmp_path / "s")
    precompute_semantic_table_to_store(tiny_kg, d, StubPTE(PTE_CFG),
                                       shard_rows=64)
    # 1) truncated shard (crash mid-write would never publish it, but bitrot
    #    or manual copying can): open must refuse.
    shard = os.path.join(d, "shard_00001.bin")
    payload = open(shard, "rb").read()
    with open(shard, "wb") as f:
        f.write(payload[:-16])
    with pytest.raises(SemanticStoreError, match="partial shard"):
        SemanticStore(d)
    with open(shard, "wb") as f:
        f.write(payload)
    SemanticStore(d)  # restored -> opens again
    # 2) missing shard
    os.remove(shard)
    with pytest.raises(SemanticStoreError, match="missing shard"):
        SemanticStore(d)


def test_interrupted_precompute_leaves_no_openable_store(tmp_path):
    """A writer that never finalized (crash before meta publish) must not
    produce an openable store, even with complete-looking shard files."""
    d = str(tmp_path / "crashed")
    w = SemanticStoreWriter(d, dim=8, shard_rows=4)
    w.append(np.ones((6, 8), dtype=np.float32))  # flushes one shard
    assert os.path.exists(os.path.join(d, "shard_00000.bin"))
    with pytest.raises(SemanticStoreError, match="missing meta"):
        SemanticStore(d)


def test_rebuild_invalidates_stale_store_first(tmp_path):
    """Starting a writer over an existing store must invalidate it
    immediately: a crash mid-rebuild leaves old meta + mixed shard files
    with plausible byte counts, which open() would otherwise accept."""
    d = str(tmp_path / "s")
    w = SemanticStoreWriter(d, dim=8, shard_rows=4)
    w.append(np.ones((8, 8), dtype=np.float32))
    w.finalize()
    SemanticStore(d)  # valid store on disk
    # rebuild starts (e.g. different dataset), crashes after one shard
    w2 = SemanticStoreWriter(d, dim=8, shard_rows=4)
    w2.append(np.zeros((4, 8), dtype=np.float32))
    with pytest.raises(SemanticStoreError, match="missing meta"):
        SemanticStore(d)  # old meta gone -> mixed state is NOT openable


def test_writer_rejects_bad_layouts(tmp_path):
    with pytest.raises(SemanticStoreError, match="quant"):
        SemanticStoreWriter(str(tmp_path), dim=8, quant="fp16")


# -------------------------------------------------------------------- cache
def test_cache_hit_miss_eviction_accounting(sem_table):
    cache = SemanticCache(sem_table, budget_rows=8)
    params = {"sem_cache": cache.buffer, "sem_slot": cache.slot_map}

    stage = cache.plan(np.array([1, 2, 3, 1, 2]))  # dupes count once
    assert (cache.hits, cache.misses, cache.evictions) == (0, 3, 0)
    assert stage.n_rows == 3
    params = cache.apply_to(params, stage)
    assert cache.resident_rows == 3

    assert cache.plan(np.array([1, 2, 3])) is None  # full hit -> no stage
    assert cache.hits == 3

    stage = cache.plan(np.arange(10, 17))  # 7 misses; budget 8 -> evictions
    params = cache.apply_to(params, stage)
    assert cache.misses == 10 and cache.evictions == 2
    assert cache.resident_rows == 8

    # residency is re-established after eviction, from the store
    stage = cache.plan(np.array([1, 2]))
    params = cache.apply_to(params, stage)
    got = np.asarray(params["sem_cache"])[np.asarray(params["sem_slot"])[[1, 2]]]
    np.testing.assert_array_equal(got, sem_table[[1, 2]])

    s = cache.stats()
    assert s["hit_rate"] == pytest.approx(s["hits"] / (s["hits"] + s["misses"]))
    assert s["device_resident_sem_bytes"] == 8 * 16 * 4 + len(sem_table) * 4


def test_cache_rejects_oversized_working_set(sem_table):
    cache = SemanticCache(sem_table, budget_rows=4)
    with pytest.raises(RuntimeError, match="budget"):
        cache.plan(np.arange(5))


def test_cache_never_evicts_current_batch(sem_table):
    cache = SemanticCache(sem_table, budget_rows=4)
    params = {"sem_cache": cache.buffer, "sem_slot": cache.slot_map}
    for ids in ([0, 1, 2, 3], [4, 1, 5, 2], [6, 7, 8, 9]):
        stage = cache.plan(np.array(ids))
        if stage is not None:
            params = cache.apply_to(params, stage)
        got = np.asarray(params["sem_cache"])[np.asarray(params["sem_slot"])[ids]]
        np.testing.assert_array_equal(got, sem_table[ids])


def test_mmap_store_gather_equals_in_memory(tiny_kg, sem_table, tmp_path, rng):
    """Cache backed by the mmap store serves the same bytes as the table."""
    store = precompute_semantic_table_to_store(
        tiny_kg, str(tmp_path), StubPTE(PTE_CFG), shard_rows=64)
    cache = SemanticCache(store, budget_rows=32)
    params = {"sem_cache": cache.buffer, "sem_slot": cache.slot_map}
    for _ in range(5):
        ids = rng.integers(0, tiny_kg.n_entities, size=20)
        stage = cache.plan(ids)
        if stage is not None:
            params = cache.apply_to(params, stage)
        got = np.asarray(params["sem_cache"])[np.asarray(params["sem_slot"])[ids]]
        np.testing.assert_array_equal(got, sem_table[ids])
    assert cache.evictions > 0  # budget actually exercised


def test_stage_apply_out_of_order_rejected(sem_table):
    cache = SemanticCache(sem_table, budget_rows=8)
    params = {"sem_cache": cache.buffer, "sem_slot": cache.slot_map}
    s1 = cache.plan(np.array([0, 1]))
    s2 = cache.plan(np.array([2, 3]))
    with pytest.raises(RuntimeError, match="out of order"):
        cache.apply_to(params, s2)
    params = cache.apply_to(params, s1)
    cache.reconcile()  # s2 planned but dropped -> residency reset
    assert cache.resident_rows == 0


# -------------------------------------------------- end-to-end train parity
def _fixed_batches(kg, n, batch):
    from repro.sampling import OnlineSampler

    sampler = OnlineSampler(kg, seed=5, patterns=("1p", "2p", "2i"))
    return [sampler.sample_batch(batch) for _ in range(n)]


@pytest.fixture(scope="module")
def tiny_store(tiny_kg, tmp_path_factory):
    d = str(tmp_path_factory.mktemp("semstore"))
    return precompute_semantic_table_to_store(tiny_kg, d, StubPTE(PTE_CFG),
                                              shard_rows=64)


def _trainer(kg, table=None, cache=None, pipeline=False):
    from repro.models import ModelConfig, make_model
    from repro.training import AdamConfig, NGDBTrainer, TrainConfig

    model = make_model("gqe", ModelConfig(dim=16, semantic_dim=PTE_CFG.d_l,
                                          semantic_proj_dim=8))
    cfg = TrainConfig(batch_size=8, n_negatives=4, b_max=64,
                      prefetch=2 if pipeline else 0, pipeline=pipeline,
                      patterns=("1p", "2p", "2i"), adam=AdamConfig(lr=1e-3))
    return NGDBTrainer(model, kg, cfg, semantic_table=table,
                       semantic_cache=cache)


def test_out_of_core_training_bit_identical(tiny_kg, tiny_store, sem_table):
    """The §4.4 proof at test scale: budget (96) << E (200), fp32 mode, sync
    AND pipelined out-of-core runs match full-resident losses bit for bit
    while the pipelined run stages every row from the prefetch thread."""
    batches = _fixed_batches(tiny_kg, 6, 8)

    tr_full = _trainer(tiny_kg, table=sem_table)
    tr_full.train(6, log_every=0, batches=batches)
    ref = [r["loss"] for r in tr_full.history]

    cache = SemanticCache(tiny_store, budget_rows=96)
    tr_sync = _trainer(tiny_kg, cache=cache)
    tr_sync.train(6, log_every=0, batches=batches)
    assert [r["loss"] for r in tr_sync.history] == ref

    cache_p = SemanticCache(tiny_store, budget_rows=96)
    tr_pipe = _trainer(tiny_kg, cache=cache_p, pipeline=True)
    tr_pipe.train(6, log_every=0, batches=batches)
    assert [r["loss"] for r in tr_pipe.history] == ref

    # trained (non-semantic-buffer) params identical across all three
    import jax

    frozen = ("sem_table", "sem_cache", "sem_slot")
    for other in (tr_sync, tr_pipe):
        a = {k: v for k, v in tr_full.params.items() if k not in frozen}
        b = {k: v for k, v in other.params.items() if k not in frozen}
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # pipeline-integrated prefetch: all staging off the critical path
    s = cache_p.stats()
    assert s["stages_background"] == s["stages"] and s["sync_stages"] == 0
    assert s["prefetch_overlap_frac"] == 1.0
    # bounded device residency
    full_bytes = tiny_kg.n_entities * PTE_CFG.d_l * 4
    assert s["device_resident_sem_bytes"] < full_bytes


def test_score_all_guard_and_chunked_parity(tiny_kg, tiny_store, sem_table):
    """score_all refuses cache params; score_all_chunked streams the store
    and matches the full-resident dense scorer."""
    import jax

    from repro.sampling import OnlineSampler

    tr_full = _trainer(tiny_kg, table=sem_table)
    cache = SemanticCache(tiny_store, budget_rows=96)
    tr_ooc = _trainer(tiny_kg, cache=cache)

    qs = [b.query for b in OnlineSampler(tiny_kg, seed=3).sample_batch(6)]
    anchors = np.unique(np.concatenate([q.anchors for q in qs]))
    stage = cache.plan(anchors)
    if stage is not None:
        tr_ooc.params = cache.apply_to(tr_ooc.params, stage)

    states = tr_ooc.executor.encode(tr_ooc.params, qs)
    with pytest.raises(RuntimeError, match="score_all_chunked"):
        tr_ooc.model.score_all(tr_ooc.params, states)

    states_full = tr_full.executor.encode(tr_full.params, qs)
    np.testing.assert_array_equal(np.asarray(states), np.asarray(states_full))

    dense = np.asarray(jax.jit(tr_full.model.score_all)(tr_full.params, states_full))
    chunked = tr_ooc.model.score_all_chunked(tr_ooc.params, states,
                                             tiny_store.read_rows, chunk=64)
    np.testing.assert_allclose(chunked, dense[:, : tiny_kg.n_entities],
                               rtol=0, atol=1e-6)


def test_gather_fuse_kernel_from_cache(tiny_kg, tiny_store, sem_table, rng):
    """The Pallas gather_fuse path gathers from the hot-set cache via the
    slot indirection and matches both the cache-mode and full-resident
    model fusion bit for bit."""
    import jax.numpy as jnp

    from repro.kernels import ops

    cache = SemanticCache(tiny_store, budget_rows=64)
    tr_full = _trainer(tiny_kg, table=sem_table)
    tr_ooc = _trainer(tiny_kg, cache=cache)

    ids = rng.integers(0, tiny_kg.n_entities, size=16)
    stage = cache.plan(ids)
    if stage is not None:
        tr_ooc.params = cache.apply_to(tr_ooc.params, stage)

    kernel = ops.gather_fuse_params(tr_ooc.params, jnp.asarray(ids, jnp.int32),
                                    interpret=True)
    model_cache = tr_ooc.model.fused_entity_vec(tr_ooc.params, jnp.asarray(ids))
    model_full = tr_full.model.fused_entity_vec(tr_full.params, jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(kernel), np.asarray(model_cache))
    np.testing.assert_array_equal(np.asarray(kernel), np.asarray(model_full))


# -------------------------------------------------------------- satellites
def test_descriptions_vectorized_matches_reference(tiny_kg):
    """The numpy-vectorized tokenizer must reproduce the seed's per-entity
    Python loop exactly."""
    from repro.semantic.pte import _DESC_LEN, _VOCAB

    def reference(kg, ent_ids):
        indptr, rels, tails = kg.relations_by_head
        toks = np.zeros((len(ent_ids), _DESC_LEN), dtype=np.int32)
        for i, e in enumerate(np.asarray(ent_ids)):
            e = int(e)
            row = [e % _VOCAB, (e * 2654435761) % _VOCAB]
            lo, hi = indptr[e], indptr[e + 1]
            for j in range(lo, min(hi, lo + (_DESC_LEN - 2) // 2)):
                row.append(int(rels[j]) % _VOCAB)
                row.append(int(tails[j]) % _VOCAB)
            toks[i, : len(row)] = row[:_DESC_LEN]
        return toks

    ids = np.concatenate([np.arange(tiny_kg.n_entities), [0, 5, 5, 199]])
    np.testing.assert_array_equal(StubPTE.descriptions(tiny_kg, ids),
                                  reference(tiny_kg, ids))


def test_serve_topk_matches_argsort(rng):
    from repro.launch.serve import topk_desc

    scores = rng.normal(size=(7, 300)).astype(np.float32)
    ref = np.argsort(-scores, axis=1)[:, :10]
    got = topk_desc(scores, 10)
    np.testing.assert_array_equal(np.take_along_axis(scores, got, axis=1),
                                  np.take_along_axis(scores, ref, axis=1))
    assert topk_desc(scores, 1000).shape == (7, 300)
