"""Live-write subsystem (DESIGN.md §LiveStore): SemanticStore in-place
growth, staleness-bounded serving with version pinning, entity-table growth,
and the LiveNGDB write coordinator with background incremental fine-tuning."""
import os
import threading

import jax
import numpy as np
import pytest

from repro.core import PooledExecutor
from repro.data import KnowledgeGraph, generate_synthetic_kg
from repro.launch.serve import serve_batch
from repro.core.patterns import QueryInstance
from repro.models import ModelConfig, make_model
from repro.semantic import SemanticStore, SemanticStoreWriter
from repro.serving import (LiveNGDB, ServingConfig, ServingEngine,
                           StaleVersionError, WriteReceipt, grow_entity_rows)
from repro.training.loop import incremental_finetune


def _store(tmp_path, rows, *, quant="fp32", shard_rows=4, name="s"):
    d = str(tmp_path / name)
    w = SemanticStoreWriter(d, dim=rows.shape[1], quant=quant,
                            shard_rows=shard_rows)
    w.append(rows.astype(np.float32))
    w.finalize()
    return SemanticStore(d)


def _fresh_setup(name="gqe", dim=8, seed=0, n_entities=60, **cfg_kw):
    """Per-test KG (live-write tests mutate it — never share tiny_kg)."""
    kg = generate_synthetic_kg(n_entities, 4, 300, seed=3)
    model = make_model(name, ModelConfig(dim=dim, gamma=6.0, **cfg_kw))
    params = model.init_params(jax.random.PRNGKey(seed), kg.n_entities,
                               kg.n_relations)
    return kg, model, params, PooledExecutor(model, b_max=64)


def _fresh_rows(kg, n, seed=0):
    """n triples guaranteed absent from kg (valid ids)."""
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        cand = np.stack([rng.integers(0, kg.n_entities, 4 * n),
                         rng.integers(0, kg.n_relations, 4 * n),
                         rng.integers(0, kg.n_entities, 4 * n)], axis=1)
        cand = cand[~kg.contains(cand)]
        out += [row for row in np.unique(cand, axis=0)]
    return np.array(out[:n])


def _payload(result):
    """Drop per-request timing fields; keep the served content."""
    return {k: v for k, v in result.items()
            if k not in ("latency_ms", "batch_size")}


def _queries(kg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    heads = kg.triples[rng.integers(0, len(kg), n), 0]
    rels = kg.triples[rng.integers(0, len(kg), n), 1]
    return [QueryInstance("1p", np.array([h]), np.array([r]))
            for h, r in zip(heads, rels)]


# ----------------------------------------------------------- store growth
@pytest.mark.parametrize("quant", ["fp32", "int8"])
def test_store_append_rows_roundtrip(tmp_path, rng, quant):
    """append_rows merges into a ragged last shard + spills fresh shards;
    OLD rows stay bitwise what the store already served for them."""
    base = rng.normal(size=(10, 8)).astype(np.float32)   # 2 full + 1 ragged
    extra = rng.normal(size=(9, 8)).astype(np.float32)
    store = _store(tmp_path, base, quant=quant, name=quant)
    before = store.read_rows(np.arange(10))
    got = store.append_rows(extra)
    assert got == range(10, 19)
    assert store.n_rows == 19
    # uniform geometry: every shard but the last holds exactly shard_rows
    reopened = SemanticStore(str(tmp_path / quant))
    assert reopened.n_rows == 19
    np.testing.assert_array_equal(store.read_rows(np.arange(10)), before)
    np.testing.assert_array_equal(reopened.read_rows(np.arange(10)), before)
    if quant == "fp32":
        np.testing.assert_array_equal(
            reopened.read_rows(np.arange(10, 19)), extra)
    else:
        got = reopened.read_rows(np.arange(10, 19))
        bound = np.abs(extra).max(axis=1, keepdims=True) / 254.0 + 1e-7
        assert (np.abs(got - extra) <= bound).all()


def test_store_append_crash_safe(tmp_path, rng, monkeypatch):
    """Crash between shard writes and the meta publish must leave the OLD
    store fully openable with its old rows bitwise intact."""
    import repro.semantic.store as store_mod

    base = rng.normal(size=(10, 8)).astype(np.float32)
    store = _store(tmp_path, base, name="crash")
    before = store.read_rows(np.arange(10))
    real = store_mod._write_atomic

    def boom(path, payload):
        if path.endswith("meta.json"):
            raise OSError("simulated crash before meta publish")
        real(path, payload)

    monkeypatch.setattr(store_mod, "_write_atomic", boom)
    with pytest.raises(OSError, match="simulated crash"):
        store.append_rows(rng.normal(size=(7, 8)).astype(np.float32))
    monkeypatch.setattr(store_mod, "_write_atomic", real)
    reopened = SemanticStore(str(tmp_path / "crash"))
    assert reopened.n_rows == 10          # append never became visible
    np.testing.assert_array_equal(reopened.read_rows(np.arange(10)), before)
    # and the surviving in-memory store still works + can retry the append
    assert store.n_rows == 10
    store.append_rows(rng.normal(size=(7, 8)).astype(np.float32))
    assert SemanticStore(str(tmp_path / "crash")).n_rows == 17


# ----------------------------------------------------------- params growth
def test_grow_entity_rows_claims_padding_first():
    model = make_model("gqe", ModelConfig(dim=8, entity_pad=8))
    params = model.init_params(jax.random.PRNGKey(0), 10, 4)
    assert params["entity"].shape[0] == 16  # padded
    ent = params["entity"]
    grown = grow_entity_rows(model, params, 3)
    assert model.n_entities == 13
    assert grown["entity"] is ent           # pad rows claimed, no realloc
    grown2 = grow_entity_rows(model, grown, 5)  # 18 > 16 -> realloc to 24
    assert model.n_entities == 18
    assert grown2["entity"].shape[0] == 24
    np.testing.assert_array_equal(np.asarray(grown2["entity"][:16]),
                                  np.asarray(ent))


def test_grow_entity_rows_sem_table():
    model = make_model("gqe", ModelConfig(dim=8, semantic_dim=4))
    table = np.arange(10 * 4, dtype=np.float32).reshape(10, 4)
    params = model.init_params(jax.random.PRNGKey(0), 10, 4,
                               semantic_table=table)
    with pytest.raises(ValueError, match="sem_rows"):
        grow_entity_rows(model, params, 2)
    new_rows = np.full((2, 4), 7.0, np.float32)
    grown = grow_entity_rows(model, params, 2, sem_rows=new_rows)
    np.testing.assert_array_equal(np.asarray(grown["sem_table"][:10]), table)
    np.testing.assert_array_equal(np.asarray(grown["sem_table"][10:12]),
                                  new_rows)


def test_grow_entity_rows_rejects_hot_set_layout():
    model = make_model("gqe", ModelConfig(dim=8))
    model.n_entities = 10
    params = {"entity": np.zeros((10, 8), np.float32),
              "sem_slot": np.zeros(10, np.int32)}
    with pytest.raises(NotImplementedError, match="hot set"):
        grow_entity_rows(model, params, 2)


# --------------------------------------------------- staleness-bounded serving
def test_stale_pin_is_shed_with_typed_error():
    kg, model, params, ex = _fresh_setup()
    cfg = ServingConfig(max_batch=8, max_wait_ms=5.0, top_k=5,
                        max_staleness_versions=1)
    with ServingEngine(model, params, executor=ex, cfg=cfg, kg=kg) as eng:
        q = _queries(kg, 1)[0]
        assert eng.submit(q, pin_version=0).result(timeout=30)["pattern"] == "1p"
        for row in _fresh_rows(kg, 2):          # two separate version bumps
            kg.add_triples(row[None])
        assert eng.graph_version == 2
        with pytest.raises(StaleVersionError) as ei:
            eng.submit(q, pin_version=0)
        assert (ei.value.pinned, ei.value.current, ei.value.bound) == (0, 2, 1)
        eng.submit(q, pin_version=1).result(timeout=30)  # within bound: served
        with pytest.raises(ValueError, match="unknown graph version"):
            eng.submit(q, pin_version=99)
        st = eng.stats()
    assert st["stale_sheds"] == 1 and st["failures"] == 0
    assert st["graph_version"] == 2
    assert st["version_lag_served"] == {0: 1, 1: 1}


def test_pin_version_requires_kg(tiny_kg):
    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    cfg = ServingConfig(max_batch=4, max_wait_ms=5.0)
    with ServingEngine(model, params, executor=PooledExecutor(model, b_max=64),
                       cfg=cfg) as eng:
        with pytest.raises(ValueError, match="live graph"):
            eng.submit(_queries(tiny_kg, 1)[0], pin_version=0)


def test_pinned_replay_bit_identical_through_writes():
    """A pin at version v must keep serving the v-era params verbatim while
    writes + param updates land — bitwise equal to the offline oracle run
    on the admitted snapshot's params."""
    kg, model, params, ex = _fresh_setup()
    cfg = ServingConfig(max_batch=8, max_wait_ms=5.0, top_k=5,
                        max_staleness_versions=4)
    qs = _queries(kg, 6)
    with ServingEngine(model, params, executor=ex, cfg=cfg, kg=kg) as eng:
        first = [_payload(eng.submit(q, pin_version=0).result(timeout=30))
                 for q in qs]
        # graph write + a params publish (as online training would do)
        kg.add_triples(np.array([[2, 0, 3], [2, 1, 4]]))
        bumped = dict(eng.params)
        bumped["entity"] = eng.params["entity"] * 1.5
        eng.update_params(bumped)
        unpinned = [_payload(eng.submit(q).result(timeout=30)) for q in qs]
        replay = [_payload(eng.submit(q, pin_version=0).result(timeout=30))
                  for q in qs]
    assert replay == first                      # pinned replay is frozen
    assert unpinned != first                    # fresh params actually differ
    oracle, _ = serve_batch(model, params, PooledExecutor(model, b_max=64),
                            qs, top_k=5)
    for got, want in zip(first, oracle):
        assert got == _payload(want)


# ------------------------------------------------------------------ LiveNGDB
def test_live_ngdb_write_burst_serving_continuity():
    kg, model, params, ex = _fresh_setup()
    cfg = ServingConfig(max_batch=8, max_wait_ms=2.0, top_k=5,
                        max_staleness_versions=8)
    qs = _queries(kg, 4)
    with ServingEngine(model, params, executor=ex, cfg=cfg, kg=kg) as eng:
        with LiveNGDB(model, kg, eng, finetune_steps=2, seed=0) as live:
            futures = []
            for k in range(6):
                futures += [eng.submit(q) for q in qs]
                r = live.write(np.array([[k, 0, (k + 7) % kg.n_entities],
                                         [k, 1, (k + 9) % kg.n_entities]]))
                assert isinstance(r, WriteReceipt)
            for f in futures:
                assert f.result(timeout=60)["pattern"] == "1p"
            live.flush()
            n_fresh = sum(1 for r in live.receipts if r.n_written)
            assert live.finetunes_done == n_fresh > 0
            # duplicate burst: no version bump, nothing enqueued
            v = kg.graph_version
            done = live.finetunes_done
            prior = next(r for r in live.receipts if r.n_written)
            r = live.write(prior.fresh_triples)
            assert r.n_written == 0 and kg.graph_version == v
            live.flush()
            assert live.finetunes_done == done
            st = eng.stats()
    assert st["failures"] == 0 and st["stale_sheds"] == 0
    assert st["graph_version"] == kg.graph_version


def test_live_ngdb_entity_growth_end_to_end():
    kg, model, params, ex = _fresh_setup()
    n0 = kg.n_entities
    cfg = ServingConfig(max_batch=8, max_wait_ms=2.0, top_k=5,
                        max_staleness_versions=8)
    with ServingEngine(model, params, executor=ex, cfg=cfg, kg=kg) as eng:
        with LiveNGDB(model, kg, eng, finetune_steps=2) as live:
            r = live.write(np.array([[n0, 0, 1], [n0 + 1, 1, n0]]),
                           n_new_entities=2)
            assert r.n_new_entities == 2 and r.n_written == 2
            assert kg.n_entities == model.n_entities == n0 + 2
            live.flush()
            # the new ids are servable immediately
            q = QueryInstance("1p", np.array([n0]), np.array([0]))
            assert eng.submit(q).result(timeout=30)["anchors"] == [n0]


def test_background_finetune_matches_sync_rerun():
    """The maintenance thread's fine-tune is a pure function of
    (params, triples, seed): a synchronous rerun from the recorded inputs
    reproduces the served params bitwise."""
    kg, model, params, ex = _fresh_setup()
    cfg = ServingConfig(max_batch=8, max_wait_ms=2.0,
                        max_staleness_versions=8)
    burst = _fresh_rows(kg, 3)
    with ServingEngine(model, params, executor=ex, cfg=cfg, kg=kg) as eng:
        with LiveNGDB(model, kg, eng, finetune_steps=3, seed=11) as live:
            r = live.write(burst)
            assert r.n_written == 3
            live.flush()
            served = eng.params
        sync, losses = incremental_finetune(
            model, params, r.fresh_triples, steps=3, lr=live.finetune_lr,
            n_negatives=live.n_negatives, seed=11 + r.graph_version)
    assert set(served) == set(sync)
    for k in served:
        np.testing.assert_array_equal(np.asarray(served[k]),
                                      np.asarray(sync[k]))
    assert len(losses) == 3 and all(np.isfinite(losses))


def test_incremental_finetune_deterministic_and_learns(tiny_kg):
    model = make_model("gqe", ModelConfig(dim=16, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    burst = tiny_kg.triples[:12]
    a, la = incremental_finetune(model, params, burst, steps=8, lr=1e-2,
                                 seed=4)
    b, lb = incremental_finetune(model, params, burst, steps=8, lr=1e-2,
                                 seed=4)
    assert la == lb
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert la[-1] < la[0]   # the touched neighborhood actually improves


def test_engine_rejects_kg_with_sem_cache(tiny_kg):
    """Device hot-set staging mutates params in place per batch — that is
    incompatible with version-pinned replay, so the combination is refused
    up front."""
    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    kg = KnowledgeGraph(4, 2, np.array([[0, 0, 1]]))
    with pytest.raises(ValueError, match="sem_cache"):
        ServingEngine(model, params, executor=PooledExecutor(model, b_max=64),
                      cfg=ServingConfig(), kg=kg, sem_cache=object(),
                      sem_rows_fn=lambda ids: ids)
