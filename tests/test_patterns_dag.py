import numpy as np
import pytest

from repro.core import (
    OpType,
    PATTERN_NAMES,
    TEMPLATES,
    QueryInstance,
    answer_query,
    build_batched_dag,
)


def test_fourteen_patterns():
    assert len(TEMPLATES) == 14
    assert set(PATTERN_NAMES) == {
        "1p", "2p", "3p", "2i", "3i", "pi", "ip", "2u", "up",
        "2in", "3in", "pin", "pni", "inp",
    }


def test_templates_well_formed():
    for name, tpl in TEMPLATES.items():
        for i, node in enumerate(tpl.nodes):
            for j in node.inputs:
                assert j < i, f"{name}: forward reference"
            if node.op == OpType.EMBED:
                assert not node.inputs
            elif node.op in (OpType.PROJECT, OpType.NEGATE):
                assert len(node.inputs) == 1
            else:
                assert len(node.inputs) >= 2
        # negation only ever feeds intersection in these patterns
        for i, node in enumerate(tpl.nodes):
            if node.op == OpType.NEGATE:
                consumers = [
                    m for m in tpl.nodes if i in m.inputs
                ]
                assert all(c.op == OpType.INTERSECT for c in consumers)


def test_answer_query_1p(tiny_kg):
    q = QueryInstance("1p", np.array([5]), np.array([1]))
    assert answer_query(tiny_kg, q) == set(tiny_kg.neighbors(5, 1).tolist())


def test_answer_query_2i_bruteforce(tiny_kg):
    q = QueryInstance("2i", np.array([3, 7]), np.array([0, 1]))
    expected = set(tiny_kg.neighbors(3, 0).tolist()) & set(
        tiny_kg.neighbors(7, 1).tolist()
    )
    assert answer_query(tiny_kg, q) == expected


def test_answer_query_2in(tiny_kg):
    q = QueryInstance("2in", np.array([3, 7]), np.array([0, 1]))
    expected = set(tiny_kg.neighbors(3, 0).tolist()) - set(
        tiny_kg.neighbors(7, 1).tolist()
    )
    assert answer_query(tiny_kg, q) == expected


def test_answer_query_up(tiny_kg):
    q = QueryInstance("up", np.array([3, 7]), np.array([0, 1, 2]))
    u = set(tiny_kg.neighbors(3, 0).tolist()) | set(tiny_kg.neighbors(7, 1).tolist())
    expected = set(
        tiny_kg.neighbors_of_set(np.fromiter(u, dtype=np.int64), 2).tolist()
    )
    assert answer_query(tiny_kg, q) == expected


def test_dag_merge_counts(mixed_queries):
    queries = [b.query for b in mixed_queries]
    dag = build_batched_dag(queries)
    expected_nodes = sum(len(TEMPLATES[q.pattern].nodes) for q in queries)
    assert dag.n_nodes == expected_nodes
    assert dag.n_queries == len(queries)
    # anchors/relations wired in template order
    for qi, q in enumerate(queries):
        mask = dag.query_id == qi
        anchors = dag.anchor[mask]
        assert np.array_equal(anchors[anchors >= 0], q.anchors)
        rels = dag.rel[mask]
        assert np.array_equal(rels[rels >= 0], q.relations)


def test_structure_key_order_invariant(mixed_queries):
    queries = [b.query for b in mixed_queries]
    k1 = build_batched_dag(queries).structure_key()
    k2 = build_batched_dag(list(reversed(queries))).structure_key()
    assert k1 == k2
