"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import pytest

from repro.data import generate_synthetic_kg, split_kg
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.semantic import PTEConfig, StubPTE, precompute_semantic_table
from repro.training import AdamConfig, NGDBTrainer, TrainConfig, evaluate


@pytest.fixture(scope="module")
def setup():
    full = generate_synthetic_kg(250, 10, 3000, seed=2)
    train, _, _ = split_kg(full, seed=2)
    return train, full


def test_end_to_end_training_improves_mrr(setup):
    """The full loop (online sampling -> operator batching -> vectorized loss
    -> Adam) must beat an untrained model on filtered MRR."""
    train_kg, full_kg = setup
    model = make_model("q2b", ModelConfig(dim=24, gamma=6.0))
    cfg = TrainConfig(batch_size=48, n_negatives=16, b_max=64, prefetch=0,
                      patterns=("1p", "2p", "2i"), adam=AdamConfig(lr=5e-3))
    tr = NGDBTrainer(model, train_kg, cfg)
    qs = [b.query for b in OnlineSampler(train_kg, patterns=("1p", "2i"),
                                         seed=11).sample_batch(24)]
    before = evaluate(model, tr.params, tr.executor, full_kg, qs)["mrr"]
    tr.train(30, log_every=0)
    after = evaluate(model, tr.params, tr.executor, full_kg, qs)["mrr"]
    assert after > before, (before, after)


def test_semantic_augmentation_runs_inference_free(setup):
    """Decoupled path: after precompute the PTE is unloaded; training still
    works and H_sem receives no gradient updates."""
    train_kg, _ = setup
    pte = StubPTE(PTEConfig(d_l=48, n_layers=1, d_model=32))
    table = precompute_semantic_table(train_kg, pte, batch_size=128)
    assert pte.unloaded
    model = make_model("gqe", ModelConfig(dim=16, semantic_dim=48))
    cfg = TrainConfig(batch_size=16, n_negatives=8, b_max=32, prefetch=0,
                      patterns=("1p", "2i"), adam=AdamConfig(lr=3e-3))
    tr = NGDBTrainer(model, train_kg, cfg, semantic_table=table)
    sem_before = np.asarray(tr.params["sem_table"]).copy()
    tr.train(5, log_every=0)
    np.testing.assert_array_equal(np.asarray(tr.params["sem_table"]), sem_before)


def test_adaptive_sampling_tracks_shift(setup):
    """Steered-workload protocol (Fig. 9, miniaturized): after a difficulty
    spike on one pattern, the adaptive distribution allocates it more mass."""
    train_kg, _ = setup
    model = make_model("gqe", ModelConfig(dim=16, gamma=6.0))
    cfg = TrainConfig(batch_size=24, n_negatives=8, b_max=64, prefetch=0,
                      patterns=("1p", "3p"), adaptive=True,
                      adam=AdamConfig(lr=3e-3))
    tr = NGDBTrainer(model, train_kg, cfg)
    for _ in range(6):
        tr.train_step()
    d = tr.adaptive.distribution()
    # 3p is structurally harder than 1p on a sparse synthetic graph
    assert d["3p"] >= d["1p"] * 0.8  # never starved; usually strictly larger
