"""The central systems-correctness property: operator-level batching must be
SEMANTICALLY INVISIBLE — pooled execution, query-level execution and naive
per-query execution produce identical query embeddings."""
import jax
import numpy as np
import pytest

from repro.core import PooledExecutor, QueryLevelExecutor
from repro.models import ModelConfig, make_model, model_names


def _naive_encode(model, params, q):
    """Reference: execute the template directly, one query at a time."""
    import jax.numpy as jnp

    from repro.core import TEMPLATES, OpType

    tpl = TEMPLATES[q.pattern]
    vals = []
    a_i = r_i = 0
    for node in tpl.nodes:
        if node.op == OpType.EMBED:
            v = model.embed(params, jnp.array([q.anchors[a_i]]))
            a_i += 1
        elif node.op == OpType.PROJECT:
            v = model.project(params, vals[node.inputs[0]], jnp.array([q.relations[r_i]]))
            r_i += 1
        elif node.op == OpType.NEGATE:
            v = model.negate(params, vals[node.inputs[0]])
        else:
            stack = jnp.stack([vals[j] for j in node.inputs], axis=1)
            v = (model.intersect if node.op == OpType.INTERSECT else model.union)(
                params, stack
            )
        vals.append(v)
    return vals[tpl.answer_node][0]


@pytest.mark.parametrize("name", model_names())
def test_pooled_equals_naive(name, tiny_kg, mixed_queries):
    model = make_model(name, ModelConfig(dim=8))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    queries = [b.query for b in mixed_queries][:10]
    pooled = PooledExecutor(model, b_max=16)
    out = np.asarray(pooled.encode(params, queries))
    for i, q in enumerate(queries):
        ref = np.asarray(_naive_encode(model, params, q))
        np.testing.assert_allclose(out[i], ref, rtol=2e-4, atol=2e-5)


def test_pooled_equals_query_level(tiny_kg, mixed_queries):
    model = make_model("q2b", ModelConfig(dim=8))
    params = model.init_params(jax.random.PRNGKey(1), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    queries = [b.query for b in mixed_queries]
    pooled = np.asarray(PooledExecutor(model, b_max=32).encode(params, queries))
    grouped = np.asarray(QueryLevelExecutor(model, b_max=32).encode(params, queries))
    np.testing.assert_allclose(pooled, grouped, rtol=2e-4, atol=2e-5)


def test_schedule_cache_reused(tiny_kg, mixed_queries):
    model = make_model("gqe", ModelConfig(dim=8))
    ex = PooledExecutor(model, b_max=32)
    queries = [b.query for b in mixed_queries]
    p1 = ex.prepare(queries)
    # same multiset, different order -> same schedule object, new bindings
    p2 = ex.prepare(list(reversed(queries)))
    assert p1.signature == p2.signature
    assert len(ex._sched_cache) == 1


def test_order_restored(tiny_kg, mixed_queries):
    """encode() must return states in the ORIGINAL query order."""
    model = make_model("gqe", ModelConfig(dim=8))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    ex = PooledExecutor(model, b_max=32)
    queries = [b.query for b in mixed_queries][:8]
    base = np.asarray(ex.encode(params, queries))
    perm = [3, 1, 0, 2, 7, 6, 5, 4]
    out = np.asarray(ex.encode(params, [queries[i] for i in perm]))
    np.testing.assert_allclose(out, base[perm], rtol=2e-4, atol=2e-5)
