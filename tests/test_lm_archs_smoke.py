"""Per-architecture smoke tests on REDUCED same-family configs (assignment):
one train step + one prefill + one decode on CPU, asserting shapes + no NaNs.
Plus the strong correctness check: prefill+decode logits == full-forward
logits at the same position."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.lm.model import forward, init_params, logits_fn
from repro.lm.steps import make_decode_step, make_prefill_step, make_train_step
from repro.training.optim import adam_init

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["embeddings"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.05,
                                          jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.is_encdec:
        batch["encoder_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)) * 0.05, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke(name):
    cfg = reduced_config(ARCHS[name])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    train = jax.jit(make_train_step(cfg))
    p2, o2, loss = train(params, adam_init(params), batch)
    assert np.isfinite(float(loss)), name
    # params actually moved
    moved = float(jnp.abs(p2["embed"] - params["embed"]).max())
    assert moved > 0 or cfg.frontend == "vision"

    caches, logits = jax.jit(make_prefill_step(cfg))(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name
    lg, caches2 = jax.jit(make_decode_step(cfg))(
        params, caches, jnp.zeros((B, 1), jnp.int32), jnp.int32(S))
    assert np.isfinite(np.asarray(lg, np.float32)).all(), name
    # cache pytree structure is stable across decode steps
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("name", ["qwen2-0.5b", "mamba2-1.3b", "mixtral-8x22b",
                                  "jamba-v0.1-52b"])
def test_prefill_decode_matches_forward(name):
    """decode(t | prefill(t<S)) must equal forward(t<=S) last-token logits.

    MoE archs get an ample capacity factor: token dropping depends on the
    whole batch competing for expert slots, so the dropped set legitimately
    differs between a 1-token decode and a full forward."""
    cfg = reduced_config(ARCHS[name])
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)

    # full forward over S+1 tokens
    hidden, _ = forward(params, cfg, tokens=toks)
    ref = logits_fn(params, cfg, hidden[:, -1:])

    # prefill S tokens, then decode token S
    batch = {"tokens": toks[:, :S]}
    if cfg.is_encdec:
        batch["encoder_frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                            jnp.bfloat16)
    caches, _ = jax.jit(make_prefill_step(cfg, cache_margin=8))(params, batch)
    got, _ = jax.jit(make_decode_step(cfg))(params, caches, toks[:, S:], jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=5e-1)  # bf16 accumulation tolerance


def test_param_counts_match_analytic():
    """config.param_count() must agree with the real parameter tree."""
    for name in ("qwen2-0.5b", "mamba2-1.3b", "mixtral-8x22b"):
        cfg = reduced_config(ARCHS[name])
        params = init_params(cfg, jax.random.PRNGKey(0))
        real = sum(x.size for x in jax.tree.leaves(params))
        # analytic excludes tiny norm/bias bookkeeping drift; keep it tight
        assert abs(real - cfg.param_count()) / real < 0.05, name


def test_full_configs_match_assignment():
    a = ARCHS["qwen2-72b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    g = ARCHS["grok-1-314b"]
    assert g.n_experts == 8 and g.top_k == 2 and g.d_ff == 32768
    j = ARCHS["jamba-v0.1-52b"]
    assert j.attn_every == 8 and j.n_experts == 16
    m = ARCHS["mamba2-1.3b"]
    assert m.ssm_state == 128 and m.n_heads == 0
    w = ARCHS["whisper-large-v3"]
    assert w.encoder_layers == 32 and w.encoder_seq == 1500
    x = ARCHS["mixtral-8x22b"]
    assert x.sliding_window > 0
