# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_kg():
    from repro.data import generate_synthetic_kg

    return generate_synthetic_kg(200, 10, 2400, seed=0)


@pytest.fixture(scope="session")
def mixed_queries(tiny_kg):
    """Mixed-pattern query batch with guaranteed-nonempty answers."""
    from repro.sampling import OnlineSampler

    sampler = OnlineSampler(tiny_kg, seed=0)
    return sampler.sample_batch(28)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
