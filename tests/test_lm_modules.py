import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lm.attention import (
    blockwise_attention,
    dense_attention,
    dense_chunked_attention,
    decode_attention,
)
from repro.lm.mamba2 import causal_conv, segsum, ssd_decode_step, ssd_scan
from repro.lm.modules import apply_rope, rms_norm
from repro.lm.moe import combine_from_experts, pack_by_expert


@pytest.fixture(scope="module")
def qkv(rng):
    b, s, h, kv, hd = 2, 64, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    return q, k, v


def _repeat_ref(q, k, v, causal=True, window=0):
    """Oracle: materialized-repeat GQA with explicit masks."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("window", [0, 24])
def test_attention_modes_agree(qkv, window):
    q, k, v = qkv
    ref = _repeat_ref(q, k, v, window=window)
    for fn in (dense_attention, blockwise_attention, dense_chunked_attention):
        kw = dict(causal=True, window=window)
        if fn is blockwise_attention:
            kw.update(q_chunk=16, kv_chunk=16)
        elif fn is dense_chunked_attention:
            kw.update(q_chunk=16)
        out = fn(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_decode_matches_dense_last_row(qkv):
    q, k, v = qkv
    s = q.shape[1]
    ref = _repeat_ref(q, k, v)[:, -1:]
    out = decode_attention(q[:, -1:], k, v, jnp.full((2,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_rope_preserves_inner_products(rng):
    """RoPE is a rotation: same relative offset => same <q,k>."""
    hd = 32
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    dots = []
    for base in (0, 17):
        qr = apply_rope(q, jnp.array([[base + 5]]), 10000.0)
        kr = apply_rope(k, jnp.array([[base]]), 10000.0)
        dots.append(float(jnp.sum(qr * kr)))
    assert abs(dots[0] - dots[1]) < 1e-3


def test_segsum():
    a = jnp.array([1.0, 2.0, 3.0])
    L = segsum(a[None])[0]
    assert L[0, 0] == 0.0
    assert float(L[2, 0]) == 5.0   # sum of a[1:3]
    assert np.isneginf(np.asarray(L)[0, 2])


def _ssd_naive(x, dtA, Bm, Cm):
    """Token-by-token recurrence oracle."""
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    state = jnp.zeros((b, h, p, n))
    ys = []
    for i in range(t):
        y, state = ssd_decode_step(state, x[:, i], dtA[:, i], Bm[:, i], Cm[:, i])
        ys.append(y)
    return jnp.stack(ys, axis=1)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_equals_recurrence(chunk, rng):
    b, t, h, p, n = 2, 16, 4, 8, 8
    x = jnp.asarray(rng.normal(size=(b, t, h, p)), jnp.float32)
    dtA = -jnp.abs(jnp.asarray(rng.normal(size=(b, t, h)), jnp.float32)) * 0.5
    Bm = jnp.asarray(rng.normal(size=(b, t, 1, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, t, 1, n)), jnp.float32)
    y, final = ssd_scan(x, dtA, Bm, Cm, chunk)
    y_ref = _ssd_naive(x, dtA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3)


def test_ssd_state_handoff(rng):
    """prefill-then-decode == one long prefill (state continuity)."""
    b, t, h, p, n = 1, 12, 2, 4, 4
    x = jnp.asarray(rng.normal(size=(b, t, h, p)), jnp.float32)
    dtA = -jnp.abs(jnp.asarray(rng.normal(size=(b, t, h)), jnp.float32)) * 0.3
    Bm = jnp.asarray(rng.normal(size=(b, t, 1, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, t, 1, n)), jnp.float32)
    y_full, _ = ssd_scan(x, dtA, Bm, Cm, chunk=4)
    y_pre, state = ssd_scan(x[:, :8], dtA[:, :8], Bm[:, :8], Cm[:, :8], chunk=4)
    ys = [y_pre]
    for i in range(8, 12):
        y, state = ssd_decode_step(state, x[:, i], dtA[:, i], Bm[:, i], Cm[:, i])
        ys.append(y[:, None])
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full),
                               rtol=1e-3, atol=1e-3)


def test_causal_conv_matches_lax(rng):
    b, t, c, k = 2, 10, 6, 4
    x = jnp.asarray(rng.normal(size=(b, t, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, c)), jnp.float32)
    bias = jnp.zeros((c,))
    y, _ = causal_conv(x, w, bias)
    ref = jax.lax.conv_general_dilated(
        x.transpose(0, 2, 1)[:, :, None, :], w.T[:, None, None, :],
        (1, 1), [(0, 0), (k - 1, 0)], feature_group_count=c,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[:, :, 0, :].transpose(0, 2, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_causal_conv_streaming(rng):
    """conv(full) == conv(prefix) + streamed conv with carried state."""
    b, t, c, k = 1, 9, 4, 4
    x = jnp.asarray(rng.normal(size=(b, t, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, c)), jnp.float32)
    bias = jnp.zeros((c,))
    full, _ = causal_conv(x, w, bias)
    y1, st = causal_conv(x[:, :5], w, bias)
    outs = [y1]
    for i in range(5, t):
        y, st = causal_conv(x[:, i : i + 1], w, bias, st)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


def test_moe_pack_combine_roundtrip(rng):
    t, d, e, k, cap = 32, 8, 4, 2, 32  # capacity ample: nothing dropped
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    eidx = jnp.asarray(rng.integers(0, e, (t, k)))
    gates = jnp.ones((t, k)) / k
    packed, meta = pack_by_expert(x, eidx, gates, e, cap)
    # identity expert: combine should reproduce sum_k gate*x = x
    y = combine_from_experts(packed, meta, t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops(rng):
    t, d, e, k = 16, 4, 2, 1
    x = jnp.ones((t, d))
    eidx = jnp.zeros((t, 1), jnp.int32)  # everyone wants expert 0
    gates = jnp.ones((t, 1))
    packed, meta = pack_by_expert(x, eidx, gates, e, capacity=4)
    y = combine_from_experts(packed, meta, t)
    kept = float(jnp.sum(y) / d)
    assert kept == 4.0  # Max-Fillness at the fill limit: overflow dropped


def test_rms_norm():
    x = jnp.array([[3.0, 4.0]])
    y = rms_norm(x, jnp.ones(2), eps=0.0)
    np.testing.assert_allclose(float(jnp.mean(y**2)), 1.0, rtol=1e-5)
