import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, make_model
from repro.training import (
    AdamConfig,
    NGDBTrainer,
    TrainConfig,
    adam_init,
    adam_update,
    evaluate,
    global_norm,
    negative_sampling_loss,
)


def test_adam_moves_params():
    params = {"w": jnp.ones((4,)), "sem_table": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,)), "sem_table": jnp.ones((4,))}
    state = adam_init(params)
    new, state = adam_update(grads, state, params, AdamConfig(lr=0.1))
    assert not np.allclose(np.asarray(new["w"]), 1.0)
    # frozen buffer (H_sem) must not move
    np.testing.assert_array_equal(np.asarray(new["sem_table"]), 1.0)
    assert int(state["step"]) == 1


def test_grad_clip():
    params = {"w": jnp.zeros((3,))}
    grads = {"w": jnp.full((3,), 100.0)}
    state = adam_init(params)
    cfg = AdamConfig(lr=1.0, clip_norm=1.0)
    new, _ = adam_update(grads, state, params, cfg)
    # clipped direction identical, magnitude bounded by Adam normalization
    assert np.isfinite(np.asarray(new["w"])).all()
    assert float(global_norm(grads)) > 1.0


def test_loss_prefers_positives(tiny_kg):
    model = make_model("gqe", ModelConfig(dim=8))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    q = model.embed(params, jnp.array([3, 4]))
    pos = jnp.array([3, 4])
    neg = jnp.array([[9, 10], [11, 12]])
    loss, per = negative_sampling_loss(model, params, q, pos, neg)
    assert per.shape == (2,)
    assert np.isfinite(float(loss))


def test_trainer_loss_decreases(tiny_kg):
    model = make_model("gqe", ModelConfig(dim=16, gamma=6.0))
    cfg = TrainConfig(batch_size=32, n_negatives=8, b_max=64, prefetch=0,
                      patterns=("1p", "2p", "2i"),
                      adam=AdamConfig(lr=5e-3))
    tr = NGDBTrainer(model, tiny_kg, cfg)
    recs = tr.train(12, log_every=0)
    first = np.mean([r["loss"] for r in recs[:3]])
    last = np.mean([r["loss"] for r in recs[-3:]])
    assert last < first


def test_query_level_baseline_runs(tiny_kg):
    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    cfg = TrainConfig(batch_size=16, n_negatives=4, b_max=32, prefetch=0,
                      patterns=("1p", "2i"), executor="query_level",
                      adam=AdamConfig(lr=1e-3))
    tr = NGDBTrainer(model, tiny_kg, cfg)
    rec = tr.train_step()
    assert np.isfinite(rec["loss"])


def test_evaluate_metrics(tiny_kg):
    from repro.sampling import OnlineSampler

    model = make_model("gqe", ModelConfig(dim=8, gamma=6.0))
    cfg = TrainConfig(batch_size=16, n_negatives=4, b_max=32, prefetch=0,
                      patterns=("1p",), adam=AdamConfig(lr=5e-3))
    tr = NGDBTrainer(model, tiny_kg, cfg)
    qs = [b.query for b in OnlineSampler(tiny_kg, patterns=("1p",), seed=9).sample_batch(12)]
    m = evaluate(model, tr.params, tr.executor, tiny_kg, qs)
    assert 0.0 <= m["mrr"] <= 1.0
    assert m["hits@10"] >= m["hits@1"]


def test_filtered_ranks():
    from repro.training import filtered_ranks

    scores = np.array([0.9, 0.8, 0.7, 0.6])
    # answers are items 0 and 1 -> both get filtered rank 1,1
    ranks = filtered_ranks(scores, np.array([0, 1]))
    assert ranks.tolist() == [1, 1]
    ranks = filtered_ranks(scores, np.array([3]))
    assert ranks.tolist() == [4]
