import numpy as np
import pytest

from repro.data import KnowledgeGraph, generate_synthetic_kg, split_kg, TABLE4


def test_dedup_and_sorted():
    tri = np.array([[0, 0, 1], [0, 0, 1], [1, 0, 2], [0, 1, 2]])
    kg = KnowledgeGraph(3, 2, tri)
    assert len(kg) == 3


def test_neighbors():
    tri = np.array([[0, 0, 1], [0, 0, 2], [0, 1, 2], [1, 0, 0]])
    kg = KnowledgeGraph(3, 2, tri)
    assert set(kg.neighbors(0, 0).tolist()) == {1, 2}
    assert set(kg.neighbors(0, 1).tolist()) == {2}
    assert kg.neighbors(2, 0).size == 0


def test_neighbors_of_set():
    tri = np.array([[0, 0, 1], [1, 0, 2], [2, 0, 0]])
    kg = KnowledgeGraph(3, 1, tri)
    out = kg.neighbors_of_set(np.array([0, 1]), 0)
    assert set(out.tolist()) == {1, 2}


def test_incoming_csr():
    tri = np.array([[0, 0, 2], [1, 1, 2], [2, 0, 1]])
    kg = KnowledgeGraph(3, 2, tri)
    indptr, rels, heads = kg.incoming_by_tail
    lo, hi = indptr[2], indptr[3]
    assert sorted(heads[lo:hi].tolist()) == [0, 1]


def test_generator_deterministic():
    a = generate_synthetic_kg(100, 5, 500, seed=7)
    b = generate_synthetic_kg(100, 5, 500, seed=7)
    assert np.array_equal(a.triples, b.triples)
    assert len(a) == 500


def test_generator_power_law(tiny_kg):
    deg = tiny_kg.degree
    # hubby: top decile should hold well over its proportional share
    top = np.sort(deg)[-len(deg) // 10 :].sum()
    assert top > 0.3 * deg.sum()


def test_split_disjoint():
    kg = generate_synthetic_kg(100, 5, 1000, seed=1)
    train, valid, test = split_kg(kg, 0.1, 0.1, seed=0)
    assert len(train) + len(valid) + len(test) == len(kg)
    tr = {tuple(t) for t in train.triples.tolist()}
    for t in valid.tolist() + test.tolist():
        assert tuple(t) not in tr


def test_table4_statistics():
    assert TABLE4["ogbl-wikikg2"].n_entities == 2_500_604
    assert TABLE4["ATLAS-Wiki-Triple-4M"].n_relations == 512_064
    assert TABLE4["FB15k"].n_total == 592_213

# ---------------------------------------------------------------------------
# Live-write regression suite (DESIGN.md §LiveStore): the four write-path
# bugs plus the snapshot/version surface they unblock.
# ---------------------------------------------------------------------------

def test_dedup_survives_int64_scale():
    """Regression: the old composite dedup key (h*R + r)*E + t overflowed
    int64 just above ATLAS-Wiki-Triple-4M scale, wrapping negative and
    corrupting both dedup and the CSR sort order. lexsort over the columns
    has no composite key to overflow."""
    E, R = 5_000_000, TABLE4["ATLAS-Wiki-Triple-4M"].n_relations
    # Old key for (E-1, R-1, E-1): ((E-1)*R + (R-1))*E + E-1 ≈ 1.28e19
    # > INT64_MAX ≈ 9.22e18 — wraps under the old scheme.
    assert (np.float64(E - 1) * R + (R - 1)) * E + (E - 1) > np.iinfo(np.int64).max
    tri = np.array([
        [E - 1, R - 1, E - 1],
        [E - 1, R - 1, E - 1],   # duplicate of the wrap-prone row
        [E - 1, R - 1, 0],
        [0, 0, 0],
        [0, 0, E - 1],
    ])
    kg = KnowledgeGraph(E, R, tri)
    assert len(kg) == 4
    assert set(kg.neighbors(0, 0).tolist()) == {0, E - 1}
    assert set(kg.neighbors(E - 1, R - 1).tolist()) == {0, E - 1}
    # CSR order: hr strictly non-decreasing, tails sorted within spans.
    hr = kg.triples[:, 0] * R + kg.triples[:, 1]
    assert np.all(np.diff(hr) >= 0)


def test_noop_write_is_free():
    """Regression: add_triples([]) (or an all-duplicates write) used to
    rebuild the CSR, bump the version and flush every listening cache."""
    from repro.core.matcache import MaterializedSubqueryCache

    kg = KnowledgeGraph(4, 2, np.array([[0, 0, 1], [1, 1, 2]]))
    cache = MaterializedSubqueryCache(8)
    cache.watch_kg(kg)
    fired = []

    def listener(reason):
        fired.append(reason)

    kg.add_invalidation_listener(listener)
    cache.insert([("q", 1)], np.ones((1, 4), np.float32))
    assert cache.stats()["live"] == 1
    v0 = kg.version
    kg.add_triples(np.empty((0, 3), np.int64))
    kg.add_triples(np.array([[0, 0, 1]]))               # pure duplicate
    kg.add_triples(np.array([[0, 0, 1], [1, 1, 2]]))    # all duplicates
    assert kg.version == v0
    assert fired == []
    assert cache.stats()["live"] == 1  # warm rows survived the no-ops
    # ...and a real write still invalidates.
    assert len(kg.insert_triples(np.array([[2, 0, 3]]))) == 1
    assert kg.version == v0 + 1
    assert fired == ["kg_write"]
    assert cache.stats()["live"] == 0


def test_failed_write_does_not_bump():
    kg = KnowledgeGraph(4, 2, np.array([[0, 0, 1]]))
    v0 = kg.version
    with pytest.raises(ValueError):
        kg.add_triples(np.array([[9, 0, 1]]))
    with pytest.raises(ValueError):
        kg.add_triples(np.array([[0, 5, 1]]))
    assert kg.version == v0


def test_listener_weakref_no_leak():
    """Regression: listeners were strong refs — a dropped cache stayed
    alive (and kept being notified) forever."""
    import gc
    import weakref

    from repro.core.matcache import MaterializedSubqueryCache

    kg = KnowledgeGraph(4, 2, np.array([[0, 0, 1]]))
    cache = MaterializedSubqueryCache(8)
    cache.watch_kg(kg)
    probe = weakref.ref(cache)
    assert kg.live_listener_count() == 1
    del cache
    gc.collect()
    assert probe() is None          # the KG must not keep the cache alive
    kg.add_triples(np.array([[1, 1, 2]]))  # dead listener must not break writes
    assert kg.live_listener_count() == 0


def test_concurrent_reads_never_torn():
    """Regression: _build reassigned triples/_hr/_tails one-by-one, so a
    lock-free reader could pair the new index with old tails. The adjacency
    now publishes as one immutable tuple; readers either see the whole old
    build or the whole new one."""
    import threading

    kg = KnowledgeGraph(4096, 1, np.array([[0, 0, 1]]))
    stop = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            tails = kg.neighbors(0, 0)
            got = set(tails.tolist())
            n = len(got)
            want = set(range(1, n + 1))
            if got != want:                     # torn read: mixed builds
                errors.append((sorted(got), n))
                return

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    # Writer: monotone frontier — after write k, neighbors(0,0) is exactly
    # {1..k+1}; any other observed set means a torn read.
    for k in range(2, 600):
        kg.add_triples(np.array([[0, 0, k]]))
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:3]


def test_snapshot_pinning_and_retention():
    from repro.data import KGSnapshot, SnapshotUnavailable

    kg = KnowledgeGraph(10, 2, np.array([[0, 0, 1]]), snapshot_retention=3)
    s0 = kg.snapshot()
    assert isinstance(s0, KGSnapshot)
    assert s0.graph_version == kg.graph_version == 0
    kg.add_triples(np.array([[0, 0, 2]]))
    kg.add_triples(np.array([[0, 0, 3]]))
    # Pinned view replays the admitted state regardless of later writes.
    assert set(s0.neighbors(0, 0).tolist()) == {1}
    assert set(kg.snapshot_at(1).neighbors(0, 0).tolist()) == {1, 2}
    assert set(kg.neighbors(0, 0).tolist()) == {1, 2, 3}
    assert kg.retained_versions() == (0, 1, 2)
    kg.add_triples(np.array([[0, 0, 4]]))   # retention=3 evicts version 0
    assert kg.retained_versions() == (1, 2, 3)
    with pytest.raises(SnapshotUnavailable):
        kg.snapshot_at(0)
    # Snapshot arrays are shared, not copied: O(1) snapshots.
    assert kg.snapshot().triples is kg.triples


def test_add_entities():
    kg = KnowledgeGraph(4, 2, np.array([[0, 0, 1]]))
    fired = []

    def listener(reason):
        fired.append(reason)

    kg.add_invalidation_listener(listener)
    v0 = kg.graph_version
    assert kg.add_entities(0) == range(4, 4)
    assert kg.graph_version == v0           # zero-growth is a no-op
    ids = kg.add_entities(3)
    assert ids == range(4, 7)
    assert kg.n_entities == 7 and kg.graph_version == v0 + 1
    assert fired == ["entity_add"]
    kg.add_triples(np.array([[6, 1, 0]]))   # new ids usable immediately
    assert set(kg.neighbors(6, 1).tolist()) == {0}
    assert kg.out_degree.shape == (7,)      # degree views resized


def test_contains():
    kg = KnowledgeGraph(5, 2, np.array([[0, 0, 1], [0, 0, 3], [2, 1, 4]]))
    got = kg.contains(np.array(
        [[0, 0, 1], [0, 0, 2], [0, 0, 3], [2, 1, 4], [2, 0, 4], [4, 1, 2]]))
    assert got.tolist() == [True, False, True, True, False, False]
