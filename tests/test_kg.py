import numpy as np
import pytest

from repro.data import KnowledgeGraph, generate_synthetic_kg, split_kg, TABLE4


def test_dedup_and_sorted():
    tri = np.array([[0, 0, 1], [0, 0, 1], [1, 0, 2], [0, 1, 2]])
    kg = KnowledgeGraph(3, 2, tri)
    assert len(kg) == 3


def test_neighbors():
    tri = np.array([[0, 0, 1], [0, 0, 2], [0, 1, 2], [1, 0, 0]])
    kg = KnowledgeGraph(3, 2, tri)
    assert set(kg.neighbors(0, 0).tolist()) == {1, 2}
    assert set(kg.neighbors(0, 1).tolist()) == {2}
    assert kg.neighbors(2, 0).size == 0


def test_neighbors_of_set():
    tri = np.array([[0, 0, 1], [1, 0, 2], [2, 0, 0]])
    kg = KnowledgeGraph(3, 1, tri)
    out = kg.neighbors_of_set(np.array([0, 1]), 0)
    assert set(out.tolist()) == {1, 2}


def test_incoming_csr():
    tri = np.array([[0, 0, 2], [1, 1, 2], [2, 0, 1]])
    kg = KnowledgeGraph(3, 2, tri)
    indptr, rels, heads = kg.incoming_by_tail
    lo, hi = indptr[2], indptr[3]
    assert sorted(heads[lo:hi].tolist()) == [0, 1]


def test_generator_deterministic():
    a = generate_synthetic_kg(100, 5, 500, seed=7)
    b = generate_synthetic_kg(100, 5, 500, seed=7)
    assert np.array_equal(a.triples, b.triples)
    assert len(a) == 500


def test_generator_power_law(tiny_kg):
    deg = tiny_kg.degree
    # hubby: top decile should hold well over its proportional share
    top = np.sort(deg)[-len(deg) // 10 :].sum()
    assert top > 0.3 * deg.sum()


def test_split_disjoint():
    kg = generate_synthetic_kg(100, 5, 1000, seed=1)
    train, valid, test = split_kg(kg, 0.1, 0.1, seed=0)
    assert len(train) + len(valid) + len(test) == len(kg)
    tr = {tuple(t) for t in train.triples.tolist()}
    for t in valid.tolist() + test.tolist():
        assert tuple(t) not in tr


def test_table4_statistics():
    assert TABLE4["ogbl-wikikg2"].n_entities == 2_500_604
    assert TABLE4["ATLAS-Wiki-Triple-4M"].n_relations == 512_064
    assert TABLE4["FB15k"].n_total == 592_213
