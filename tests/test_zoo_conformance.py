"""Zoo-wide conformance matrix: every model family × every EFO pattern.

Every backbone in the zoo must serve every one of the 14 logical patterns:
encode + all-entity scoring produce finite, deterministic (bitwise
replayable) scores, and the hard patterns — negation and union — round-trip
through the continuous-batching engine with exactly the offline
``serve_batch`` top-k. This is the serving twin of the per-operator model
tests: it pins the full model-zoo × pattern surface the paper's Table 3
sweeps, so a regression in any one (family, pattern) cell fails by name.
"""
import jax
import numpy as np
import pytest

from repro.core import PATTERN_NAMES, PooledExecutor
from repro.core.patterns import NEGATION_PATTERNS, UNION_PATTERNS
from repro.launch.serve import serve_batch
from repro.models import ModelConfig, make_model, model_names
from repro.sampling import OnlineSampler
from repro.serving import (ServingConfig, ServingEngine,
                           check_against_offline, scorer_for)

DIM = 8


@pytest.fixture(scope="module", params=model_names())
def zoo_model(request, tiny_kg):
    """(model, params, executor) per family — module-scoped so the 14-pattern
    scan and the engine round-trip share one init + compile set."""
    model = make_model(request.param, ModelConfig(dim=DIM))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    return model, params, PooledExecutor(model, b_max=64)


def test_family_count():
    assert len(model_names()) == 6, model_names()
    assert len(PATTERN_NAMES) == 14, PATTERN_NAMES


def test_all_patterns_finite_and_deterministic(zoo_model, tiny_kg):
    """encode + score_all over all 14 patterns: finite everywhere, and a
    second pass reproduces the scores bit for bit."""
    model, params, ex = zoo_model
    sampler = OnlineSampler(tiny_kg, seed=5)
    scorer = scorer_for(model)
    for pattern in PATTERN_NAMES:
        queries = [sampler.sample(pattern).query for _ in range(2)]
        states = np.asarray(ex.encode(params, queries))
        assert np.isfinite(states).all(), (model.name, pattern)
        scores = np.asarray(scorer(params, ex.encode(params, queries)))
        assert scores.shape == (2, tiny_kg.n_entities), (model.name, pattern)
        assert np.isfinite(scores).all(), (model.name, pattern)
        replay = np.asarray(scorer(params, ex.encode(params, queries)))
        np.testing.assert_array_equal(scores, replay,
                                      err_msg=f"{model.name}/{pattern}")


def test_negation_union_engine_roundtrip(zoo_model, tiny_kg):
    """The hard patterns (negation + union) served through the async engine
    return exactly the offline serve_batch top-k on the same micro-batch
    compositions — for every model family."""
    model, params, ex = zoo_model
    sampler = OnlineSampler(tiny_kg, seed=9)
    patterns = list(NEGATION_PATTERNS) + list(UNION_PATTERNS)
    queries = [sampler.sample(p).query for p in patterns]
    cfg = ServingConfig(max_batch=8, max_wait_ms=50.0, top_k=10,
                        record_batches=True)
    with ServingEngine(model, params, executor=ex, cfg=cfg) as engine:
        futs = engine.submit_many(queries)
        results = [f.result(timeout=120) for f in futs]
        log = list(engine.batch_log)
    assert [r["pattern"] for r in results] == patterns
    ex2 = PooledExecutor(model, b_max=64)  # fresh compile caches
    checked = check_against_offline(
        log, lambda qs: serve_batch(model, params, ex2, qs, top_k=10)[0])
    assert checked == len(patterns)
