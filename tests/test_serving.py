"""Serving subsystem tests: the continuous-batching engine (flush policy,
bucketing, backpressure, futures/latency, error isolation, out-of-core
serving) and the ``serve_batch`` offline baseline (single-trace regression,
engine parity)."""
import queue
import time

import jax
import numpy as np
import pytest

from repro.core import PooledExecutor
from repro.launch.serve import serve_batch
from repro.core.patterns import QueryInstance
from repro.models import ModelConfig, make_model
from repro.semantic import SemanticCache
from repro.serving import (ServingConfig, ServingEngine,
                           check_against_offline, make_workload,
                           pad_to_bucket, run_closed_loop, run_open_loop,
                           scorer_for)


def _setup(tiny_kg, name="gqe", dim=8, seed=0, **cfg_kw):
    model = make_model(name, ModelConfig(dim=dim, **cfg_kw))
    params = model.init_params(jax.random.PRNGKey(seed), tiny_kg.n_entities,
                               tiny_kg.n_relations)
    return model, params, PooledExecutor(model, b_max=64)


# ---------------------------------------------------------------- satellites
def test_serve_batch_traces_score_all_exactly_once(tiny_kg, mixed_queries):
    """Regression for the historical bug: ``serve_batch`` rebuilt
    ``jax.jit(model.score_all)`` per call, so EVERY batch retraced. The
    process-wide cached scorer must trace once across repeated calls."""
    # dim=12 gives this test its own scorer-cache key, so traces from other
    # tests sharing the default dim can't mask a regression here.
    model, params, ex = _setup(tiny_kg, dim=12)
    queries = [b.query for b in mixed_queries][:8]
    scorer = scorer_for(model)
    t0 = scorer.traces
    first, _ = serve_batch(model, params, ex, queries, top_k=5)
    for _ in range(3):
        again, _ = serve_batch(model, params, ex, queries, top_k=5)
        assert again == first  # deterministic replay, same compiled programs
    assert scorer.traces - t0 == 1
    # and the encode side compiled once per signature too
    assert ex.cache_stats()["encode_jit"]["misses"] == 1


def test_scorer_cache_shared_across_instances(tiny_kg):
    """Two instances of the same zoo family share one compiled scorer."""
    m1, p1, _ = _setup(tiny_kg, dim=12)
    m2, p2, _ = _setup(tiny_kg, dim=12, seed=1)
    assert scorer_for(m1) is scorer_for(m2)


def test_pad_to_bucket():
    t = QueryInstance("1p", np.array([0]), np.array([0]))
    for n, want in [(1, 1), (2, 2), (3, 4), (5, 8), (8, 8), (9, 16)]:
        padded, n_real = pad_to_bucket([t] * n)
        assert (len(padded), n_real) == (want, n)
        assert all(p is t for p in padded)
    assert pad_to_bucket([]) == ([], 0)


# -------------------------------------------------------------------- engine
def test_engine_matches_offline_serve_batch(tiny_kg, mixed_queries):
    """Closed-loop traffic through the engine == offline serve_batch on the
    same recorded micro-batch compositions, bit for bit."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=8, max_wait_ms=1000.0, top_k=7,
                        record_batches=True)
    with ServingEngine(model, params, executor=ex, cfg=cfg) as engine:
        queries = [b.query for b in mixed_queries][:24]
        rep = run_closed_loop(engine, queries, concurrency=8)
        assert [r["pattern"] for r in rep.results] == [q.pattern for q in queries]
        log = list(engine.batch_log)
    # fresh executor: the oracle must not reuse the engine's compiled cache
    ex2 = PooledExecutor(model, b_max=64)
    checked = check_against_offline(
        log, lambda qs: serve_batch(model, params, ex2, qs, top_k=7)[0])
    assert checked == 24


def test_engine_mixed_top_k_matches_per_k_oracle(tiny_kg, mixed_queries):
    """Co-batched requests with different top_k each match serve_batch at
    THEIR OWN k (selection at k, not a sliced k_max selection — the two can
    disagree on boundary-tied scores)."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=8, max_wait_ms=1000.0, record_batches=True)
    ks = [3, 9]
    with ServingEngine(model, params, executor=ex, cfg=cfg) as engine:
        queries = [b.query for b in mixed_queries][:8]
        futs = [engine.submit(q, top_k=ks[i % 2])
                for i, q in enumerate(queries)]
        results = [f.result(timeout=60) for f in futs]
        log = list(engine.batch_log)
    assert [len(r["top_entities"]) for r in results] == [ks[i % 2]
                                                         for i in range(8)]
    ex2 = PooledExecutor(model, b_max=64)
    for rec in log:
        oracles = {k: serve_batch(model, params, ex2, rec.queries,
                                  top_k=k)[0] for k in ks}
        for i, got in enumerate(rec.results[: rec.n_real]):
            want = oracles[len(got["top_entities"])][i]
            assert got["top_entities"] == want["top_entities"]
            assert got["scores"] == want["scores"]


def test_engine_rejects_nonpositive_top_k(tiny_kg, mixed_queries):
    model, params, ex = _setup(tiny_kg)
    engine = ServingEngine(model, params, executor=ex, started=False)
    with pytest.raises(ValueError, match="top_k"):
        engine.submit(mixed_queries[0].query, top_k=0)
    engine.close(drain=False)


def test_engine_age_flush_pads_partial_batch(tiny_kg, mixed_queries):
    """A partial batch must flush once the oldest request ages out, padded
    to the pow2 bucket, and padded rows must not leak into results."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=16, max_wait_ms=30.0, record_batches=True)
    with ServingEngine(model, params, executor=ex, cfg=cfg) as engine:
        queries = [b.query for b in mixed_queries][:5]
        futs = engine.submit_many(queries)
        results = [f.result(timeout=60) for f in futs]
        st = engine.stats()
        log = list(engine.batch_log)
    assert len(results) == 5
    total_real = sum(r.n_real for r in log)
    assert total_real == 5
    for rec in log:
        assert len(rec.queries) == 1 << (rec.n_real - 1).bit_length()
        assert len(rec.results) == rec.n_real
    assert st["flushes"]["age"] >= 1
    assert st["flushes"]["size"] == 0


def test_engine_bounded_admission_backpressure(tiny_kg, mixed_queries):
    """With the batcher stopped, the admission queue fills to queue_depth
    and further submits raise queue.Full; once started, all complete."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=4, max_wait_ms=5.0, queue_depth=3)
    engine = ServingEngine(model, params, executor=ex, cfg=cfg, started=False)
    queries = [b.query for b in mixed_queries][:4]
    futs = [engine.submit(q) for q in queries[:3]]
    with pytest.raises(queue.Full):
        engine.submit(queries[3], timeout=0.05)
    engine.start()
    for f in futs:
        assert f.result(timeout=60)["top_entities"]
    engine.close()
    with pytest.raises(RuntimeError):
        engine.submit(queries[0])


def test_engine_isolates_poison_request(tiny_kg, mixed_queries):
    """One malformed query fails its own future; co-batched neighbors and
    later traffic still serve."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=4, max_wait_ms=50.0)
    with ServingEngine(model, params, executor=ex, cfg=cfg) as engine:
        good = [b.query for b in mixed_queries][:3]
        bad = QueryInstance("no-such-pattern", np.array([0]), np.array([0]))
        futs = engine.submit_many(good[:2] + [bad])
        assert futs[0].result(timeout=60)["top_entities"]
        assert futs[1].result(timeout=60)["top_entities"]
        with pytest.raises(KeyError):
            futs[2].result(timeout=60)
        assert engine.submit(good[2]).result(timeout=60)["top_entities"]
        assert engine.stats()["failures"] == 1


def test_engine_zero_steady_state_retraces_on_replay(tiny_kg):
    """Replaying a deterministic workload after warmup compiles nothing."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=8, max_wait_ms=1000.0)
    with ServingEngine(model, params, executor=ex, cfg=cfg) as engine:
        workload = make_workload(tiny_kg, 32, seed=5)
        run_closed_loop(engine, workload, concurrency=8)
        assert engine.retraces() > 0  # warmup did compile
        engine.reset_counters()
        rep = run_open_loop(engine, workload)  # burst: same chunkings
        assert engine.retraces() == 0, engine.stats()["caches"]
        lat = engine.stats()["latency_ms"]
    assert lat["n"] == 32
    assert lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
    assert all(r["latency_ms"] > 0 for r in rep.results)
    assert all(r["batch_size"] == 8 for r in rep.results)


def test_engine_out_of_core_semantic_serving(tiny_kg, mixed_queries, rng):
    """Semantic serving through the engine — hot-set staging on the batcher
    thread + chunked store-streamed scoring — matches offline serve_batch
    with the same cache/chunked-scorer configuration, bit for bit, even
    with a budget small enough to force evictions."""
    d_l = 16
    table = rng.normal(size=(tiny_kg.n_entities, d_l)).astype(np.float32)
    rows_fn = lambda ids: table[np.asarray(ids, dtype=np.int64).ravel()]  # noqa: E731

    model = make_model("gqe", ModelConfig(dim=8, semantic_dim=d_l))
    ex = PooledExecutor(model, b_max=64)
    cache = SemanticCache(table, budget_rows=48)
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations, semantic_cache=cache)
    cfg = ServingConfig(max_batch=8, max_wait_ms=1000.0, top_k=6,
                        record_batches=True)
    with ServingEngine(model, params, executor=ex, cfg=cfg,
                       sem_cache=cache, sem_rows_fn=rows_fn) as engine:
        queries = [b.query for b in mixed_queries][:24]
        run_closed_loop(engine, queries, concurrency=8)
        log = list(engine.batch_log)
        assert engine.stats()["sem_cache"]["rows_staged"] > 0

    # offline oracle: fresh cache + params, same chunked scorer; params
    # thread through the closure because staging rewrites them per batch
    cache2 = SemanticCache(table, budget_rows=48)
    params2 = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                                tiny_kg.n_relations, semantic_cache=cache2)
    ex2 = PooledExecutor(model, b_max=64)
    chunked = lambda p, q: model.score_all_chunked(p, q, rows_fn, chunk=64)  # noqa: E731

    def oracle(qs):
        nonlocal params2
        res, params2 = serve_batch(model, params2, ex2, qs, top_k=6,
                                   score_all_fn=chunked, sem_cache=cache2)
        return res

    assert check_against_offline(log, oracle) == 24


def test_engine_requires_rows_fn_with_cache(tiny_kg, rng):
    table = rng.normal(size=(tiny_kg.n_entities, 16)).astype(np.float32)
    model = make_model("gqe", ModelConfig(dim=8, semantic_dim=16))
    cache = SemanticCache(table, budget_rows=32)
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations, semantic_cache=cache)
    with pytest.raises(ValueError, match="sem_rows_fn"):
        ServingEngine(model, params, sem_cache=cache, started=False)
    # same contract offline: cache params can't dense-score, so serve_batch
    # must refuse sem_cache without a chunked score_all_fn BEFORE staging
    ex = PooledExecutor(model, b_max=64)
    q = QueryInstance("1p", np.array([0]), np.array([0]))
    with pytest.raises(ValueError, match="score_all_fn"):
        serve_batch(model, params, ex, [q], sem_cache=cache)


def test_engine_coalesces_duplicate_inflight_requests(tiny_kg, mixed_queries):
    """Exact-duplicate in-flight requests (same ``QueryInstance.key()``)
    share one computed row: every future resolves, results are identical,
    the batch log records the UNIQUE composition, and ``stats()['coalesced']``
    counts the deduped requests."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=10, max_wait_ms=1000.0, top_k=5,
                        record_batches=True)
    distinct = [b.query for b in mixed_queries][:2]
    dup = mixed_queries[2].query
    engine = ServingEngine(model, params, executor=ex, cfg=cfg, started=False)
    futs = engine.submit_many([dup] * 8 + distinct)
    engine.start()
    results = [f.result(timeout=60) for f in futs]
    st = engine.stats()
    log = list(engine.batch_log)
    engine.close()
    assert st["coalesced"] == 7
    assert all(r["top_entities"] == results[0]["top_entities"] and
               r["scores"] == results[0]["scores"] for r in results[:8])
    [rec] = log
    assert rec.n_real == 3                      # 3 unique queries computed
    assert len(rec.queries) == 4                # padded to pow2 of uniques
    assert [q.key() for q in rec.queries[:3]] == [
        dup.key(), distinct[0].key(), distinct[1].key()]
    # the unique composition replays bit-identically through serve_batch
    ex2 = PooledExecutor(model, b_max=64)
    assert check_against_offline(
        log, lambda qs: serve_batch(model, params, ex2, qs, top_k=5)[0]) == 3


def test_engine_coalesced_duplicates_honor_per_request_top_k(tiny_kg,
                                                             mixed_queries):
    """Duplicates with DIFFERENT top_k still share the computed row — only
    the final selection differs."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=4, max_wait_ms=1000.0, top_k=9,
                        record_batches=True)
    q = mixed_queries[0].query
    engine = ServingEngine(model, params, executor=ex, cfg=cfg, started=False)
    f3 = engine.submit(q, top_k=3)
    f9 = engine.submit(q)          # engine default k=9
    engine.start()
    r3, r9 = f3.result(timeout=60), f9.result(timeout=60)
    st = engine.stats()
    [rec] = engine.batch_log
    engine.close()
    # the logged row records the DEFAULT-k selection (fixed-k oracle replay
    # contract), even though the custom-k request was submitted first
    assert len(rec.results[0]["top_entities"]) == 9
    assert st["coalesced"] == 1
    assert len(r3["top_entities"]) == 3 and len(r9["top_entities"]) == 9
    # Same underlying score row: the k=3 score sequence prefixes the k=9
    # one. (Ids are not asserted — argpartition may arbitrate boundary-TIED
    # scores differently between the two selections.)
    assert r3["scores"] == r9["scores"][:3]


def test_engine_concurrent_submitters_share_caches(tiny_kg, mixed_queries):
    """N submitter threads + the batcher + outside prepare() callers share
    the plan and materialized caches concurrently: every good future
    resolves, each poison request fails ALONE (KeyError, solo-retry
    isolation), counters sum exactly, the cache invariants hold (no torn
    slot maps) and the engine stays serviceable afterwards."""
    import threading

    from repro.core import MaterializedSubqueryCache

    model, params, ex = _setup(tiny_kg)
    mat = MaterializedSubqueryCache(32)
    cfg = ServingConfig(max_batch=8, max_wait_ms=5.0)
    pool = [b.query for b in mixed_queries][:6]
    bad = QueryInstance("no-such-pattern", np.array([0]), np.array([0]))
    n_threads, per_thread = 4, 25
    n_poison_each = sum(1 for i in range(per_thread) if i % 12 == 7)
    results, errors = [], []
    res_lock = threading.Lock()
    with ServingEngine(model, params, executor=ex, cfg=cfg,
                       mat_cache=mat) as engine:

        def submitter(tid):
            rng = np.random.default_rng(tid)
            futs = []
            for i in range(per_thread):
                q = bad if i % 12 == 7 else pool[int(rng.integers(len(pool)))]
                futs.append((q is bad, engine.submit(q)))
            for is_bad, f in futs:
                try:
                    r = f.result(timeout=120)
                    with res_lock:
                        results.append((is_bad, r))
                except KeyError:
                    with res_lock:
                        errors.append(is_bad)

        def preparer():
            # hammer the shared plan cache from OUTSIDE the batcher thread
            for _ in range(40):
                ex.prepare(pool)

        threads = ([threading.Thread(target=submitter, args=(t,))
                    for t in range(n_threads)]
                   + [threading.Thread(target=preparer) for _ in range(2)])
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # poison must not have wedged any lock: the engine still serves
        assert engine.submit(pool[0]).result(timeout=60)["top_entities"]
        st = engine.stats()

    n_poison = n_threads * n_poison_each
    assert errors == [True] * n_poison          # every poison future raised
    assert len(results) == n_threads * per_thread - n_poison
    assert not any(is_bad for is_bad, _ in results)
    assert st["failures"] == n_poison
    assert st["completed"] == st["submitted"]
    mat.check_consistent()
    mc = st["mat_cache"]
    assert mc["hits"] + mc["misses"] > 0
    assert mc["hits"] > 0                       # dup-heavy pool did reuse rows


def test_engine_drain_on_close(tiny_kg, mixed_queries):
    """close(drain=True) serves everything already admitted — the tail
    partial batch flushes immediately, not after the age window."""
    model, params, ex = _setup(tiny_kg)
    cfg = ServingConfig(max_batch=16, max_wait_ms=10_000.0)
    engine = ServingEngine(model, params, executor=ex, cfg=cfg)
    futs = engine.submit_many([b.query for b in mixed_queries][:3])
    t0 = time.perf_counter()
    engine.close(drain=True)
    assert time.perf_counter() - t0 < 10  # did not sit out max_wait_ms
    for f in futs:
        assert f.result(timeout=1)["top_entities"]
    assert engine.stats()["flushes"]["drain"] == 1
