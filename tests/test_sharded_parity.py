"""Mesh-sharded training parity + elastic checkpointing (ISSUE 3 acceptance).

Every test runs in a subprocess with 8 emulated host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be set before
jax imports, and the parent's single-device state must stay untouched —
same idiom as the other subprocess tests in ``test_distributed.py``).

What is pinned:
* sharded (mesh ``data=8``, fsdp profile) training — sync AND pipelined —
  reproduces single-device sync per-step losses within float tolerance on
  identical replayed batches, with and without the out-of-core semantic
  store;
* the entity table is physically split 1/8 per device while training;
* a checkpoint written by an 8-device run restores onto a 4-device mesh
  (mesh-shape-agnostic restore) with identical values and 4-way shardings.
"""
import subprocess
import sys

import pytest

# The heaviest tests in the suite (each subprocess trains 2-3 trainers on 8
# emulated devices): deselected from the tier-1 matrix (`-m "not slow"`),
# run unfiltered by the dedicated multidevice CI job.
pytestmark = pytest.mark.slow

_PRELUDE = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.data import generate_synthetic_kg
from repro.distributed.context import ExecutionContext, make_execution_context
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training import AdamConfig, NGDBTrainer, TrainConfig

E, DIM, B, NEG, STEPS = 2048, 32, 16, 4, 4
kg = generate_synthetic_kg(E, 10, 9000, seed=0)
sampler = OnlineSampler(kg, seed=7)
batches = [sampler.sample_batch(B) for _ in range(3)]

def make_trainer(ctx, pipeline, sem_dim=0, cache=None, ckpt=None):
    model = make_model("gqe", ModelConfig(dim=DIM, entity_pad=8,
                                          semantic_dim=sem_dim))
    cfg = TrainConfig(batch_size=B, n_negatives=NEG, adam=AdamConfig(lr=1e-3),
                      pipeline=pipeline, seed=0, checkpoint_dir=ckpt,
                      checkpoint_every=STEPS)
    return NGDBTrainer(model, kg, cfg, semantic_cache=cache, ctx=ctx)

def losses(tr):
    tr.train(STEPS, log_every=0, batches=batches)
    return np.array([r["loss"] for r in tr.history])
"""


def _run(body: str) -> None:
    r = subprocess.run([sys.executable, "-c", _PRELUDE + body],
                       capture_output=True, text=True, timeout=600, cwd=".")
    assert "OK True" in r.stdout, (r.stdout, r.stderr[-3000:])


def test_sharded_loss_parity_subprocess():
    """8-device sync and pipelined both match single-device sync; the entity
    table is physically 1/8 per device while doing so."""
    _run(r"""
ref = losses(make_trainer(ExecutionContext.single_device(), pipeline=False))

ctx = make_execution_context("data=8", profile="fsdp")
sync = make_trainer(ctx, pipeline=False)
l_sync = losses(sync)
pipe = make_trainer(ctx, pipeline=True)
l_pipe = losses(pipe)

ent = pipe.params["entity"]
split = ent.addressable_shards[0].data.nbytes * 8 == ent.nbytes
ok = (np.abs(l_sync - ref).max() < 1e-3
      and np.abs(l_pipe - ref).max() < 1e-3
      and split)
print("OK", bool(ok), l_sync, l_pipe, ref, ent.sharding.spec)
""")


def test_sharded_loss_parity_semantic_store_subprocess():
    """Same parity with the out-of-core semantic path: store on disk, bounded
    hot-set cache staged through plan/apply on a replicated sharded buffer."""
    _run(r"""
from repro.semantic import (PTEConfig, SemanticCache, StubPTE,
                            precompute_semantic_table_to_store)

d = tempfile.mkdtemp()
pte = StubPTE(PTEConfig(d_l=16, n_layers=1, d_model=32))
store = precompute_semantic_table_to_store(kg, d, pte, shard_rows=512)
budget = 1024

ref = losses(make_trainer(ExecutionContext.single_device(), pipeline=False,
                          sem_dim=16, cache=SemanticCache(store, budget)))

ctx = make_execution_context("data=8", profile="fsdp")
l_sync = losses(make_trainer(ctx, pipeline=False, sem_dim=16,
                             cache=SemanticCache(store, budget, ctx=ctx)))
pipe = make_trainer(ctx, pipeline=True, sem_dim=16,
                    cache=SemanticCache(store, budget, ctx=ctx))
l_pipe = losses(pipe)

staged = pipe.sem_cache.stats()["rows_staged"] > 0
rep = pipe.params["sem_cache"].sharding.spec == jax.sharding.PartitionSpec()
ok = (np.abs(l_sync - ref).max() < 1e-3
      and np.abs(l_pipe - ref).max() < 1e-3
      and staged and rep)
print("OK", bool(ok), l_sync, l_pipe, ref)
""")


def test_checkpoint_8dev_save_restore_4dev_subprocess():
    """NGDB params/opt written by an 8-device run come back on a 4-device
    mesh: same values, resharded onto the smaller mesh (elastic restore)."""
    _run(r"""
d = tempfile.mkdtemp()
ctx8 = make_execution_context("data=8", profile="fsdp")
t8 = make_trainer(ctx8, pipeline=True, ckpt=d)
losses(t8)  # trains STEPS steps; checkpoint_every=STEPS -> one save
want = np.asarray(t8.params["entity"])

ctx4 = make_execution_context("data=4", profile="fsdp")
t4 = make_trainer(ctx4, pipeline=False, ckpt=d)
resumed = t4.resume()
got = t4.params["entity"]
on4 = got.sharding.mesh.size == 4
split4 = got.addressable_shards[0].data.nbytes * 4 == got.nbytes
same = np.array_equal(np.asarray(got), want)
step_ok = t4.step == STEPS
opt_ok = np.array_equal(np.asarray(t4.opt_state["m"]["entity"]),
                        np.asarray(t8.opt_state["m"]["entity"]))
ok = resumed and on4 and split4 and same and step_ok and opt_ok
print("OK", bool(ok), resumed, on4, split4, same, step_ok, opt_ok)
""")
