import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, make_model, model_names


@pytest.fixture(scope="module")
def setup():
    out = {}
    for name in model_names():
        model = make_model(name, ModelConfig(dim=8))
        params = model.init_params(jax.random.PRNGKey(0), 50, 6)
        out[name] = (model, params)
    return out


@pytest.mark.parametrize("name", model_names())
def test_operator_shapes(name, setup):
    model, params = setup[name]
    ids = jnp.array([0, 1, 2])
    x = model.embed(params, ids)
    assert x.shape == (3, model.state_dim)
    y = model.project(params, x, jnp.array([0, 1, 2]))
    assert y.shape == x.shape
    for k in (2, 3):
        stack = jnp.stack([x] * k, axis=1)
        assert model.intersect(params, stack).shape == x.shape
        assert model.union(params, stack).shape == x.shape
    assert model.negate(params, x).shape == x.shape
    s = model.score_ids(params, x, jnp.array([[0, 1], [2, 3], [4, 5]]))
    assert s.shape == (3, 2)
    assert np.isfinite(np.asarray(s)).all()


@pytest.mark.parametrize("name", model_names())
def test_score_all_matches_score_ids(name, setup):
    model, params = setup[name]
    q = model.embed(params, jnp.array([4, 7]))
    full = np.asarray(model.score_all(params, q))
    ids = jnp.arange(50)[None, :].repeat(2, 0)
    sub = np.asarray(model.score_ids(params, q, ids))
    np.testing.assert_allclose(full, sub, rtol=1e-4, atol=1e-5)


def test_betae_negation_involution(setup):
    model, params = setup["betae"]
    x = model.embed(params, jnp.array([1, 2, 3]))
    xx = model.negate(params, model.negate(params, x))
    np.testing.assert_allclose(np.asarray(xx), np.asarray(x), rtol=1e-4)


def test_betae_positive_params(setup):
    model, params = setup["betae"]
    x = model.embed(params, jnp.arange(10))
    assert (np.asarray(x) > 0).all()
    y = model.project(params, x, jnp.zeros(10, jnp.int32))
    assert (np.asarray(y) > 0).all()


def test_fuzzqe_logic_laws(setup):
    model, params = setup["fuzzqe"]
    x = model.embed(params, jnp.array([1, 2]))
    # complement involution
    np.testing.assert_allclose(
        np.asarray(model.negate(params, model.negate(params, x))),
        np.asarray(x), rtol=1e-5)
    # De Morgan: ¬(a ∧ b) == ¬a ∨ ¬b for product/probabilistic-sum pair
    a = model.embed(params, jnp.array([3]))
    b = model.embed(params, jnp.array([4]))
    lhs = model.negate(params, model.intersect(params, jnp.stack([a, b], 1)))
    rhs = model.union(params, jnp.stack([model.negate(params, a),
                                         model.negate(params, b)], 1))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-5)
    # intersection shrinks membership, union grows it
    inter = model.intersect(params, jnp.stack([a, b], 1))
    uni = model.union(params, jnp.stack([a, b], 1))
    assert (np.asarray(inter) <= np.asarray(a) + 1e-6).all()
    assert (np.asarray(uni) >= np.asarray(a) - 1e-6).all()


def test_q2b_entity_in_own_box(setup):
    model, params = setup["q2b"]
    x = model.embed(params, jnp.array([5]))
    ev = model.fused_entity_vec(params, jnp.array([5]))
    d = model.distance(params, x, ev)
    assert float(d[0]) < 1e-4  # zero offset box centered at the entity


def test_semantic_fusion_path(tiny_kg):
    from repro.semantic import precompute_semantic_table, StubPTE, PTEConfig

    pte = StubPTE(PTEConfig(d_l=32, n_layers=1, d_model=32))
    table = precompute_semantic_table(tiny_kg, pte, batch_size=64)
    assert table.shape == (tiny_kg.n_entities, 32)
    assert pte.unloaded
    with pytest.raises(RuntimeError):
        pte.encode_entities(tiny_kg, np.arange(3))

    model = make_model("gqe", ModelConfig(dim=8, semantic_dim=32))
    params = model.init_params(jax.random.PRNGKey(0), tiny_kg.n_entities,
                               tiny_kg.n_relations, semantic_table=table)
    v = model.fused_entity_vec(params, jnp.array([0, 1]))
    assert v.shape == (2, 8)
    assert np.isfinite(np.asarray(v)).all()
