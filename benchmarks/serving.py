"""§Serving load test: continuous-batching engine vs the offline baseline.

Replays a deterministic mixed-pattern workload through the ``ServingEngine``
and asserts the two serving invariants (DESIGN.md §Serving):

* **bit-identity** — per-request top-k entities AND scores from the async
  engine equal ``launch/serve.py::serve_batch`` run offline on the same
  micro-batch compositions, with a FRESH model/executor built from the same
  seed (so nothing leaks through engine state);
* **zero steady-state retraces** — after one warmup pass over the workload,
  the timed open-loop and closed-loop passes compile NOTHING: every
  schedule/encode/scorer lookup hits (signature-bucketed padding keeps the
  jit signature set closed).

Timed phases measure closed-loop throughput (max sustainable QPS) and
open-loop latency (p50/p95/p99 under burst or ``--qps``-paced arrivals).
The summary lands in ``BENCH_serving.json`` at the repo root (committed, so
the serving perf trajectory accumulates across PRs); a violated invariant
publishes ``ok: false`` BEFORE raising, so a stale green verdict can never
survive a crashed run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # direct `python benchmarks/serving.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import jax

from benchmarks.common import emit
from repro.core import PooledExecutor
from repro.data import load_dataset
from repro.launch.serve import serve_batch
from repro.models import ModelConfig, make_model
from repro.serving import (ServingConfig, ServingEngine,
                           check_against_offline, make_workload,
                           run_closed_loop, run_open_loop)

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_serving.json")


def _check_bit_identity(engine, model_name, dim, kg, top_k, b_max):
    """Replay every recorded micro-batch through the offline ``serve_batch``
    baseline on a FRESH model + executor (same init seed ⇒ same params) and
    demand exact per-request equality of top-k ids and scores."""
    model = make_model(model_name, ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    ex = PooledExecutor(model, b_max=b_max)
    return check_against_offline(
        engine.batch_log,
        lambda qs: serve_batch(model, params, ex, qs, top_k=top_k)[0])


def run(requests: int = 192, max_batch: int = 16, dim: int = 32,
        model_name: str = "gqe", dataset: str = "FB15k", top_k: int = 10,
        qps: float = 0.0, out_path: str = _DEFAULT_OUT) -> dict:
    summary = {"ok": False, "suite": "serving", "model": model_name,
               "dataset": dataset, "requests": 0, "failures": []}

    def publish():
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")

    try:
        _run_inner(summary, requests, max_batch, dim, model_name, dataset,
                   top_k, qps)
        summary["ok"] = not summary["failures"]
    except BaseException as e:
        # Publish the red verdict first: a crashed sweep must not leave a
        # stale ok=true on disk for CI's ok-check to read.
        summary["failures"].append(f"{type(e).__name__}: {e}")
        publish()
        raise
    publish()
    return summary


def _run_inner(summary, requests, max_batch, dim, model_name, dataset,
               top_k, qps) -> None:
    # Full micro-batches only: the workload divides max_batch so every flush
    # is size-triggered and the replayed compositions are exactly the warmup
    # compositions (the zero-retrace claim is about a replayed workload).
    requests -= requests % max_batch
    assert requests >= 2 * max_batch, "workload too small to measure"
    kg, _, _ = load_dataset(dataset)
    workload = make_workload(kg, requests, seed=11)
    model = make_model(model_name, ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    b_max = 256
    cfg = ServingConfig(max_batch=max_batch, max_wait_ms=2000.0,
                        queue_depth=64, top_k=top_k, record_batches=True)
    engine = ServingEngine(model, params,
                           executor=PooledExecutor(model, b_max=b_max),
                           cfg=cfg)

    # -- warmup: compile every signature the replay will form ------------
    run_closed_loop(engine, workload, concurrency=max_batch)
    warm_compiles = engine.retraces()
    engine.reset_counters(clear_log=True)

    # -- timed closed loop: max sustainable throughput -------------------
    closed = run_closed_loop(engine, workload, concurrency=max_batch)
    closed_retraces = engine.retraces()
    emit(f"serving/{dataset}/{model_name}/closed_qps",
         1e6 / max(closed.qps, 1e-9), f"qps={closed.qps:.0f}")
    if closed_retraces != 0:
        summary["failures"].append(
            f"{closed_retraces} retraces in the closed-loop replay "
            f"(warmup: {warm_compiles} cold misses)")

    # -- timed open loop: latency under offered load ---------------------
    open_rep = run_open_loop(engine, workload, qps=qps)
    open_retraces = engine.retraces() - closed_retraces
    lat = open_rep.latency_ms
    emit(f"serving/{dataset}/{model_name}/open_qps",
         1e6 / max(open_rep.qps, 1e-9), f"qps={open_rep.qps:.0f}")
    emit(f"serving/{dataset}/{model_name}/latency_p50", lat["p50"] * 1e3,
         f"{lat['p50']:.1f} ms")
    emit(f"serving/{dataset}/{model_name}/latency_p95", lat["p95"] * 1e3,
         f"{lat['p95']:.1f} ms")
    emit(f"serving/{dataset}/{model_name}/latency_p99", lat["p99"] * 1e3,
         f"{lat['p99']:.1f} ms")
    if qps == 0 and open_retraces != 0:
        # A paced open loop may form partial batches (unwarmed signatures);
        # the burst replay must not.
        summary["failures"].append(
            f"{open_retraces} retraces in the open-loop burst replay")

    # -- bit-identity vs the offline serve_batch oracle ------------------
    st = engine.stats()
    engine.close()
    checked = _check_bit_identity(engine, model_name, dim, kg, top_k, b_max)
    # One oracle comparison per COMPUTED row: duplicate in-flight requests
    # coalesce onto one row (engine.stats()["coalesced"]), so the row count
    # is 2*requests minus the coalesced duplicates — demand exactly that,
    # not a request count the log no longer contains.
    want_rows = sum(rec.n_real for rec in engine.batch_log)
    assert checked == want_rows >= 2 * requests - st["coalesced"], (
        checked, want_rows, requests, st["coalesced"])
    emit(f"serving/{dataset}/{model_name}/bit_identity", 0.0,
         f"{checked} computed rows == offline serve_batch "
         f"({st['coalesced']} duplicates coalesced)")
    emit(f"serving/{dataset}/{model_name}/retraces", 0.0,
         f"{closed_retraces + open_retraces} (warmup: {warm_compiles} "
         f"cold misses)")

    summary.update({
        "requests": requests,
        "max_batch": max_batch,
        "dim": dim,
        "top_k": top_k,
        "qps_offered": qps,
        "qps_closed": round(closed.qps, 1),
        "qps_open": round(open_rep.qps, 1),
        "latency_ms": {k: round(v, 3) for k, v in lat.items()},
        "closed_latency_ms": {k: round(v, 3)
                              for k, v in closed.latency_ms.items()},
        "warmup_cache_misses": warm_compiles,
        "steady_state_retraces": closed_retraces + open_retraces,
        "bit_identical_requests": checked,
        "mean_batch_size": round(st["mean_batch_size"], 2),
        "flushes": st["flushes"],
    })
    for name in ("top_entities", "scores"):  # spot-check payload shape
        assert name in engine.batch_log[0].results[0]
    if summary["failures"]:
        raise AssertionError("; ".join(summary["failures"]))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=192)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--model", default="gqe")
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop pacing; 0 = burst (retrace-assertable)")
    args = ap.parse_args()
    run(requests=args.requests, max_batch=args.max_batch, dim=args.dim,
        model_name=args.model, dataset=args.dataset, top_k=args.top_k,
        qps=args.qps)
