"""§Serving load test: continuous-batching engine vs the offline baseline,
plus the multi-replica serving-tier gates (DESIGN.md §ServingTier).

Replays a deterministic mixed-pattern workload through the ``ServingEngine``
and asserts the two serving invariants (DESIGN.md §Serving):

* **bit-identity** — per-request top-k entities AND scores from the async
  engine equal ``launch/serve.py::serve_batch`` run offline on the same
  micro-batch compositions, with a FRESH model/executor built from the same
  seed (so nothing leaks through engine state);
* **zero steady-state retraces** — after one warmup pass over the workload,
  the timed open-loop and closed-loop passes compile NOTHING: every
  schedule/encode/scorer lookup hits (signature-bucketed padding keeps the
  jit signature set closed).

The serving-tier section (``multi_replica`` in the summary; ``--no-tier``
skips it) adds two phases over a routed :class:`ReplicaPool`:

* **affinity replay** — a cyclic replay over more distinct queries than ONE
  replica's materialized cache can hold. Rendezvous routing partitions the
  topologies so every replica's share FITS its cache (steady-state mat hits,
  zero retraces per replica), while a single replica with the SAME
  per-replica budget thrashes its CLOCK cache on every cycle — the
  aggregate-QPS >= 2.5x gate is cache affinity made visible, not thread
  parallelism (the bench box serializes on one core either way).
* **overload mix** — a paced high-priority tenant (gold) against a
  low-priority flood (bronze): gold p99 must stay within 2x its unloaded
  p99 while bronze's excess is shed with typed, counted, never-blocking
  ``ShedError``s.

The summary lands in ``BENCH_serving.json`` at the repo root (committed, so
the serving perf trajectory accumulates across PRs); a violated invariant
publishes ``ok: false`` BEFORE raising, so a stale green verdict can never
survive a crashed run.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import threading
import time

if __package__ in (None, ""):  # direct `python benchmarks/serving.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import jax

from benchmarks.common import emit
from repro.core import PooledExecutor
from repro.data import load_dataset
from repro.launch.serve import serve_batch
from repro.models import ModelConfig, make_model
from repro.serving import (ReplicaPool, Router, RouterConfig, ServingConfig,
                           ServingEngine, TenantLoad, TenantSpec,
                           check_against_offline, make_workload,
                           query_topology_key, rendezvous_rank,
                           run_closed_loop, run_open_loop, run_tenant_mix)

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_serving.json")


def _check_bit_identity(engine, model_name, dim, kg, top_k, b_max):
    """Replay every recorded micro-batch through the offline ``serve_batch``
    baseline on a FRESH model + executor (same init seed ⇒ same params) and
    demand exact per-request equality of top-k ids and scores."""
    model = make_model(model_name, ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    ex = PooledExecutor(model, b_max=b_max)
    return check_against_offline(
        engine.batch_log,
        lambda qs: serve_batch(model, params, ex, qs, top_k=top_k)[0])


def run(requests: int = 192, max_batch: int = 16, dim: int = 32,
        model_name: str = "gqe", dataset: str = "FB15k", top_k: int = 10,
        qps: float = 0.0, replicas: int = 4, tier: bool = True,
        out_path: str = _DEFAULT_OUT) -> dict:
    summary = {"ok": False, "suite": "serving", "model": model_name,
               "dataset": dataset, "requests": 0, "failures": []}

    def publish():
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")

    try:
        _run_inner(summary, requests, max_batch, dim, model_name, dataset,
                   top_k, qps)
        if tier:
            _run_tier(summary, max_batch, dim, model_name, dataset, top_k,
                      replicas)
        summary["ok"] = not summary["failures"]
    except BaseException as e:
        # Publish the red verdict first: a crashed sweep must not leave a
        # stale ok=true on disk for CI's ok-check to read.
        summary["failures"].append(f"{type(e).__name__}: {e}")
        publish()
        raise
    publish()
    return summary


def _run_inner(summary, requests, max_batch, dim, model_name, dataset,
               top_k, qps) -> None:
    # Full micro-batches only: the workload divides max_batch so every flush
    # is size-triggered and the replayed compositions are exactly the warmup
    # compositions (the zero-retrace claim is about a replayed workload).
    requests -= requests % max_batch
    assert requests >= 2 * max_batch, "workload too small to measure"
    kg, _, _ = load_dataset(dataset)
    workload = make_workload(kg, requests, seed=11)
    model = make_model(model_name, ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    b_max = 256
    cfg = ServingConfig(max_batch=max_batch, max_wait_ms=2000.0,
                        queue_depth=64, top_k=top_k, record_batches=True)
    engine = ServingEngine(model, params,
                           executor=PooledExecutor(model, b_max=b_max),
                           cfg=cfg)

    # -- warmup: compile every signature the replay will form ------------
    run_closed_loop(engine, workload, concurrency=max_batch)
    warm_compiles = engine.retraces()
    engine.reset_counters(clear_log=True)

    # -- timed closed loop: max sustainable throughput -------------------
    closed = run_closed_loop(engine, workload, concurrency=max_batch)
    closed_retraces = engine.retraces()
    emit(f"serving/{dataset}/{model_name}/closed_qps",
         1e6 / max(closed.qps, 1e-9), f"qps={closed.qps:.0f}")
    if closed_retraces != 0:
        summary["failures"].append(
            f"{closed_retraces} retraces in the closed-loop replay "
            f"(warmup: {warm_compiles} cold misses)")

    # -- timed open loop: latency under offered load ---------------------
    open_rep = run_open_loop(engine, workload, qps=qps)
    open_retraces = engine.retraces() - closed_retraces
    lat = open_rep.latency_ms
    emit(f"serving/{dataset}/{model_name}/open_qps",
         1e6 / max(open_rep.qps, 1e-9), f"qps={open_rep.qps:.0f}")
    emit(f"serving/{dataset}/{model_name}/latency_p50", lat["p50"] * 1e3,
         f"{lat['p50']:.1f} ms")
    emit(f"serving/{dataset}/{model_name}/latency_p95", lat["p95"] * 1e3,
         f"{lat['p95']:.1f} ms")
    emit(f"serving/{dataset}/{model_name}/latency_p99", lat["p99"] * 1e3,
         f"{lat['p99']:.1f} ms")
    if qps == 0 and open_retraces != 0:
        # A paced open loop may form partial batches (unwarmed signatures);
        # the burst replay must not.
        summary["failures"].append(
            f"{open_retraces} retraces in the open-loop burst replay")

    # -- bit-identity vs the offline serve_batch oracle ------------------
    st = engine.stats()
    engine.close()
    checked = _check_bit_identity(engine, model_name, dim, kg, top_k, b_max)
    # One oracle comparison per COMPUTED row: duplicate in-flight requests
    # coalesce onto one row (engine.stats()["coalesced"]), so the row count
    # is 2*requests minus the coalesced duplicates — demand exactly that,
    # not a request count the log no longer contains.
    want_rows = sum(rec.n_real for rec in engine.batch_log)
    assert checked == want_rows >= 2 * requests - st["coalesced"], (
        checked, want_rows, requests, st["coalesced"])
    emit(f"serving/{dataset}/{model_name}/bit_identity", 0.0,
         f"{checked} computed rows == offline serve_batch "
         f"({st['coalesced']} duplicates coalesced)")
    emit(f"serving/{dataset}/{model_name}/retraces", 0.0,
         f"{closed_retraces + open_retraces} (warmup: {warm_compiles} "
         f"cold misses)")

    # ``qps_offered`` is the rate the open-loop generator MEASURED over its
    # submit phase (historically it echoed the --qps argument, so burst mode
    # published 0.0 next to a 4000+ qps_open). Nonzero-gated.
    if open_rep.offered_qps <= 0:
        summary["failures"].append(
            f"open-loop offered rate not recorded ({open_rep.offered_qps})")

    summary.update({
        "requests": requests,
        "max_batch": max_batch,
        "dim": dim,
        "top_k": top_k,
        "qps_offered": round(open_rep.offered_qps, 1),
        "qps_paced": qps,
        "qps_closed": round(closed.qps, 1),
        "qps_open": round(open_rep.qps, 1),
        "latency_ms": {k: round(v, 3) for k, v in lat.items()},
        "closed_latency_ms": {k: round(v, 3)
                              for k, v in closed.latency_ms.items()},
        "warmup_cache_misses": warm_compiles,
        "steady_state_retraces": closed_retraces + open_retraces,
        "bit_identical_requests": checked,
        "mean_batch_size": round(st["mean_batch_size"], 2),
        "flushes": st["flushes"],
    })
    for name in ("top_entities", "scores"):  # spot-check payload shape
        assert name in engine.batch_log[0].results[0]
    if summary["failures"]:
        raise AssertionError("; ".join(summary["failures"]))


_DEEP_PATTERNS = ("3p", "3i", "ip", "pi", "inp", "pin", "pni", "up", "3in")


def _affinity_streams(kg, rids, max_batch, seed=13):
    """Deterministic per-replica replay streams: unique deep-pattern queries
    partitioned by the SAME rendezvous placement the router will use, each
    stream trimmed to whole micro-batches so the lock-step closed loop below
    replays identical compositions every cycle (the zero-retrace contract is
    about replayed compositions). Deep (multi-hop/intersection) patterns
    because that is the traffic the affinity claim is about: the deeper the
    plan, the more encode work a materialized-row hit elides."""
    raw = {q.key(): q for q in make_workload(kg, 16 * max_batch, seed=seed,
                                             patterns=list(_DEEP_PATTERNS))}
    streams = {rid: [] for rid in rids}
    for q in raw.values():
        streams[rendezvous_rank(query_topology_key(q), rids)[0]].append(q)
    return {rid: qs[: len(qs) // max_batch * max_batch]
            for rid, qs in streams.items() if len(qs) >= max_batch}


def _cycle_blocks(streams, max_batch):
    """One replay cycle as replica-homogeneous blocks of ``max_batch``: the
    closed loop keeps exactly one block in flight, so each block IS one
    micro-batch on its home replica — composition-deterministic across
    cycles and across the single-replica baseline."""
    blocks = []
    for rid in sorted(streams):
        qs = streams[rid]
        blocks.extend(qs[i:i + max_batch]
                      for i in range(0, len(qs), max_batch))
    return blocks


def _lane(router, blocks, timeout, errs):
    """One client's lock-step replay: exactly one block in flight, so each
    block IS one micro-batch on its home replica and compositions replay
    identically every cycle."""
    try:
        for blk in blocks:
            # Batched admission (one placement pass + one queue entry per
            # home replica) for both configurations — the tier comparison
            # measures serving cost, not per-call client overhead.
            futures = router.submit_many(blk)
            for f in futures:
                f.result(timeout=timeout)
    except BaseException as e:  # surfaced by _replay_lanes on the caller
        errs.append(e)


def _replay_lanes(router, lanes, timeout=120.0):
    """Replay affinity lanes concurrently — one client thread per lane,
    mirroring a deployment where each replica serves its own stream of
    affine clients. Per-replica compositions stay deterministic (each lane
    keeps one block in flight on its home replica); the lanes overlap only
    where the engine releases the GIL (XLA compute), which is exactly the
    concurrency a multi-replica tier buys on shared hardware. Returns
    aggregate QPS over the slowest lane's wall clock."""
    errs: list = []
    t0 = time.perf_counter()
    if len(lanes) == 1:
        _lane(router, lanes[0], timeout, errs)
    else:
        threads = [threading.Thread(target=_lane, args=(router, bl, timeout,
                                                        errs), daemon=True)
                   for bl in lanes]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    n = sum(len(b) for bl in lanes for b in bl)
    return n / max(wall, 1e-9)


def _run_tier(summary, max_batch, dim, model_name, dataset, top_k,
              replicas) -> None:
    import dataclasses

    kg, _, _ = load_dataset(dataset)
    model = make_model(model_name, ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    tier = {"replicas": replicas}
    summary["multi_replica"] = tier

    # ---- affinity replay: aggregate QPS vs a single replica -------------
    # Encode cost per miss batch is dominated by per-stage-group dispatch,
    # i.e. it is nearly independent of the row count, while the hit path
    # scales with rows — so SMALL blocks maximize the measured contrast
    # between a mat-resident pool and a thrashing single replica. One block
    # == one engine micro-batch keeps compositions replay-deterministic.
    tier_batch = max_batch
    rids = list(range(replicas))
    streams = _affinity_streams(kg, rids, tier_batch)
    shares = {rid: len(qs) for rid, qs in streams.items()}
    total = sum(shares.values())
    # Every replica's share fits its materialized cache; the UNION does not
    # fit one replica's cache — that asymmetry is the whole experiment.
    budget = max(shares.values()) + tier_batch
    if total < budget + 2 * tier_batch:
        summary["failures"].append(
            f"affinity workload too small to demonstrate thrash "
            f"(unique {total}, per-replica budget {budget})")
        return
    blocks = _cycle_blocks(streams, tier_batch)
    cycles = 2
    n_timed = len(blocks) * tier_batch * cycles
    cfg = ServingConfig(max_batch=tier_batch, max_wait_ms=2000.0,
                        queue_depth=256, top_k=top_k)
    rcfg = RouterConfig(spill_width=0)  # pure affinity: deterministic homes

    results = {}
    for tag, n_reps in (("single", 1), ("pool", replicas)):
        pool = ReplicaPool(model, params, n_replicas=n_reps, cfg=cfg,
                           mat_budget_rows=budget, b_max=256)
        router = Router(pool, cfg=dataclasses.replace(rcfg))
        # One sequential client for BOTH configurations: every block is one
        # micro-batch on its home replica, compositions replay identically
        # every cycle, and the comparison isolates cache affinity (client
        # threads per lane would measure GIL contention on this one-core
        # box, not the tier).
        lanes = [blocks]
        _replay_lanes(router, lanes)                   # warm caches + jits
        pool.reset_counters()
        timed_lanes = [bl * cycles for bl in lanes]
        # Best of two timed replays: OS scheduling jitter on a shared box
        # only ever slows a run down, so the faster trial is the better
        # estimate of each configuration's sustainable rate. Collect garbage
        # before each trial — a gen2 GC pause landing on the batcher thread
        # mid-replay is pure measurement noise.
        gc.collect()
        best_qps = _replay_lanes(router, timed_lanes)
        gc.collect()
        best_qps = max(best_qps, _replay_lanes(router, timed_lanes))
        per = {}
        for rid, r in pool.replicas().items():
            st = r.stats()
            per[rid] = {
                "submitted": st["submitted"],
                "batches": st["batches"],
                "retraces": st["retraces"],
                "mat_hit_rate": round(st["mat_cache"]["hit_rate"], 4),
            }
        results[tag] = {"qps": best_qps, "per_replica": per,
                        "router": router.stats(), "pool": pool}
        if tag == "single":
            router.close()

    qps_single = results["single"]["qps"]
    qps_pool = results["pool"]["qps"]
    speedup = qps_pool / max(qps_single, 1e-9)
    tier.update({
        "unique_queries": total,
        "tier_batch": tier_batch,
        "mat_budget_rows": budget,
        "replay_requests": n_timed,
        "qps_single": round(qps_single, 1),
        "qps_pool": round(qps_pool, 1),
        "speedup": round(speedup, 2),
        "single_mat_hit_rate":
            results["single"]["per_replica"][0]["mat_hit_rate"],
        "per_replica": {str(rid): dict(st)
                        for rid, st in results["pool"]["per_replica"].items()},
        "spilled": results["pool"]["router"]["spilled"],
    })
    emit(f"serving/{dataset}/{model_name}/tier_qps_pool",
         1e6 / max(qps_pool, 1e-9), f"qps={qps_pool:.0f}")
    emit(f"serving/{dataset}/{model_name}/tier_speedup_x{replicas}",
         speedup, f"{speedup:.2f}x vs single replica")
    if speedup < 2.5:
        summary["failures"].append(
            f"affinity speedup {speedup:.2f}x < 2.5x at {replicas} replicas "
            f"(single {qps_single:.0f} qps, pool {qps_pool:.0f} qps)")
    for rid, st in results["pool"]["per_replica"].items():
        if st["retraces"] != 0:
            summary["failures"].append(
                f"replica {rid}: {st['retraces']} steady-state retraces in "
                f"the affinity replay")

    # ---- overload mix: priority SLOs + typed shed -----------------------
    pool = results["pool"]["pool"]
    # Re-point the flush policy at latency-serving values for the paced
    # phase (the affinity phase used a long age window for deterministic
    # replay); queues are empty between phases, so this is safe.
    for r in pool.replicas().values():
        r.engine.cfg = dataclasses.replace(r.engine.cfg, max_wait_ms=2.0,
                                           max_batch=max_batch)
    router = Router(pool, tenants=[
        TenantSpec("gold", "high"),
        TenantSpec("bronze", "low"),
    ], cfg=RouterConfig(spill_width=0, low_priority_depth=1))
    # Warm the shared scorer for the small pow2 batch sizes paced arrivals
    # form (the affinity phase only ever scored full batches; every overload
    # query is mat-resident, so encode never runs and only score_all has
    # unseen signatures).
    import numpy as np

    from repro.serving import scorer_for

    any_rep = next(iter(pool.replicas().values()))
    probe_q = next(iter(streams.values()))[0]
    state_dim = np.asarray(
        any_rep.executor.encode(params, [probe_q], compiled=True)).shape[1]
    scorer = scorer_for(model)
    b = 1
    while b <= max_batch:
        scorer(params, np.zeros((b, state_dim), dtype=np.float32))
        b *= 2

    gold_n, gold_qps = 12 * max_batch, 150.0
    bronze_n, bronze_qps = 24 * max_batch, 1000.0
    all_qs = [q for rid in sorted(streams) for q in streams[rid]]
    gold_qs = (all_qs * ((gold_n // len(all_qs)) + 1))[:gold_n]
    bronze_qs = (all_qs[::-1] * ((bronze_n // len(all_qs)) + 1))[:bronze_n]

    # GC before each paced phase: a collection pause on a batcher thread
    # stalls every queued request at once, which a p99-vs-p99 gate reads as
    # an SLO breach when it is allocator noise from the phases before.
    gc.collect()
    unloaded = run_tenant_mix(router, [TenantLoad("gold", gold_qs, gold_qps)])
    gc.collect()
    mixed = run_tenant_mix(router, [
        TenantLoad("gold", gold_qs, gold_qps),
        TenantLoad("bronze", bronze_qs, bronze_qps),
    ])
    router.close()

    g0, g1, b1 = unloaded["gold"], mixed["gold"], mixed["bronze"]
    tier["tenants"] = {
        "gold": {
            "priority": "high",
            "offered": g1.offered,
            "completed": g1.completed,
            "shed_rate": round(g1.shed / max(g1.offered, 1), 4),
            "p50_ms": round(g1.latency_ms["p50"], 3),
            "p99_ms": round(g1.latency_ms["p99"], 3),
            "p99_unloaded_ms": round(g0.latency_ms["p99"], 3),
        },
        "bronze": {
            "priority": "low",
            "offered": b1.offered,
            "completed": b1.completed,
            "shed": b1.shed,
            "shed_rate": round(b1.shed / max(b1.offered, 1), 4),
            "failures": b1.failures,
            "submit_p99_ms": round(b1.submit_ms["p99"], 3),
            "submit_max_ms": round(b1.submit_ms["max"], 3),
            "p50_ms": round(b1.latency_ms["p50"], 3),
            "p99_ms": round(b1.latency_ms["p99"], 3),
        },
    }
    emit(f"serving/{dataset}/{model_name}/tier_gold_p99",
         g1.latency_ms["p99"] * 1e3, f"{g1.latency_ms['p99']:.1f} ms "
         f"(unloaded {g0.latency_ms['p99']:.1f} ms)")
    emit(f"serving/{dataset}/{model_name}/tier_bronze_shed_rate",
         b1.shed / max(b1.offered, 1) * 1e3,
         f"{b1.shed}/{b1.offered} shed, submit p99 "
         f"{b1.submit_ms['p99']:.2f} ms")
    if g1.failures or g1.shed:
        summary["failures"].append(
            f"gold (high priority) saw {g1.failures} failures / "
            f"{g1.shed} sheds under overload")
    if g1.latency_ms["p99"] > 2.0 * g0.latency_ms["p99"]:
        summary["failures"].append(
            f"gold p99 {g1.latency_ms['p99']:.2f} ms exceeds 2x unloaded "
            f"p99 {g0.latency_ms['p99']:.2f} ms under the overload mix")
    if b1.shed == 0:
        summary["failures"].append(
            "bronze (low priority) flood was never shed — backpressure "
            "admission is not engaging")
    if b1.failures:
        summary["failures"].append(
            f"bronze saw {b1.failures} hard failures (sheds must be typed, "
            f"not failures)")
    if b1.submit_ms["p99"] > 20.0:
        summary["failures"].append(
            f"bronze submit p99 {b1.submit_ms['p99']:.1f} ms — "
            f"low-priority admission must never block")
    pool.close()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=192)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--model", default="gqe")
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop pacing; 0 = burst (retrace-assertable)")
    ap.add_argument("--replicas", type=int, default=4,
                    help="serving-tier pool size for the multi-replica gates")
    ap.add_argument("--no-tier", action="store_true",
                    help="skip the multi-replica serving-tier section")
    args = ap.parse_args()
    run(requests=args.requests, max_batch=args.max_batch, dim=args.dim,
        model_name=args.model, dataset=args.dataset, top_k=args.top_k,
        qps=args.qps, replicas=args.replicas, tier=not args.no_tier)
