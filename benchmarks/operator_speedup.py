"""Table 6: per-operator execution time, baseline (fragmented per-query
launches) vs batched (one pooled kernel). Reproduces the paper's ablation
showing Intersect/Union gain the most (multi-input, high arithmetic
intensity)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.models import ModelConfig, make_model


def run(n_ops: int = 256, dim: int = 64, model_name: str = "betae") -> None:
    model = make_model(model_name, ModelConfig(dim=dim))
    params = model.init_params(jax.random.PRNGKey(0), 1000, 20)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 1000, n_ops))
    rels = jnp.asarray(rng.integers(0, 20, n_ops))
    x = model.embed(params, ids)
    stack3 = jnp.stack([x, x[::-1], x], axis=1)

    cases = {
        "EmbedE": (lambda p, i: model.embed(p, i), (params, ids),
                   lambda p, i: model.embed(p, i[:1])),
        "Project": (lambda p, v, r: model.project(p, v, r), (params, x, rels),
                    lambda p, v, r: model.project(p, v[:1], r[:1])),
        "Intersect": (lambda p, s: model.intersect(p, s), (params, stack3),
                      lambda p, s: model.intersect(p, s[:1])),
        "Union": (lambda p, s: model.union(p, s), (params, stack3),
                  lambda p, s: model.union(p, s[:1])),
        "Negate": (lambda p, v: model.negate(p, v), (params, x),
                   lambda p, v: model.negate(p, v[:1])),
    }
    for name, (batched, args, single) in cases.items():
        jb = jax.jit(batched)
        js = jax.jit(single)
        t_batched = time_fn(jb, *args)
        t_single = time_fn(js, *args)          # one fragment
        t_baseline = t_single * n_ops          # n_ops fragmented launches
        emit(f"op/{name}/batched", t_batched, f"n={n_ops}")
        emit(f"op/{name}/baseline_extrap", t_baseline, "per-query loop")
        emit(f"op/{name}/speedup", 0.0, f"x{t_baseline / t_batched:.1f}")


if __name__ == "__main__":
    run()
