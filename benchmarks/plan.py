"""§Compiler load test: plan-IR CSE on an overlap-heavy replay workload.

Replays a deterministic workload whose queries share anchor+relation chains
(prefix-derived subqueries and repeated queries — the 2p/3p/ip/pi overlap
case and the shape of real serving traffic) and asserts the compiler
invariants (DESIGN.md §Compiler):

* **sharing** — cross-query CSE merges ≥ 25% of the pooled rows on the
  overlap workload (the ``SharingReport`` aggregate);
* **bitwise invisibility** — encode outputs and first-step training losses
  are bit-identical with CSE on vs off for ALL SIX model families (the
  forward pass is bitwise GIVEN identical params — DESIGN.md §Compiler).
  Across steps, reverse-mode AD sums per-consumer cotangents INTO a shared
  node before scattering into the tables, where the no-CSE graph scatters
  each duplicate separately — floating-point addition reassociates, so
  parameters (and hence later losses) may drift by ulps. The bench asserts
  the full loss sequences stay within 1e-5 and records which families are
  fully bitwise over the replay (5-6 of 6 in practice; drift, when it
  appears at 50%+ sharing, is a single float32 ulp);
* **zero steady-state retraces** — after a warmup pass, replaying the
  workload compiles nothing: schedule/encode/train-step caches all hit
  (the deduped-topology structure key is replay-stable);
* **plan-cache reuse** — the cross-batch ``PlanCache`` turns every replayed
  batch into one dict lookup: steady-state hit rate ≥ 90% and ZERO
  canonicalize calls (compile cost is a warmup-only line item);
* **throughput** — steady-state sync and pipelined queries/sec, CSE on vs
  off, with one-time compile cost reported separately as ``compile_ms``.
  With plans cached across batches the per-batch host cost is identical
  for both modes, so the device-side row savings must win:
  ``cse_dominates`` asserts CSE-on QPS ≥ CSE-off in BOTH modes;
* **serving reuse** — a duplicate-heavy engine replay with a
  ``MaterializedSubqueryCache``: steady state serves encoded rows from
  cache (hit rate ≥ 90%, zero retraces).

The summary lands in ``BENCH_plan.json`` at the repo root (committed, so the
compiler perf trajectory accumulates across PRs); any violated invariant
publishes ``ok: false`` BEFORE raising, so a stale green verdict can never
survive a crashed run.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/plan.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import numpy as np

from benchmarks.common import emit
from repro.core import compile_batch
from repro.core.patterns import QueryInstance, answer_query
from repro.data import load_dataset
from repro.models import ModelConfig, make_model, model_names
from repro.sampling import OnlineSampler
from repro.sampling.online import SampledQuery
from repro.training import AdamConfig, NGDBTrainer, TrainConfig

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_plan.json")

_PREFIX = {"3p": "2p", "2p": "1p"}  # chain patterns -> their prefix pattern


def make_overlap_batches(kg, n_batches: int, batch_size: int, seed: int = 13):
    """Deterministic overlap-heavy workload: each batch is ~half freshly
    sampled chain/branch queries, plus prefix-derived subqueries (a 1p that
    IS the first hop of a co-batched 2p, etc.) and repeated queries — the
    overlap profile of production serving traffic, where popular subqueries
    recur across concurrent requests."""
    sampler = OnlineSampler(kg, patterns=("1p", "2p", "3p", "ip", "pi", "2i"),
                            seed=seed)
    batches = []
    for _ in range(n_batches):
        base = sampler.sample_batch(max(batch_size // 2, 1))
        derived = []
        for b in base:
            q = b.query
            pre = _PREFIX.get(q.pattern)
            if pre is None:
                continue
            n_rel = 1 if pre == "1p" else 2
            pq = QueryInstance(pre, q.anchors[:1].copy(),
                               q.relations[:n_rel].copy())
            ans = answer_query(kg, pq)
            if ans:  # prefix of a non-empty chain is non-empty, but be safe
                derived.append(SampledQuery(pq, np.fromiter(ans, np.int64)))
        batch = base + derived
        i = 0
        while len(batch) < batch_size:  # repeats: the serving-dup extreme
            batch.append(base[i % len(base)])
            i += 1
        batches.append(batch[:batch_size])
    return batches


def run(steps: int = 8, batch: int = 128, dim: int = 64,
        dataset: str = "FB15k", loss_steps: int = 5, trials: int = 8,
        out_path: str = _DEFAULT_OUT) -> dict:
    summary = {"ok": False, "suite": "plan", "dataset": dataset,
               "failures": []}

    def publish():
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")

    try:
        _run_inner(summary, steps, batch, dim, dataset, loss_steps, trials)
        summary["ok"] = not summary["failures"]
    except BaseException as e:
        # Publish the red verdict first: a crashed run must not leave a
        # stale ok=true on disk for CI's ok-check to read.
        summary["failures"].append(f"{type(e).__name__}: {e}")
        publish()
        raise
    publish()
    return summary


def _make_trainer(model_name, kg, dim, batch, cse, pipeline, seed=0):
    cfg = TrainConfig(batch_size=batch, n_negatives=8, b_max=128,
                      adam=AdamConfig(lr=1e-3), seed=seed, prefetch=2,
                      pipeline=pipeline, cse=cse)
    return NGDBTrainer(make_model(model_name, ModelConfig(dim=dim, gamma=6.0)),
                       kg, cfg)


def _run_inner(summary, steps, batch, dim, dataset, loss_steps, trials):
    kg, _, _ = load_dataset(dataset)
    batches = make_overlap_batches(kg, n_batches=4, batch_size=batch)
    summary.update({"batch_size": batch, "n_replay_batches": len(batches)})

    # -- sharing: aggregate CSE effect over the replay workload ----------
    before = after = 0
    for b in batches:
        plan = compile_batch([s.query for s in b], model_name="probe")
        before += plan.report.nodes_before
        after += plan.report.nodes_after
    saved_frac = (before - after) / max(before, 1)
    summary["pooled_rows_saved_frac"] = round(saved_frac, 4)
    summary["nodes_before"] = before
    summary["nodes_after"] = after
    emit(f"plan/{dataset}/pooled_rows_saved", 0.0,
         f"{before - after}/{before} = {saved_frac:.1%}")
    if saved_frac < 0.25:
        summary["failures"].append(
            f"pooled rows saved {saved_frac:.1%} < 25% on the overlap "
            f"workload — CSE is not merging shared subexpressions")

    # -- bitwise invisibility: encode + loss sequences, all 6 families ---
    import jax

    summary["loss_bitwise"] = {}
    for name in model_names():
        model = make_model(name, ModelConfig(dim=8, gamma=6.0))
        params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                                   kg.n_relations)
        from repro.core import PooledExecutor

        qs = [s.query for s in batches[0]]
        enc_on = np.asarray(
            PooledExecutor(model, b_max=128, cse=True).encode(params, qs))
        enc_off = np.asarray(
            PooledExecutor(model, b_max=128, cse=False).encode(params, qs))
        if not np.array_equal(enc_on, enc_off):
            summary["failures"].append(f"{name}: encode CSE on != off")
        losses = {}
        for cse in (True, False):
            tr = _make_trainer(name, kg, 8, batch, cse=cse, pipeline=False)
            tr.train(loss_steps, log_every=0, batches=batches)
            losses[cse] = [h["loss"] for h in tr.history]
        # Step 1 runs from IDENTICAL params: encode is bitwise, so the loss
        # must be too — any difference here is a real compiler bug, not
        # gradient-accumulation reassociation.
        if losses[True][0] != losses[False][0]:
            summary["failures"].append(
                f"{name}: FIRST-step loss differs with CSE "
                f"({losses[True][0]!r} != {losses[False][0]!r}) — the "
                f"forward pass is not bitwise")
        diff = float(np.max(np.abs(np.asarray(losses[True])
                                   - np.asarray(losses[False]))))
        bitwise = losses[True] == losses[False]
        summary["loss_bitwise"][name] = bitwise
        summary.setdefault("loss_max_diff", {})[name] = diff
        if diff > 1e-5:
            summary["failures"].append(
                f"{name}: loss sequences drift {diff:.2e} > 1e-5 with CSE "
                f"(on={losses[True]}, off={losses[False]})")
        emit(f"plan/{dataset}/{name}/loss_bitwise", 0.0,
             f"{bitwise} (max drift {diff:.1e})")

    # -- throughput + zero steady-state retraces, sync & pipelined -------
    def stream():
        it = itertools.cycle(batches)
        return lambda: next(it)

    trainers = {}
    summary["compile_ms"] = {}
    for cse in (True, False):
        for mode in ("sync", "pipelined"):
            tag = f"{mode}_{'cse' if cse else 'nocse'}"
            tr = _make_trainer("gqe", kg, dim, batch, cse=cse,
                               pipeline=(mode == "pipelined"))
            t0 = time.perf_counter()
            tr.train(steps, log_every=0, batches=stream())  # warm signatures
            # One-time cost: tracing/compiling every signature plus the
            # first canonicalize+hash-cons per batch. Reported separately
            # so steady-state QPS below measures the replay loop only.
            summary["compile_ms"][tag] = round(
                1e3 * (time.perf_counter() - t0), 1)
            tr._train_fns.reset_counters()
            tr.executor.reset_cache_counters()
            trainers[(cse, mode)] = tr

    best = {k: float("inf") for k in trainers}
    keys = list(trainers)
    for t in range(max(trials, 1)):
        # Interleaved AND rotated: machine-speed drift hits every engine
        # equally, and no engine is systematically first (the first-timed
        # engine eats cold-cache/frequency effects every trial otherwise —
        # at a ~4% CSE win that bias alone can flip the verdict).
        for key in keys[t % len(keys):] + keys[:t % len(keys)]:
            t0 = time.perf_counter()
            trainers[key].train(steps, log_every=0, batches=stream())
            best[key] = min(best[key], time.perf_counter() - t0)

    summary["qps"] = {}
    summary["plan_cache_hit_rate"] = {}
    retraces = 0
    for (cse, mode), tr in trainers.items():
        tag = f"{mode}_{'cse' if cse else 'nocse'}"
        qps = steps * batch / best[(cse, mode)]
        summary["qps"][tag] = round(qps, 1)
        cs = tr.compile_cache_stats()
        misses = (int(cs["train_step"]["misses"])
                  + sum(int(cs[k]["misses"])
                        for k in ("schedule", "encode", "encode_jit")))
        retraces += misses
        pc = tr.executor.sharing_stats()["plan_cache"]
        summary["plan_cache_hit_rate"][tag] = round(pc["hit_rate"], 4)
        emit(f"plan/{dataset}/{tag}_qps", 1e6 * best[(cse, mode)] / steps,
             f"qps={qps:.0f} retraces={misses} "
             f"plan_hits={pc['hit_rate']:.0%}")
        if misses:
            summary["failures"].append(
                f"{tag}: {misses} steady-state retraces on the replayed "
                f"workload — the deduped-topology key is not replay-stable")
        if pc["hit_rate"] < 0.9:
            summary["failures"].append(
                f"{tag}: steady-state plan-cache hit rate "
                f"{pc['hit_rate']:.1%} < 90% on an exact replay")
        if pc["canonicalize_calls"] != 0:
            summary["failures"].append(
                f"{tag}: {pc['canonicalize_calls']} canonicalize calls in "
                f"steady state — exact-key plan hits must skip "
                f"canonicalization entirely")
    summary["steady_state_retraces"] = retraces
    on, off = summary["qps"]["sync_cse"], summary["qps"]["sync_nocse"]
    emit(f"plan/{dataset}/sync_speedup", 0.0, f"x{on / max(off, 1e-9):.2f}")
    # With plans cached, CSE's per-batch host cost matches no-CSE (one dict
    # lookup each) and the device step runs strictly fewer pooled rows —
    # steady-state throughput must not regress in EITHER mode.
    dominates = (summary["qps"]["sync_cse"] >= summary["qps"]["sync_nocse"]
                 and summary["qps"]["pipelined_cse"]
                 >= summary["qps"]["pipelined_nocse"])
    summary["cse_dominates"] = dominates
    if not dominates:
        summary["failures"].append(
            f"CSE does not dominate in steady state: {summary['qps']}")

    _serving_replay(summary, kg, dataset, batch)


def _serving_replay(summary, kg, dataset, batch):
    """Duplicate-heavy engine replay: the batcher consults the materialized
    cache before padding, so steady-state traffic skips encode entirely."""
    import jax

    from repro.core import MaterializedSubqueryCache, PooledExecutor
    from repro.serving import (ServingConfig, ServingEngine, make_workload,
                               run_closed_loop)

    model = make_model("gqe", ModelConfig(dim=16, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    executor = PooledExecutor(model, b_max=128)
    mat = MaterializedSubqueryCache(4 * batch)
    mat.watch_kg(kg)
    engine = ServingEngine(model, params, executor=executor,
                           cfg=ServingConfig(max_batch=16), mat_cache=mat)
    try:
        uniq = make_workload(kg, 32, seed=7)
        workload = [uniq[i % len(uniq)] for i in range(4 * len(uniq))]
        run_closed_loop(engine, workload, concurrency=16)  # warm + fill
        engine.reset_counters()
        t0 = time.perf_counter()
        run_closed_loop(engine, workload, concurrency=16)
        dt = time.perf_counter() - t0
        st = engine.stats()
        mc, rt = st["mat_cache"], int(st["retraces"])
        summary["serving"] = {
            "qps": round(len(workload) / dt, 1),
            "mat_hit_rate": round(mc["hit_rate"], 4),
            "coalesced": int(st["coalesced"]),
            "retraces": rt,
        }
        emit(f"plan/{dataset}/serving_replay", 1e6 * dt / len(workload),
             f"qps={summary['serving']['qps']:.0f} "
             f"mat_hits={mc['hit_rate']:.0%} retraces={rt}")
        if mc["hit_rate"] < 0.9:
            summary["failures"].append(
                f"serving replay: materialized hit rate "
                f"{mc['hit_rate']:.1%} < 90% on duplicate-heavy traffic")
        if rt:
            summary["failures"].append(
                f"serving replay: {rt} steady-state retraces with the "
                f"materialized cache attached")
    finally:
        engine.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--loss-steps", type=int, default=5)
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--dataset", default="FB15k")
    args = ap.parse_args()
    run(steps=args.steps, batch=args.batch, dim=args.dim,
        dataset=args.dataset, loss_steps=args.loss_steps, trials=args.trials)


if __name__ == "__main__":
    main()
