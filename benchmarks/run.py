# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: tput,ops,sem,semstore,"
                         "adaptive,freebase,scaling,kernels,pipeline,serving,"
                         "plan,obs,autotune,live")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from benchmarks import (adaptive, autotune, kernels_bench, live, obs,
                            operator_speedup, plan, runtime_freebase,
                            scaling, semantic, serving, throughput)

    suites = [
        ("tput", "Table 3/1: operator-level vs query-level throughput",
         lambda: (throughput.run(), throughput.run_schedule_stats())),
        ("ops", "Table 6: per-operator batched speedup", operator_speedup.run),
        ("sem", "Table 8/Fig 8: decoupled semantic integration", semantic.run),
        ("semstore", "§4.4 out-of-core semantic store + hot-set cache",
         semantic.run_store),
        ("adaptive", "Fig 9: adaptive sampling under shift", adaptive.run),
        ("freebase", "Table 2: single-hop completion runtime", runtime_freebase.run),
        # The scaling sweep also persists its summary (per-device param
        # bytes, steps/s, retrace counts) to BENCH_scaling.json at the repo
        # root, so the perf trajectory accumulates across PRs.
        ("scaling", "Fig 7/Table 2: sharded-vs-single-device scaling sweep",
         scaling.run),
        # Persists oracle-agreement + resolved-tile summary to
        # BENCH_kernels.json at the repo root (committed across PRs).
        ("kernels", "Pallas kernel validation/micro (BENCH_kernels.json)",
         kernels_bench.run),
        ("pipeline", "Pipelined dataflow executor vs sync + compile cache",
         throughput.run_pipeline_compare),
        # Also persists its QPS/latency/invariant summary to
        # BENCH_serving.json at the repo root (committed across PRs).
        ("serving", "§Serving: continuous-batching engine load test "
                    "(bit-identity + zero steady-state retraces)",
         serving.run),
        # Persists its sharing/bit-identity/retrace summary to
        # BENCH_plan.json at the repo root (committed across PRs).
        ("plan", "§Compiler: plan-IR CSE on an overlap-heavy replay "
                 "(>=25% pooled rows saved, bitwise losses, zero retraces)",
         plan.run),
        # Persists its overhead/bit-identity/trace-completeness summary to
        # BENCH_obs.json at the repo root (committed across PRs).
        ("obs", "§Observability: tracing overhead gate (off = bit-identical "
                "+ free; on <= 2% pipelined throughput; traces validate)",
         obs.run),
        # Persists its bit-identity/retrace/paired-ratio/cache-roundtrip
        # summary to BENCH_autotune.json at the repo root (committed).
        ("autotune", "§Autotuner: tile sweep gate (tuned bitwise vs default, "
                     "zero retraces w/ kernel-aware bucketing, tuned never "
                     "slower, persisted cache serves run 2)",
         autotune.run),
        # Persists its continuity/pinned-replay/staleness/determinism
        # summary to BENCH_live.json at the repo root (committed across PRs).
        ("live", "§LiveStore: live KG writes under serving load (zero "
                 "failed requests, pinned replay bitwise vs snapshot "
                 "oracle, typed staleness sheds, deterministic background "
                 "fine-tune)",
         live.run),
    ]
    print("name,us_per_call,derived")
    for key, desc, fn in suites:
        if want and key not in want:
            continue
        print(f"# {desc}", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(f"{key}/ERROR,0.0,failed")


if __name__ == "__main__":
    main()
