"""Table 8 / Fig 8: decoupled GPU-resident semantic integration vs joint
PTE-in-the-loop training. Measures the throughput speedup from making the
train loop inference-free, and the memory delta (PTE unloaded vs resident)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.semantic import PTEConfig, StubPTE, precompute_semantic_table
from repro.training import AdamConfig, NGDBTrainer, TrainConfig


def run(model_name: str = "q2b", steps: int = 4, batch: int = 32,
        d_l: int = 256) -> None:
    kg, _, _ = load_dataset("FB15k")
    pte_cfg = PTEConfig(d_l=d_l, n_layers=4, d_model=128)

    # ---- decoupled: offline precompute, then gather-only training ----------
    pte = StubPTE(pte_cfg)
    t0 = time.perf_counter()
    table = precompute_semantic_table(kg, pte, batch_size=256)
    precompute_s = time.perf_counter() - t0
    model = make_model(model_name, ModelConfig(dim=32, gamma=6.0, semantic_dim=d_l))
    cfg = TrainConfig(batch_size=batch, n_negatives=16, b_max=128, prefetch=0,
                      patterns=("1p", "2p", "2i"), adam=AdamConfig(lr=1e-3))
    tr = NGDBTrainer(model, kg, cfg, semantic_table=table)
    tr.train_step()
    t0 = time.perf_counter()
    for _ in range(steps):
        tr.train_step()
    qps_decoupled = steps * batch / (time.perf_counter() - t0)

    # ---- joint: PTE forward inside every train step -------------------------
    pte2 = StubPTE(pte_cfg)
    enc = jax.jit(pte2.encode_tokens)
    tr2 = NGDBTrainer(model, kg, cfg, semantic_table=table)

    rng = np.random.default_rng(0)

    def joint_step():
        batch_q = tr2.sampler.sample_batch(batch)
        # the joint design re-encodes every entity the loss touches:
        # anchors, positives AND the negative samples (the decoupled path
        # serves all of these from the precomputed buffer for free)
        ents = np.unique(np.concatenate(
            [b.query.anchors for b in batch_q]
            + [b.answers[:1] for b in batch_q]
            + [rng.integers(0, kg.n_entities, cfg.n_negatives)
               for _ in batch_q]))
        toks = StubPTE.descriptions(kg, ents)
        fresh = enc(jnp.asarray(toks))       # PTE inference on the hot path
        jax.block_until_ready(fresh)
        tr2.train_step(batch_q)

    joint_step()
    t0 = time.perf_counter()
    for _ in range(steps):
        joint_step()
    qps_joint = steps * batch / (time.perf_counter() - t0)

    pte_params = sum(x.size for x in jax.tree.leaves(StubPTE(pte_cfg).params))
    table_bytes = table.size * 4
    emit("sem/decoupled_qps", 1e6 / qps_decoupled, f"qps={qps_decoupled:.0f}")
    emit("sem/joint_qps", 1e6 / qps_joint, f"qps={qps_joint:.0f}")
    emit("sem/speedup", 0.0, f"x{qps_decoupled / qps_joint:.2f}")
    emit("sem/precompute_s", precompute_s * 1e6, "one-off offline phase")
    emit("sem/resident_buffer_mb", 0.0, f"{table_bytes / 1e6:.1f}")
    emit("sem/unloaded_pte_params", 0.0, f"{pte_params}")


if __name__ == "__main__":
    run()
