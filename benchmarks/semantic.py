"""Table 8 / Fig 8: decoupled GPU-resident semantic integration vs joint
PTE-in-the-loop training. Measures the throughput speedup from making the
train loop inference-free, and the memory delta (PTE unloaded vs resident).

``run_store`` adds the §4.4 out-of-core proof (DESIGN.md §SemanticStore):
training against the sharded mmap store + bounded device hot-set cache must
be bit-identical to full-resident fp32 training while device-resident
semantic bytes stay under budget, with all row staging done by the pipeline
prefetch (zero synchronous mid-step store reads)."""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.semantic import (PTEConfig, SemanticCache, StubPTE,
                            precompute_semantic_table,
                            precompute_semantic_table_to_store)
from repro.training import AdamConfig, NGDBTrainer, TrainConfig


def run(model_name: str = "q2b", steps: int = 4, batch: int = 32,
        d_l: int = 256) -> None:
    kg, _, _ = load_dataset("FB15k")
    pte_cfg = PTEConfig(d_l=d_l, n_layers=4, d_model=128)

    # ---- decoupled: offline precompute, then gather-only training ----------
    pte = StubPTE(pte_cfg)
    t0 = time.perf_counter()
    table = precompute_semantic_table(kg, pte, batch_size=256)
    precompute_s = time.perf_counter() - t0
    model = make_model(model_name, ModelConfig(dim=32, gamma=6.0, semantic_dim=d_l))
    cfg = TrainConfig(batch_size=batch, n_negatives=16, b_max=128, prefetch=0,
                      patterns=("1p", "2p", "2i"), adam=AdamConfig(lr=1e-3))
    tr = NGDBTrainer(model, kg, cfg, semantic_table=table)
    tr.train_step()
    t0 = time.perf_counter()
    for _ in range(steps):
        tr.train_step()
    qps_decoupled = steps * batch / (time.perf_counter() - t0)

    # ---- joint: PTE forward inside every train step -------------------------
    pte2 = StubPTE(pte_cfg)
    enc = jax.jit(pte2.encode_tokens)
    tr2 = NGDBTrainer(model, kg, cfg, semantic_table=table)

    rng = np.random.default_rng(0)

    def joint_step():
        batch_q = tr2.sampler.sample_batch(batch)
        # the joint design re-encodes every entity the loss touches:
        # anchors, positives AND the negative samples (the decoupled path
        # serves all of these from the precomputed buffer for free)
        ents = np.unique(np.concatenate(
            [b.query.anchors for b in batch_q]
            + [b.answers[:1] for b in batch_q]
            + [rng.integers(0, kg.n_entities, cfg.n_negatives)
               for _ in batch_q]))
        toks = StubPTE.descriptions(kg, ents)
        fresh = enc(jnp.asarray(toks))       # PTE inference on the hot path
        jax.block_until_ready(fresh)
        tr2.train_step(batch_q)

    joint_step()
    t0 = time.perf_counter()
    for _ in range(steps):
        joint_step()
    qps_joint = steps * batch / (time.perf_counter() - t0)

    pte_params = sum(x.size for x in jax.tree.leaves(StubPTE(pte_cfg).params))
    table_bytes = table.size * 4
    emit("sem/decoupled_qps", 1e6 / qps_decoupled, f"qps={qps_decoupled:.0f}")
    emit("sem/joint_qps", 1e6 / qps_joint, f"qps={qps_joint:.0f}")
    emit("sem/speedup", 0.0, f"x{qps_decoupled / qps_joint:.2f}")
    emit("sem/precompute_s", precompute_s * 1e6, "one-off offline phase")
    emit("sem/resident_buffer_mb", 0.0, f"{table_bytes / 1e6:.1f}")
    emit("sem/unloaded_pte_params", 0.0, f"{pte_params}")


def run_store(model_name: str = "gqe", steps: int = 8, batch: int = 16,
              negatives: int = 8, d_l: int = 64, budget_rows: int = 256) -> None:
    """Out-of-core semantic training vs full-resident, same fixed batches."""
    kg, _, _ = load_dataset("FB15k")
    pte_cfg = PTEConfig(d_l=d_l, n_layers=2, d_model=64)
    patterns = ("1p", "2p", "2i")
    full_bytes = kg.n_entities * d_l * 4
    assert budget_rows < kg.n_entities, "budget must be out-of-core to prove the claim"

    batches = [OnlineSampler(kg, seed=11, patterns=patterns).sample_batch(batch)
               for _ in range(steps)]

    def make_trainer(cache=None, table=None, pipeline=False):
        model = make_model(model_name, ModelConfig(dim=32, gamma=6.0,
                                                   semantic_dim=d_l))
        cfg = TrainConfig(batch_size=batch, n_negatives=negatives, b_max=128,
                          prefetch=2 if pipeline else 0, pipeline=pipeline,
                          patterns=patterns, adam=AdamConfig(lr=1e-3))
        return NGDBTrainer(model, kg, cfg, semantic_table=table,
                           semantic_cache=cache)

    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        store = precompute_semantic_table_to_store(
            kg, d, StubPTE(pte_cfg), quant="fp32", shard_rows=128)
        build_s = time.perf_counter() - t0

        # Full-resident baseline (same rows, bulk-exported from the store).
        table = np.concatenate([rows for _, rows in store.iter_shards()])
        tr_full = make_trainer(table=table)
        tr_full.train(steps, log_every=0, batches=batches)

        # Out-of-core: hot-set cache + pipelined prefetch staging.
        cache = SemanticCache(store, budget_rows=budget_rows)
        tr_ooc = make_trainer(cache=cache, pipeline=True)
        t0 = time.perf_counter()
        tr_ooc.train(steps, log_every=0, batches=batches)
        qps = steps * batch / (time.perf_counter() - t0)

        bit_identical = [r["loss"] for r in tr_full.history] == \
                        [r["loss"] for r in tr_ooc.history]
        cs = cache.stats()
        emit("sem/store_build_s", build_s * 1e6,
             f"shards={len(store._shards)},disk_mb={store.disk_nbytes/1e6:.2f}")
        emit("sem/store_qps", 1e6 / qps, f"qps={qps:.0f}")
        emit("sem/store_loss_bitmatch", 0.0,
             f"{'OK' if bit_identical else 'MISMATCH'}")
        emit("device_resident_sem_bytes", 0.0,
             f"{cs['device_resident_sem_bytes']} (full-resident {full_bytes})")
        emit("sem_cache_hit_rate", 0.0, f"{cs['hit_rate']:.3f}")
        emit("prefetch_overlap_frac", 0.0,
             f"{cs['prefetch_overlap_frac']:.3f} (sync_mid_step_reads="
             f"{cs['sync_stages']})")
        assert bit_identical, "out-of-core fp32 training diverged from full-resident"
        assert cs["device_resident_sem_bytes"] < full_bytes
        assert cs["sync_stages"] == 0, "pipelined run did a mid-step store read"


if __name__ == "__main__":
    run()
    run_store()
