"""§LiveStore gate: live KG writes under serving load (BENCH_live.json).

Drives the full live-write surface end to end and asserts the four
§LiveStore invariants (DESIGN.md §LiveStore):

* **continuity** — a closed-loop replay runs THROUGH a concurrent write
  burst (graph commits + entity growth + background fine-tunes publishing
  params mid-flight) with zero failed requests;
* **pinned replay** — requests pinned to a retained graph version are
  bit-identical to the offline ``serve_batch`` oracle run on the params
  that were live when that version was admitted, even after later writes
  and param publishes land;
* **staleness bound** — a pin that falls more than ``max_staleness_versions``
  behind is shed with the typed ``StaleVersionError`` (accounted as
  ``stale_sheds``, never ``failures``) and serves zero rows;
* **maintenance determinism** — the background incremental fine-tune equals
  a synchronous rerun from the recorded (params, triples, seed) bitwise,
  and the maintained params score the written neighborhood within tolerance
  of a from-scratch fine-tune of the pre-write params on the same triples.

The summary lands in ``BENCH_live.json`` at the repo root (committed, so
the live-path trajectory accumulates across PRs); any violated invariant
publishes ``ok: false`` BEFORE raising, so a stale green verdict can never
survive a crashed run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

if __package__ in (None, ""):  # direct `python benchmarks/live.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import MaterializedSubqueryCache, PooledExecutor
from repro.data import load_dataset
from repro.launch.serve import serve_batch
from repro.models import ModelConfig, make_model
from repro.serving import (LiveNGDB, ServingConfig, ServingEngine,
                           StaleVersionError, make_workload, run_closed_loop)
from repro.training.loop import incremental_finetune

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_live.json")


def _fresh_rows(kg, rng, n):
    cand = np.stack([rng.integers(0, kg.n_entities, 8 * n),
                     rng.integers(0, kg.n_relations, 8 * n),
                     rng.integers(0, kg.n_entities, 8 * n)], axis=1)
    return np.unique(cand[~kg.contains(cand)], axis=0)[:n]


def _strip(r):
    return {k: v for k, v in r.items() if k not in ("latency_ms",
                                                    "batch_size")}


def run(requests: int = 96, max_batch: int = 8, dim: int = 16,
        model_name: str = "gqe", dataset: str = "FB15k", bound: int = 3,
        writes: int = 6, out_path: str = _DEFAULT_OUT) -> dict:
    summary = {"ok": False, "suite": "live", "model": model_name,
               "dataset": dataset, "requests": 0, "failures": []}

    def publish():
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")

    try:
        _run_inner(summary, requests, max_batch, dim, model_name, dataset,
                   bound, writes)
        summary["ok"] = not summary["failures"]
    except BaseException as e:
        # Publish the red verdict first: a crashed sweep must not leave a
        # stale ok=true on disk for CI's ok-check to read.
        summary["failures"].append(f"{type(e).__name__}: {e}")
        publish()
        raise
    publish()
    return summary


def _run_inner(summary, requests, max_batch, dim, model_name, dataset,
               bound, writes) -> None:
    kg, _, _ = load_dataset(dataset)
    workload = make_workload(kg, requests, seed=11)
    model = make_model(model_name, ModelConfig(dim=dim, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    base_params = params
    mat = MaterializedSubqueryCache(256)
    mat.watch_kg(kg)
    cfg = ServingConfig(max_batch=max_batch, max_wait_ms=5.0, top_k=10,
                        queue_depth=256, max_staleness_versions=bound)
    engine = ServingEngine(model, params,
                           executor=PooledExecutor(model, b_max=256),
                           cfg=cfg, kg=kg, mat_cache=mat)
    live = LiveNGDB(model, kg, engine, finetune_steps=2, seed=0)
    rng = np.random.default_rng(17)

    # -- continuity: closed loop THROUGH a live write burst ---------------
    run_closed_loop(engine, workload, concurrency=max_batch)  # warmup
    engine.reset_counters()
    done = threading.Event()

    def _burst():
        for i in range(writes):
            # one burst also grows the entity table, exercising the
            # params/store/graph growth path under load
            if i == writes // 2:
                n0 = kg.n_entities
                live.write(np.array([[n0, 0, 0], [n0 + 1, 1, n0]]),
                           n_new_entities=2)
            else:
                live.write(_fresh_rows(kg, rng, 4))
            time.sleep(0.01)
        done.set()

    writer = threading.Thread(target=_burst, name="live-writer")
    t0 = time.perf_counter()
    writer.start()
    rep = run_closed_loop(engine, workload, concurrency=max_batch)
    while not done.is_set():       # keep traffic up until every write lands
        rep2 = run_closed_loop(engine, workload[:max_batch],
                               concurrency=max_batch)
        assert len(rep2.results) == max_batch
    writer.join()
    live.flush()
    dt = time.perf_counter() - t0
    st = engine.stats()
    summary["requests"] = int(st["completed"])
    emit(f"live/{dataset}/{model_name}/qps_through_writes",
         1e6 / max(rep.qps, 1e-9), f"qps={rep.qps:.0f}")
    if st["failures"] != 0 or len(rep.results) != requests:
        summary["failures"].append(
            f"continuity: {st['failures']} failed requests, "
            f"{len(rep.results)}/{requests} served through the write burst")
    n_fresh = sum(r.n_written for r in live.receipts)
    if live.finetunes_done != sum(1 for r in live.receipts if r.n_written):
        summary["failures"].append(
            f"maintenance: {live.finetunes_done} fine-tunes for "
            f"{n_fresh} fresh triples across {len(live.receipts)} bursts")
    summary.update({
        "write_bursts": len(live.receipts), "fresh_triples": int(n_fresh),
        "graph_version_after_burst": int(kg.graph_version),
        "finetunes": int(live.finetunes_done),
        "burst_wall_s": round(dt, 2),
    })

    # -- pinned replay: bit-identical to the snapshot-pinned oracle -------
    pin = kg.graph_version
    pinned_params = engine.params          # live params admitted at `pin`
    qs = [workload[i] for i in rng.integers(len(workload), size=16)]
    live.write(_fresh_rows(kg, rng, 4))    # later write the pin must ignore
    live.flush()                           # ...and a later param publish
    got = [_strip(engine.submit(q, pin_version=pin).result(timeout=120))
           for q in qs]
    oracle, _ = serve_batch(model, pinned_params,
                            PooledExecutor(model, b_max=256), qs, top_k=10)
    mismatches = sum(g != _strip(w) for g, w in zip(got, oracle))
    if mismatches:
        summary["failures"].append(
            f"pinned replay: {mismatches}/{len(qs)} rows differ from the "
            f"snapshot-pinned offline oracle at version {pin}")
    summary["pinned_replay_rows"] = len(qs)
    summary["pinned_version"] = int(pin)

    # -- staleness bound: out-of-bound pins shed, zero stale rows ---------
    for _ in range(bound + 1):             # push `pin` out of the bound
        live.write(_fresh_rows(kg, rng, 2))
    live.flush()
    sheds = 0
    for q in qs[:4]:
        try:
            engine.submit(q, pin_version=pin)
            summary["failures"].append(
                f"staleness: pin {pin} admitted at version "
                f"{kg.graph_version} with bound {bound}")
        except StaleVersionError:
            sheds += 1
    st = engine.stats()
    if st["stale_sheds"] != sheds or st["failures"] != 0:
        summary["failures"].append(
            f"staleness accounting: {sheds} typed sheds but stats say "
            f"stale_sheds={st['stale_sheds']} failures={st['failures']}")
    summary["stale_sheds"] = int(st["stale_sheds"])
    summary["version_lag_served"] = {str(k): v for k, v
                                     in sorted(st["version_lag_served"].items())}

    # -- maintenance determinism + loss vs from-scratch rebuild -----------
    pre = engine.params
    receipt = live.write(_fresh_rows(kg, rng, 4))
    live.flush()
    sync, sync_losses = incremental_finetune(
        model, pre, receipt.fresh_triples, steps=live.finetune_steps,
        lr=live.finetune_lr, n_negatives=live.n_negatives,
        seed=live.seed + receipt.graph_version)
    for k in sync:
        if not np.array_equal(np.asarray(engine.params[k]),
                              np.asarray(sync[k])):
            summary["failures"].append(
                f"determinism: background fine-tune of '{k}' differs from "
                f"the synchronous rerun")
    # touched neighborhood = everything written this run; probe the loss of
    # the incrementally-maintained params vs a from-scratch fine-tune of
    # the NEVER-maintained base params on the same triples.
    touched = np.concatenate([r.fresh_triples for r in live.receipts
                              if r.n_written])
    _, probe_inc = incremental_finetune(model, engine.params, touched,
                                        steps=1, seed=1)
    rebuilt, _ = incremental_finetune(
        model, base_params, touched, lr=live.finetune_lr,
        steps=live.finetune_steps * max(1, live.finetunes_done), seed=1)
    _, probe_reb = incremental_finetune(model, rebuilt, touched,
                                        steps=1, seed=1)
    tol = 2.0
    if probe_inc[0] > probe_reb[0] + tol:
        summary["failures"].append(
            f"maintenance loss: incremental {probe_inc[0]:.3f} vs "
            f"from-scratch rebuild {probe_reb[0]:.3f} (tol {tol})")
    summary.update({
        "finetune_loss_first": round(float(sync_losses[0]), 4),
        "finetune_loss_last": round(float(sync_losses[-1]), 4),
        "touched_loss_incremental": round(float(probe_inc[0]), 4),
        "touched_loss_rebuild": round(float(probe_reb[0]), 4),
        "graph_version_final": int(kg.graph_version),
    })
    emit(f"live/{dataset}/{model_name}/finetune_loss",
         float(probe_inc[0]) * 1e3,
         f"inc={probe_inc[0]:.3f} rebuild={probe_reb[0]:.3f}")
    live.close()
    engine.close()
    if summary["failures"]:
        raise AssertionError("; ".join(summary["failures"]))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--model", default="gqe")
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--bound", type=int, default=3)
    ap.add_argument("--writes", type=int, default=6)
    args = ap.parse_args()
    run(requests=args.requests, max_batch=args.max_batch, dim=args.dim,
        model_name=args.model, dataset=args.dataset, bound=args.bound,
        writes=args.writes)
