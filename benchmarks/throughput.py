"""Table 3 / Table 1: end-to-end training throughput, operator-level
(NGDB-Zoo) vs query-level (KGReasoning/SQE-style) batching, across backbone
models and datasets. CPU-scale reduction of the paper's protocol; the metric
of record is the RELATIVE speedup and the schedule statistics (pool fill,
slot reuse), which are hardware-independent.

Protocol: steady-state (the paper trains tens of thousands of steps, so
compile cost amortizes to zero). We pre-sample a fixed list of mixed-pattern
batches, warm BOTH engines on the same list until their jit caches are
signature-stable, then time pure training-step execution over the list.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.training import AdamConfig, NGDBTrainer, TrainConfig


def run(models=("betae", "q2b", "gqe"),
        datasets=("FB15k",), steps: int = 5, batch: int = 64,
        dim: int = 32) -> None:
    """Headline trio by default (Table 1); pass all five for the full Table 3."""
    for ds in datasets:
        kg, _, stats = load_dataset(ds)
        for name in models:
            rows = {}
            for ex_kind in ("pooled", "query_level"):
                model = make_model(name, ModelConfig(dim=dim, gamma=6.0))
                cfg = TrainConfig(batch_size=batch, n_negatives=16, b_max=256,
                                  prefetch=0, executor=ex_kind,
                                  adam=AdamConfig(lr=1e-3))
                tr = NGDBTrainer(model, kg, cfg)
                batches = [tr.sampler.sample_batch(batch) for _ in range(steps)]
                for b in batches:  # warm every signature once
                    tr.train_step(b)
                t0 = time.perf_counter()
                for b in batches:  # steady state: all signatures compiled
                    tr.train_step(b)
                dt = time.perf_counter() - t0
                rows[ex_kind] = steps * batch / dt
            speedup = rows["pooled"] / rows["query_level"]
            emit(f"tput/{ds}/{name}/pooled_qps", 1e6 / rows["pooled"],
                 f"qps={rows['pooled']:.0f}")
            emit(f"tput/{ds}/{name}/query_level_qps", 1e6 / rows["query_level"],
                 f"qps={rows['query_level']:.0f}")
            emit(f"tput/{ds}/{name}/speedup", 0.0, f"x{speedup:.2f}")


def run_schedule_stats(batch: int = 512) -> None:
    """Memory-side claim (Eq. 7): slot reuse vs query-scoped allocation, and
    the kernel-count claim (Eq. 4/5): pooled steps vs fragmented launches."""
    from repro.core import PooledExecutor, build_batched_dag, schedule
    from repro.sampling import OnlineSampler

    kg, _, _ = load_dataset("FB15k")
    sampler = OnlineSampler(kg, seed=0)
    queries = [b.query for b in sampler.sample_batch(batch)]
    model = make_model("betae", ModelConfig(dim=16))
    ex = PooledExecutor(model, b_max=512)
    prepared = ex.prepare(queries)
    st = prepared.sched.stats
    emit("sched/steps", 0.0, f"{st['steps']}")
    emit("sched/mean_pool_fill", 0.0, f"{st['mean_pool_fill']:.1f}")
    emit("sched/slot_reuse_ratio", 0.0, f"x{st['slot_reuse_ratio']:.2f}")
    emit("sched/pad_waste", 0.0, f"{st['pad_waste']:.3f}")
    # fragmentation comparison: pooled kernel count vs per-pattern grouping
    frag_steps = 0
    groups = {}
    for q in queries:
        groups.setdefault(q.pattern, []).append(q)
    for pat, qs in groups.items():
        frag_steps += len(schedule(build_batched_dag(qs), b_max=512).steps)
    emit("sched/pooled_kernels", 0.0, f"{st['steps']}")
    emit("sched/query_level_kernels", 0.0, f"{frag_steps}")
    emit("sched/kernel_reduction", 0.0, f"x{frag_steps / max(st['steps'],1):.1f}")


if __name__ == "__main__":
    run()
    run_schedule_stats()
