"""Table 3 / Table 1: end-to-end training throughput, operator-level
(NGDB-Zoo) vs query-level (KGReasoning/SQE-style) batching, across backbone
models and datasets. CPU-scale reduction of the paper's protocol; the metric
of record is the RELATIVE speedup and the schedule statistics (pool fill,
slot reuse), which are hardware-independent.

Protocol: steady-state (the paper trains tens of thousands of steps, so
compile cost amortizes to zero). We pre-sample a fixed list of mixed-pattern
batches, warm BOTH engines on the same list until their jit caches are
signature-stable, then time pure training-step execution over the list.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/throughput.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)
    # Pin XLA-CPU to one intra-op thread: applies equally to both engines,
    # leaves a core for the host pipeline, and cuts run-to-run variance on
    # small shared machines. Must be set before jax initializes.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
    ).strip()

import numpy as np

from benchmarks.common import emit
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training import AdamConfig, NGDBTrainer, TrainConfig


def run(models=("betae", "q2b", "gqe"),
        datasets=("FB15k",), steps: int = 5, batch: int = 64,
        dim: int = 32) -> None:
    """Headline trio by default (Table 1); pass all five for the full Table 3."""
    for ds in datasets:
        kg, _, stats = load_dataset(ds)
        for name in models:
            rows = {}
            for ex_kind in ("pooled", "query_level"):
                model = make_model(name, ModelConfig(dim=dim, gamma=6.0))
                cfg = TrainConfig(batch_size=batch, n_negatives=16, b_max=256,
                                  prefetch=0, executor=ex_kind,
                                  adam=AdamConfig(lr=1e-3))
                tr = NGDBTrainer(model, kg, cfg)
                batches = [tr.sampler.sample_batch(batch) for _ in range(steps)]
                for b in batches:  # warm every signature once
                    tr.train_step(b)
                t0 = time.perf_counter()
                for b in batches:  # steady state: all signatures compiled
                    tr.train_step(b)
                dt = time.perf_counter() - t0
                rows[ex_kind] = steps * batch / dt
            speedup = rows["pooled"] / rows["query_level"]
            emit(f"tput/{ds}/{name}/pooled_qps", 1e6 / rows["pooled"],
                 f"qps={rows['pooled']:.0f}")
            emit(f"tput/{ds}/{name}/query_level_qps", 1e6 / rows["query_level"],
                 f"qps={rows['query_level']:.0f}")
            emit(f"tput/{ds}/{name}/speedup", 0.0, f"x{speedup:.2f}")


def _host_parallel_efficiency(seconds: float = 0.8) -> float:
    """How much concurrent progress a Python thread and a GIL-releasing
    compute thread make on this host, summed in units of their solo rates
    (2.0 = two independent cores, 1.0 = a single effective core / no
    overlap possible). The pipelined engine overlaps exactly these two kinds
    of work, so its wall-clock win is physically bounded by this number —
    emitted so the speedup below is interpretable on small/shared machines."""
    import threading

    a = np.random.default_rng(0).normal(size=(384, 384)).astype(np.float32)

    def compute(count, stop):  # numpy matmul releases the GIL
        while not stop[0]:
            (a @ a).sum()
            count[0] += 1

    def python_work(count, stop):  # interpreter-bound, holds the GIL
        x = 0
        while not stop[0]:
            x = (x + 1) % 1000003
            count[0] += 1

    def run(workers) -> List[float]:
        counts = [[0] for _ in workers]
        stop = [False]
        ts = [threading.Thread(target=w, args=(c, stop))
              for w, c in zip(workers, counts)]
        for t in ts:
            t.start()
        time.sleep(seconds)
        stop[0] = True
        for t in ts:
            t.join()
        return [c[0] / seconds for c in counts]

    comp_solo = run([compute])[0]
    py_solo = run([python_work])[0]
    comp_c, py_c = run([compute, python_work])
    return comp_c / max(comp_solo, 1) + py_c / max(py_solo, 1)


def run_pipeline_compare(steps: int = 20, batch: int = 1024, dim: int = 64,
                         model_name: str = "gqe", negatives: int = 32,
                         dataset: str = "FB15k", trials: int = 3) -> float:
    """Sync vs pipelined dataflow execution on an identical end-to-end
    synthetic workload — online sampling → training arrays → Algorithm-1
    scheduling → fused device step (DESIGN.md §Pipeline).

    The batch stream is a seeded sampler: every pass (and both engines) sees
    the exact same batch sequence, so the signature set is fixed and the
    compile cache must report ZERO retraces across all timed passes. Sync
    runs all stages strictly in sequence on one thread (the ablation
    baseline); pipelined overlaps the host stages with device execution.
    Timed passes are interleaved (S,P,S,P,...) so machine-speed drift hits
    both engines equally, and min-time per mode rejects co-tenant noise
    spikes. Steady-state claims: ZERO retraces (asserted — 100% compile
    cache hit rate), and pipelined >= 1.3x sync steps/sec wherever the host
    can actually overlap (reported; physically bounded by the emitted
    host_parallel_efficiency — see DESIGN.md §Pipeline)."""
    eff = _host_parallel_efficiency()
    emit(f"pipeline/{dataset}/{model_name}/host_parallel_efficiency", 0.0,
         f"{eff:.2f} (2.0=two independent cores, 1.0=no overlap possible)")

    kg, _, _ = load_dataset(dataset)
    src = OnlineSampler(kg, seed=7)
    replay = [src.sample_batch(batch) for _ in range(steps)]

    def stream():
        """Deterministic batch source: same sequence every pass."""
        it = iter(replay * 1000)
        return lambda: next(it)

    trainers = {}
    for mode in ("sync", "pipelined"):
        model = make_model(model_name, ModelConfig(dim=dim, gamma=6.0))
        cfg = TrainConfig(batch_size=batch, n_negatives=negatives, b_max=256,
                          prefetch=2, executor="pooled",
                          pipeline=(mode == "pipelined"),
                          adam=AdamConfig(lr=1e-3), seed=0)
        tr = NGDBTrainer(model, kg, cfg)
        tr.train(steps, log_every=0, batches=stream())  # warm every signature
        tr._train_fns.reset_counters()
        trainers[mode] = tr

    best = {"sync": float("inf"), "pipelined": float("inf")}
    for _ in range(max(trials, 1)):
        for mode, tr in trainers.items():
            t0 = time.perf_counter()
            tr.train(steps, log_every=0, batches=stream())  # steady-state
            best[mode] = min(best[mode], time.perf_counter() - t0)

    qps = {}
    for mode, tr in trainers.items():
        qps[mode] = steps * batch / best[mode]
        cc = tr._train_fns.stats()
        emit(f"pipeline/{dataset}/{model_name}/{mode}_steps_per_sec",
             1e6 * best[mode] / steps,
             f"steps/s={steps / best[mode]:.2f} qps={qps[mode]:.0f}")
        emit(f"pipeline/{dataset}/{model_name}/{mode}_cache_hit_rate", 0.0,
             f"{cc['hit_rate']:.2%} ({cc['misses']} retraces)")
        assert cc["misses"] == 0, (
            f"{mode}: {cc['misses']} retraces after warmup — the bucketed "
            f"signature set must be compile-stable on a replayed workload")
    speedup = qps["pipelined"] / qps["sync"]
    emit(f"pipeline/{dataset}/{model_name}/speedup", 0.0, f"x{speedup:.2f}")
    return speedup


def run_schedule_stats(batch: int = 512) -> None:
    """Memory-side claim (Eq. 7): slot reuse vs query-scoped allocation, and
    the kernel-count claim (Eq. 4/5): pooled steps vs fragmented launches."""
    from repro.core import PooledExecutor, build_batched_dag, schedule
    from repro.sampling import OnlineSampler

    kg, _, _ = load_dataset("FB15k")
    sampler = OnlineSampler(kg, seed=0)
    queries = [b.query for b in sampler.sample_batch(batch)]
    model = make_model("betae", ModelConfig(dim=16))
    ex = PooledExecutor(model, b_max=512)
    prepared = ex.prepare(queries)
    st = prepared.sched.stats
    emit("sched/steps", 0.0, f"{st['steps']}")
    emit("sched/mean_pool_fill", 0.0, f"{st['mean_pool_fill']:.1f}")
    emit("sched/slot_reuse_ratio", 0.0, f"x{st['slot_reuse_ratio']:.2f}")
    emit("sched/pad_waste", 0.0, f"{st['pad_waste']:.3f}")
    # fragmentation comparison: pooled kernel count vs per-pattern grouping
    frag_steps = 0
    groups = {}
    for q in queries:
        groups.setdefault(q.pattern, []).append(q)
    for pat, qs in groups.items():
        frag_steps += len(schedule(build_batched_dag(qs), b_max=512).steps)
    emit("sched/pooled_kernels", 0.0, f"{st['steps']}")
    emit("sched/query_level_kernels", 0.0, f"{frag_steps}")
    emit("sched/kernel_reduction", 0.0, f"x{frag_steps / max(st['steps'],1):.1f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare", action="store_true",
                    help="sync vs pipelined dataflow executor + cache hit rate")
    ap.add_argument("--steps", type=int, default=15)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--negatives", type=int, default=32)
    ap.add_argument("--model", default="gqe")
    ap.add_argument("--trials", type=int, default=3)
    args = ap.parse_args()
    if args.compare:
        run_pipeline_compare(steps=args.steps, batch=args.batch, dim=args.dim,
                             model_name=args.model, negatives=args.negatives,
                             trials=args.trials)
    else:
        run()
        run_schedule_stats()
