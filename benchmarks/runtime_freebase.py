"""Table 2: single-hop (KG completion) runtime — ComplEx d=100 on a Freebase
stand-in. Reports epoch time on this host plus derived triples/sec; the
multi-GPU columns of Table 2 are covered structurally by benchmarks/scaling.py
(per-device FLOPs halve per device-count doubling)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import QueryInstance
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.training import AdamConfig, NGDBTrainer, TrainConfig


def run(batch: int = 256, epoch_triples: int = 2048, dim: int = 100) -> None:
    kg, _, _ = load_dataset("FB15k")  # reduced Freebase-family stand-in
    model = make_model("complex", ModelConfig(dim=dim, gamma=6.0))
    cfg = TrainConfig(batch_size=batch, n_negatives=32, b_max=512, prefetch=0,
                      patterns=("1p",), adam=AdamConfig(lr=1e-3))
    tr = NGDBTrainer(model, kg, cfg)
    tr.train_step()  # warmup/compile
    steps = max(epoch_triples // batch, 1)
    t0 = time.perf_counter()
    for _ in range(steps):
        tr.train_step()
    dt = time.perf_counter() - t0
    emit("freebase/epoch_time_s", dt * 1e6 / steps, f"total={dt:.2f}s")
    emit("freebase/triples_per_sec", 0.0, f"{steps * batch / dt:.0f}")
    emit("freebase/model", 0.0, f"complex_d{dim}")


if __name__ == "__main__":
    run()
