"""Fig 7 / Table 2: multi-device scaling. The container has one physical CPU
core, so wall-clock multi-GPU scaling is not measurable; instead we verify the
paper's near-linear-scaling claim STRUCTURALLY: lower the data-parallel NGDB
train step onto 1/2/4/8-device meshes (placeholder host devices in a
subprocess) and report per-device FLOPs + collective wire bytes. Near-linear
scaling == per-device FLOPs ~halve per doubling with collective bytes a small
constant (the gradient all-reduce).

The step is a true DP shard_map: every device runs the operator-level
schedule on ITS OWN query shard (per-shard index arrays stacked on the mesh
axis), then gradients psum — the paper's multi-GPU execution model."""
from __future__ import annotations

import json
import subprocess
import sys

from benchmarks.common import emit

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.core import PooledExecutor
from repro.sampling import OnlineSampler
from repro.lm.moe import shard_map  # version-bridging wrapper
from repro.training.loss import negative_sampling_loss
from repro.training.optim import AdamConfig, adam_init, adam_update
from repro.launch.roofline import parse_collectives

kg, _, _ = load_dataset("FB15k")
model = make_model("betae", ModelConfig(dim=64))
B_SHARD = 32   # queries per device (weak scaling: global batch = n * 32)
N_NEG = 16
ex = PooledExecutor(model, b_max=256)
params = model.init_params(jax.random.PRNGKey(0), kg.n_entities, kg.n_relations)
opt = adam_init(params)
adam = AdamConfig(lr=1e-4)

# identical pattern multiset per shard => one schedule signature for all
# shards; only the anchor/relation bindings (and pos/neg ids) differ.
from repro.core import TEMPLATES, QueryInstance
PATS = list(TEMPLATES)

def shard_args(seed):
    rng = np.random.default_rng(seed)
    qs = []
    for i in range(B_SHARD):
        t = TEMPLATES[PATS[i % len(PATS)]]
        qs.append(QueryInstance(PATS[i % len(PATS)],
                                rng.integers(0, kg.n_entities, t.n_anchors),
                                rng.integers(0, kg.n_relations, t.n_relations)))
    prepared = ex.prepare(qs)
    pos = rng.integers(0, kg.n_entities, B_SHARD)
    neg = rng.integers(0, kg.n_entities, (B_SHARD, N_NEG))
    return prepared, prepared.device_args(), pos, neg

out = {}
for n in (1, 2, 4, 8):
    mesh = jax.make_mesh((n,), ("data",))
    sh_prepared, (steps0, ans0), _, _ = shard_args(0)
    encode = ex.encode_fn(sh_prepared)
    # stack per-shard schedule bindings on the mesh axis
    all_steps, all_pos, all_neg = [], [], []
    for i in range(n):
        _, (st, an), pos, neg = shard_args(i)
        all_steps.append(st)
        all_pos.append(pos)
        all_neg.append(neg)
    steps_stacked = jax.tree.map(lambda *xs: np.stack(xs), *all_steps)
    pos_s = np.stack(all_pos); neg_s = np.stack(all_neg)

    def local_step(params, opt_state, steps, pos, neg):
        steps = jax.tree.map(lambda a: a[0], steps)   # drop shard dim
        def loss_fn(p):
            q = encode(p, steps, jnp.asarray(ans0))
            return negative_sampling_loss(model, p, q, pos[0], neg[0])[0]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.lax.pmean(grads, "data")          # gradient all-reduce
        params, opt_state = adam_update(grads, opt_state, params, adam)
        return params, opt_state, jax.lax.pmean(loss, "data")

    fn = shard_map(local_step, mesh,
                   in_specs=(P(), P(), P("data"), P("data"), P("data")),
                   out_specs=(P(), P(), P()))
    with mesh:
        c = jax.jit(fn).lower(params, opt, steps_stacked, pos_s, neg_s).compile()
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)): cost = cost[0]
    coll = parse_collectives(c.as_text(), n)
    out[n] = {"flops": cost.get("flops", 0.0), "wire": coll.wire_bytes}
print("RESULT " + json.dumps(out))
"""


def run() -> None:
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, timeout=1200, cwd=".")
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    if not line:
        emit("scaling/error", 0.0, r.stderr[-200:].replace(",", ";").replace("\n", " "))
        return
    data = json.loads(line[0][len("RESULT "):])
    f1 = data["1"]["flops"]
    for n in ("1", "2", "4", "8"):
        d = data[n]
        # weak scaling: per-device work should stay ~f1 as devices grow
        eff = f1 / d["flops"] if d["flops"] else 0.0
        emit(f"scaling/{n}dev_flops_per_dev", 0.0, f"{d['flops']:.3e}")
        emit(f"scaling/{n}dev_weak_efficiency", 0.0, f"{eff:.2f}")
        emit(f"scaling/{n}dev_wire_bytes", 0.0, f"{d['wire']:.3e}")


if __name__ == "__main__":
    run()
