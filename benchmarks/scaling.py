"""Fig 7 / Table 2: multi-device scaling, now through the ExecutionContext.

The container has one physical CPU core, so wall-clock multi-device speedup
is not measurable; what IS measurable — and what this sweep asserts — is the
paper's scaling *invariants* on emulated host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, in a subprocess so
the parent's device state is untouched):

* **correctness** — pipelined sharded training (mesh ``data=N``, fsdp
  profile) reproduces the single-device sync per-step losses within float
  tolerance on the SAME replayed batches, for every device count;
* **memory** — the entity table's per-device bytes are exactly 1/N of the
  logical table (the fsdp profile shards its row dim over the data axis;
  ``entity_pad`` keeps the rows divisible);
* **compile stability** — after one pass over the batch signatures, the
  train-step compile cache hit rate is 100%: ZERO steady-state retraces on
  any mesh shape.

The summary (per-device param/entity bytes, steps/s, retrace counts) lands
in ``BENCH_scaling.json`` at the repo root so the perf trajectory
accumulates across PRs; violated invariants raise, so CI fails loudly when
invoked directly (``benchmarks/run.py`` converts the raise into an ERROR
CSV row per its contract).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_scaling.json")

DEVICE_COUNTS = (1, 2, 4, 8)

# __DEVICE_COUNTS__ / __MAX_DEVICES__ are substituted below so the sweep,
# the emulated-device count and run()'s assertions share one source of truth.
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=__MAX_DEVICES__"
import sys, json, time
sys.path.insert(0, "src")
import jax, numpy as np
from repro.data import generate_synthetic_kg
from repro.distributed.context import ExecutionContext, make_execution_context
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training import AdamConfig, NGDBTrainer, TrainConfig

E, R, DIM, B, NEG = 4096, 12, 32, 32, 8
WARMUP, MEASURE = 4, 12
STEPS = WARMUP + MEASURE
kg = generate_synthetic_kg(E, R, 16000, seed=0)
sampler = OnlineSampler(kg, seed=7)
batches = [sampler.sample_batch(B) for _ in range(4)]  # fixed replay workload

# Unique train-step signatures of the replay workload (host-side probe): a
# run with ZERO steady-state retraces traces exactly this many programs.
from repro.core import PooledExecutor
probe_model = make_model("gqe", ModelConfig(dim=DIM, entity_pad=8))
probe = PooledExecutor(probe_model, b_max=512)
N_SIGS = len({probe.prepare([q.query for q in b]).signature for b in batches})

def make_trainer(ctx, pipeline):
    model = make_model("gqe", ModelConfig(dim=DIM, entity_pad=8))
    cfg = TrainConfig(batch_size=B, n_negatives=NEG, adam=AdamConfig(lr=1e-3),
                      pipeline=pipeline, seed=0)
    return NGDBTrainer(model, kg, cfg, ctx=ctx)

def run_all(tr):
    # ONE train() call per trainer: the negative-sampling RNG draws then
    # happen in deterministic item order for sync and pipelined alike (a
    # second call would see RNG state advanced by however far the first
    # call had prefetched ahead). Measured-window throughput comes from the
    # per-step records, so warmup compiles are excluded.
    tr.train(STEPS, log_every=0, batches=batches)
    jax.block_until_ready(tr.params)
    dur = sum(B / r["queries_per_sec"] for r in tr.history[WARMUP:])
    return MEASURE / dur, [r["loss"] for r in tr.history]

def per_device_bytes(params):
    ent = params["entity"]
    total = sum(p.nbytes for p in jax.tree.leaves(params))
    per_dev = sum(p.addressable_shards[0].data.nbytes
                  for p in jax.tree.leaves(params))
    return {"entity_bytes_total": int(ent.nbytes),
            "entity_bytes_per_device": int(ent.addressable_shards[0].data.nbytes),
            "param_bytes_total": int(total),
            "param_bytes_per_device": int(per_dev)}

# Baseline: single-device sync — the loss reference for every mesh shape.
base_sps, base_losses = run_all(
    make_trainer(ExecutionContext.single_device(), pipeline=False))

out = {"config": {"entities": E, "dim": DIM, "batch": B, "negatives": NEG,
                  "warmup_steps": WARMUP, "measure_steps": MEASURE,
                  "unique_signatures": N_SIGS,
                  "profile": "fsdp", "pipeline": True},
       "single_device_sync": {"steps_per_s": base_sps,
                              "losses": base_losses},
       "devices": {}}

for n in __DEVICE_COUNTS__:
    ctx = make_execution_context(f"data={n}", profile="fsdp")
    tr = make_trainer(ctx, pipeline=True)
    sps, tr_losses = run_all(tr)
    cc = tr.compile_cache_stats()["train_step"]
    # Every signature appears within the first replay cycle (= warmup), so
    # any trace beyond N_SIGS is a steady-state retrace.
    retraces = int(cc["misses"]) - N_SIGS
    rec = per_device_bytes(tr.params)
    rec.update({
        "steps_per_s": sps,
        "warmup_traces": N_SIGS,
        "steady_retraces": retraces,
        "steady_hit_rate": 1.0 if retraces == 0 else
            1.0 - retraces / max(STEPS - N_SIGS, 1),
        "loss_max_abs_diff_vs_single": float(np.abs(
            np.array(tr_losses) - np.array(base_losses)).max()),
        "entity_sharding": str(tr.params["entity"].sharding.spec),
    })
    out["devices"][str(n)] = rec

print("RESULT " + json.dumps(out))
"""


def run(out_path: str = _DEFAULT_OUT) -> dict:
    script = (_SCRIPT
              .replace("__DEVICE_COUNTS__", repr(tuple(DEVICE_COUNTS)))
              .replace("__MAX_DEVICES__", str(max(DEVICE_COUNTS))))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=1800, cwd=_REPO_ROOT)
    lines = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    try:
        data = json.loads(lines[0][len("RESULT "):]) if lines else None
    except json.JSONDecodeError:
        data = None
    if data is None:
        # Publish the failed verdict BEFORE raising: a stale ok=true file
        # from a previous good run must not satisfy CI's json check when the
        # sweep itself never produced a result.
        with open(out_path, "w") as f:
            json.dump({"ok": False,
                       "failures": ["sweep subprocess produced no RESULT"],
                       "stderr_tail": r.stderr[-2000:]}, f, indent=1)
        emit("scaling/error", 0.0,
             r.stderr[-300:].replace(",", ";").replace("\n", " "))
        raise RuntimeError(f"scaling sweep produced no RESULT: {r.stderr[-2000:]}")

    failures = []
    for n in map(str, DEVICE_COUNTS):
        d = data["devices"][n]
        # Acceptance invariants (ISSUE 3): parity, 1/N memory, zero retraces.
        if d["loss_max_abs_diff_vs_single"] > 2e-3:
            failures.append(f"{n}dev loss diverges from single-device sync "
                            f"by {d['loss_max_abs_diff_vs_single']:.2e}")
        if d["entity_bytes_per_device"] * int(n) != d["entity_bytes_total"]:
            failures.append(
                f"{n}dev entity bytes/device {d['entity_bytes_per_device']} "
                f"!= 1/{n} of {d['entity_bytes_total']}")
        if d["steady_retraces"] != 0 or d["steady_hit_rate"] < 1.0:
            failures.append(f"{n}dev retraced after warmup "
                            f"({d['steady_retraces']} traces, hit rate "
                            f"{d['steady_hit_rate']:.2%})")
        emit(f"scaling/{n}dev_steps_per_s", 0.0, f"{d['steps_per_s']:.2f}")
        emit(f"scaling/{n}dev_entity_bytes_per_dev", 0.0,
             f"{d['entity_bytes_per_device']}")
        emit(f"scaling/{n}dev_param_bytes_per_dev", 0.0,
             f"{d['param_bytes_per_device']}")
        emit(f"scaling/{n}dev_steady_retraces", 0.0, f"{d['steady_retraces']}")
        emit(f"scaling/{n}dev_loss_max_abs_diff", 0.0,
             f"{d['loss_max_abs_diff_vs_single']:.2e}")

    data["ok"] = not failures
    data["failures"] = failures
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1)
    emit("scaling/summary_json", 0.0, os.path.relpath(out_path, _REPO_ROOT))
    assert not failures, "; ".join(failures)
    return data


if __name__ == "__main__":
    run()
