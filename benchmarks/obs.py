"""§Observability overhead gate: tracing must be free when off, cheap when on.

The telemetry layer (DESIGN.md §Observability) lives permanently in the hot
paths — pipeline scheduler, serving batcher, dispatch loop — so its cost
contract is part of the perf surface and gets the same treatment as the
compiler and serving invariants:

* **disabled fast path** — a disabled ``TRACER.span()`` is one attribute
  read + one shared-null-context return; the micro-benchmark asserts it
  stays under 2 µs/call (it measures ~100 ns in practice), i.e. no
  measurable steady-state cost at realistic span rates (~10 spans/step);
* **bit-identity** — enabling tracing must not perturb numerics: two fresh
  trainers on the SAME replayed workload, tracing off vs on, produce
  EXACTLY equal loss sequences (float equality, pipelined mode);
* **enabled overhead** — paired trials of the steady-state pipelined
  replay through ONE warmed trainer, tracing toggled per pass: the gate is
  the median of per-trial on/off time ratios (correlated machine noise
  cancels within a pair), and it must stay ≤ ~2%. Measured without the
  ``jax.profiler.TraceAnnotation`` bridge (``jax_annotations=False``) —
  the bridge is for correlating lanes against a simultaneously captured
  JAX device profile, where the profiler's own overhead dwarfs it;
* **trace completeness** — a short sampler-driven pipelined run and a
  serving replay each yield a validating trace (``validate_trace``: the
  rules Perfetto's JSON importer enforces) with ≥ 4 named thread lanes and
  the full span vocabulary for their side of the system.

The summary lands in ``BENCH_obs.json`` at the repo root (committed); any
violated invariant publishes ``ok: false`` BEFORE raising, so a stale green
verdict can never survive a crashed run.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/obs.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

from benchmarks.common import emit
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.obs import TRACER, validate_trace
from repro.sampling import OnlineSampler
from repro.training import AdamConfig, NGDBTrainer, TrainConfig

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_obs.json")

#: Span names a pipelined train trace / serving trace must contain.
TRAIN_SPANS = {"sample", "schedule", "transfer", "pipeline_wait", "compile",
               "dispatch", "retire"}
SERVE_SPANS = {"request", "batch", "encode", "score", "select"}


def run(steps: int = 10, batch: int = 128, dim: int = 64,
        dataset: str = "FB15k", trials: int = 8,
        out_path: str = _DEFAULT_OUT) -> dict:
    summary = {"ok": False, "suite": "obs", "dataset": dataset,
               "failures": []}

    def publish():
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")

    try:
        _run_inner(summary, steps, batch, dim, dataset, trials)
        summary["ok"] = not summary["failures"]
    except BaseException as e:
        summary["failures"].append(f"{type(e).__name__}: {e}")
        publish()
        raise
    finally:
        TRACER.disable()
    publish()
    return summary


def _make_trainer(kg, dim, batch, seed=0):
    cfg = TrainConfig(batch_size=batch, n_negatives=8, b_max=128,
                      adam=AdamConfig(lr=1e-3), seed=seed, prefetch=2,
                      pipeline=True)
    return NGDBTrainer(make_model("gqe", ModelConfig(dim=dim, gamma=6.0)),
                       kg, cfg)


def _run_inner(summary, steps, batch, dim, dataset, trials):
    kg, _, _ = load_dataset(dataset)
    summary.update({"batch_size": batch, "steps": steps, "trials": trials})

    # -- disabled fast path: span() when tracing is off ------------------
    TRACER.disable()
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with TRACER.span("probe"):
            pass
    ns = (time.perf_counter() - t0) / n * 1e9
    summary["disabled_span_ns"] = round(ns, 1)
    emit("obs/disabled_span", ns / 1e3, f"{ns:.0f} ns/span (off)")
    if ns > 2000:
        summary["failures"].append(
            f"disabled span() costs {ns:.0f} ns/call > 2 µs — the off "
            f"path is no longer a single attribute read")

    # -- bit-identity: tracing on must not perturb numerics --------------
    batches = [OnlineSampler(kg, seed=29).sample_batch(batch)
               for _ in range(4)]

    def stream():
        it = itertools.cycle(batches)
        return lambda: next(it)

    losses = {}
    for on in (False, True):
        if on:
            TRACER.enable(jax_annotations=False)
        else:
            TRACER.disable()
        tr = _make_trainer(kg, dim, batch)
        tr.train(steps, log_every=0, batches=stream())
        losses[on] = [h["loss"] for h in tr.history]
    TRACER.disable()
    summary["loss_bitwise"] = losses[False] == losses[True]
    emit(f"obs/{dataset}/loss_bitwise", 0.0, str(summary["loss_bitwise"]))
    if not summary["loss_bitwise"]:
        summary["failures"].append(
            f"tracing perturbs the loss sequence: off={losses[False]} "
            f"on={losses[True]}")

    # -- enabled overhead: steady-state pipelined replay, off vs on ------
    # ONE warmed trainer, tracing toggled per timed pass: the two modes
    # share every byte of host state (caches, allocator layout, threads),
    # so the measured delta is the tracer's cost plus symmetric noise —
    # separate per-mode trainer objects would bake object-level luck into
    # the comparison.
    replay = _make_trainer(kg, dim, batch)
    replay.train(steps, log_every=0, batches=stream())  # warm signatures
    best = {False: float("inf"), True: float("inf")}
    deltas = []  # per-trial paired overhead: t_on / t_off - 1

    def _round(n):
        for t in range(max(n, 1)):
            # Each trial times both modes back-to-back (rotated order), and
            # the gate statistic is the MEDIAN of per-trial paired ratios:
            # container-level throttling hits both halves of a pair
            # near-identically and cancels in the ratio, rotation cancels
            # the within-pair order bias, and the median discards the
            # passes a noisy neighbour stomped on. Raw best-of minima are
            # reported for context but carry ±4% run-to-run variance here.
            order = (False, True) if t % 2 == 0 else (True, False)
            pair = {}
            for on in order:
                if on:
                    TRACER.enable(jax_annotations=False)
                else:
                    TRACER.disable()
                t0 = time.perf_counter()
                replay.train(steps, log_every=0, batches=stream())
                pair[on] = time.perf_counter() - t0
                best[on] = min(best[on], pair[on])
            deltas.append(pair[True] / pair[False] - 1.0)

    # A borderline verdict on a noisy box means too few samples, not a
    # looser gate: escalate with more paired rounds before declaring the
    # 2% contract broken.
    rounds = 0
    while True:
        _round(trials)
        rounds += 1
        overhead = sorted(deltas)[len(deltas) // 2]
        if overhead <= 0.02 or rounds >= 3:
            break
    TRACER.disable()
    qps_off = steps * batch / best[False]
    qps_on = steps * batch / best[True]
    summary["overhead_rounds"] = rounds
    summary["qps"] = {"tracing_off": round(qps_off, 1),
                      "tracing_on": round(qps_on, 1)}
    summary["tracing_overhead_frac"] = round(overhead, 4)
    emit(f"obs/{dataset}/pipelined_overhead", 1e6 * best[True] / steps,
         f"off={qps_off:.0f} on={qps_on:.0f} q/s "
         f"(overhead {overhead:.1%})")
    if overhead > 0.02:
        summary["failures"].append(
            f"tracing costs {overhead:.1%} pipelined throughput (median of "
            f"{len(deltas)} paired on/off trials; best-of off={qps_off:.0f} "
            f"on={qps_on:.0f} q/s) — contract: <= 2%")

    # -- trace completeness: pipelined train (4 lanes + full vocabulary) --
    # One trace covering both feed modes: the warmed replay trainer emits
    # steady-state "dispatch" spans (every signature hot), and a fresh
    # sampler-driven trainer emits "compile" spans plus the sampling-worker
    # lanes (pinned-batch mode runs a single pump thread instead).
    TRACER.enable(jax_annotations=False)
    replay.train(steps, log_every=0, batches=stream())
    tr = _make_trainer(kg, 16, batch, seed=31)
    tr.train(3, log_every=0)  # no pinned batches: sampling workers run
    train_trace = TRACER.to_json()
    TRACER.disable()
    _check_trace(summary, "train", train_trace, TRAIN_SPANS)

    _serving_trace(summary, kg)


def _check_trace(summary, tag, obj, want_spans):
    try:
        s = validate_trace(obj)
    except ValueError as e:
        summary["failures"].append(f"{tag} trace invalid: {e}")
        return
    lanes = set(s["lanes"])
    names = set(s["names"])
    summary[f"{tag}_trace"] = {"n_events": s["n_events"],
                               "lanes": sorted(lanes),
                               "span_names": sorted(names)}
    emit(f"obs/{tag}_trace", 0.0,
         f"{s['n_events']} events | {len(lanes)} lanes")
    if len(lanes) < 4:
        summary["failures"].append(
            f"{tag} trace has {len(lanes)} named lanes {sorted(lanes)} < 4")
    missing = want_spans - names
    if missing:
        summary["failures"].append(
            f"{tag} trace is missing spans: {sorted(missing)} "
            f"(got {sorted(names)})")


def _serving_trace(summary, kg):
    import jax

    from repro.core import PooledExecutor
    from repro.serving import (ServingConfig, ServingEngine, make_workload,
                               run_closed_loop)

    model = make_model("gqe", ModelConfig(dim=16, gamma=6.0))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    engine = ServingEngine(model, params,
                           executor=PooledExecutor(model, b_max=128),
                           cfg=ServingConfig(max_batch=16))
    try:
        workload = make_workload(kg, 64, seed=7)
        run_closed_loop(engine, workload, concurrency=16)  # warm signatures
        engine.reset_counters()
        TRACER.enable()
        TRACER.set_lane("loadgen main")
        run_closed_loop(engine, workload, concurrency=16, threads=3)
        serve_trace = TRACER.to_json()
        TRACER.disable()
        _check_trace(summary, "serving", serve_trace, SERVE_SPANS)
    finally:
        engine.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--dataset", default="FB15k")
    args = ap.parse_args()
    run(steps=args.steps, batch=args.batch, dim=args.dim,
        dataset=args.dataset, trials=args.trials)


if __name__ == "__main__":
    main()
