"""Fig 9: adaptive vs uniform sampling under a steered (non-stationary)
query workload — the hard pattern family flips every ``shift_every`` steps.

Metric: per-query loss on a FIXED held-out probe batch of the currently-hard
family, evaluated after training. (Comparing *training* loss would be
confounded: the adaptive sampler deliberately samples more hard queries,
which raises its own training loss while lowering probe loss.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training import AdamConfig, NGDBTrainer, TrainConfig
from repro.training.loss import negative_sampling_loss


def _probe_loss(tr, probe):
    queries, pos, neg = tr.sampler.to_training_arrays(probe, 8)
    prepared = tr.executor.prepare(queries)
    encode = tr.executor.encode_fn(prepared)
    steps, ans = prepared.device_args()
    q = encode(tr.params, steps, ans)
    loss, _ = negative_sampling_loss(tr.model, tr.params, q,
                                     jnp.asarray(pos[prepared.order]),
                                     jnp.asarray(neg[prepared.order]))
    return float(loss)


def run(steps: int = 16, shift_every: int = 8, batch: int = 24) -> None:
    kg, _, _ = load_dataset("FB15k-237")
    hard = "3p"  # the final phase's hard family
    probe = OnlineSampler(kg, patterns=(hard,), seed=99).sample_batch(24)
    results = {}
    for adaptive in (False, True):
        model = make_model("gqe", ModelConfig(dim=24, gamma=6.0))
        cfg = TrainConfig(batch_size=batch, n_negatives=8, b_max=64,
                          prefetch=0, patterns=("1p", "2p", "3p", "2i"),
                          adaptive=adaptive, adam=AdamConfig(lr=3e-3))
        tr = NGDBTrainer(model, kg, cfg)
        for step in range(steps):
            if tr.adaptive and step % shift_every == 0:
                # steered workload: difficulty spikes on the hard family
                phase = (step // shift_every) % 2
                tr.adaptive.update({hard: 5.0} if phase else {"2i": 5.0})
            tr.train_step()
        results[adaptive] = _probe_loss(tr, probe)
    emit("adaptive/probe_loss_uniform", 0.0, f"{results[False]:.4f}")
    emit("adaptive/probe_loss_adaptive", 0.0, f"{results[True]:.4f}")
    rel = (results[False] - results[True]) / abs(results[False]) * 100
    emit("adaptive/relative_improvement_pct", 0.0, f"{rel:.1f}")


if __name__ == "__main__":
    run()
