"""§Autotuner gate: tuning must be invisible to numerics and to the trace
budget, and never slower than the hand-picked defaults.

Bench boxes are noisy, so the HARD gates are invariants — wall-clock is
reported honestly but never gates alone (DESIGN.md §Autotuner):

* **bit-identity** — for every swept shape bucket, the tuned config's
  output is ``np.array_equal`` to the default-tile path (this holds by
  construction: the sweep rejects any candidate that differs by a bit, so
  the gate re-verifies the construction end-to-end through the public
  wrappers) and agrees with the pure-jnp ``kernels/ref.py`` oracle within
  fp32 tolerance. Tuned-vs-ref is NOT gated bitwise: the oracle reduces in
  one association while the kernel k-loops in tiles — the same ulp-level
  relationship the seed engine always had (and the tuner never changes bk,
  so tuning cannot move it).
* **zero steady-state retraces** — with kernel-aware bucketing ENABLED (a
  real, non-empty ``PoolTilePolicy`` snapshotted by the executors), a
  replayed workload compiles nothing after warmup in BOTH sync and
  pipelined modes, and encodes are bitwise vs an untuned executor (pool
  padding may shrink, but real rows never change).
* **tuned never slower** — paired trials per tuned bucket, default and
  tuned configs timed back-to-back in rotated order: the median of
  per-trial default/tuned ratios must be ≥ 1.0 in aggregate (buckets where
  the sweep kept the default contribute exactly 1.0), with per-bucket
  medians allowed a small paired-noise floor after escalation.
* **persisted cache round-trip** — a second tuner constructed from the
  saved JSON serves every bucket with ZERO sweeps and identical configs.

The summary lands in ``BENCH_autotune.json`` at the repo root (committed);
any violated invariant publishes ``ok: false`` BEFORE raising, so a stale
green verdict can never survive a crashed run. The launch-environment
report (tcmalloc/XLA flags actually live in this process) is recorded for
context.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import sys
import tempfile
import time

if __package__ in (None, ""):  # direct `python benchmarks/autotune.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import numpy as np

from benchmarks.common import emit
from repro.kernels import autotune as at
from repro.launch.env import current_report

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_autotune.json")

#: Shape buckets swept by the gate: one small + one production-shaped
#: bucket per op (the trainer gates below add the pool-ladder intersect
#: buckets on top via ``tune_for_model``).
BUCKETS = {
    "scoring": [(32, 512, 32), (128, 2048, 64)],
    "intersect": [(16, 2, 64, 128), (128, 3, 32, 64)],
    "gather_fuse": [(16, 16, 8, 4), (64, 32, 16, 8)],
}


def run(steps: int = 6, batch: int = 64, dim: int = 16, trials: int = 6,
        dataset: str = "FB15k", out_path: str = _DEFAULT_OUT) -> dict:
    summary = {"ok": False, "suite": "autotune", "dataset": dataset,
               "failures": [], "env": current_report()}

    def publish():
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")

    prev = at.set_tuner(None)
    try:
        _run_inner(summary, steps, batch, dim, trials, dataset)
        summary["ok"] = not summary["failures"]
    except BaseException as e:
        # Publish the red verdict first: a crashed run must not leave a
        # stale ok=true on disk for CI's ok-check to read.
        summary["failures"].append(f"{type(e).__name__}: {e}")
        publish()
        raise
    finally:
        at.set_tuner(prev)
    publish()
    return summary


def _effective(op, bucket, cfg):
    """The config the ops wrapper actually executes after clamping tiles to
    the row bucket (``at.row_block``). A tuned config that clamps to the
    same effective tiles as the default runs the SAME kernel launch — a
    paired timing of the two would measure pure host noise."""
    if op == "scoring":
        B, N, _ = bucket
        return {"bm": at.row_block(B, cfg["bm"], 8)[0],
                "bn": at.row_block(N, cfg["bn"], at.LANE)[0],
                "bk": cfg["bk"]}
    if op == "intersect":
        return {"bn": at.row_block(bucket[0], cfg["bn"], 8)[0]}
    return {"rows": at.row_block(bucket[0], cfg["rows"], 1)[0]}


def _run_inner(summary, steps, batch, dim, trials, dataset):
    tmpdir = tempfile.mkdtemp(prefix="autotune_bench_")
    cache_path = os.path.join(tmpdir, "tiles.json")
    tuner = at.KernelTuner(path=cache_path, iters=2, warmup=1)
    summary.update({"steps": steps, "batch": batch, "trials": trials})

    # -- sweep + bit-identity vs default tiles and the ref oracle --------
    t0 = time.perf_counter()
    summary["buckets"] = {}
    for op, buckets in BUCKETS.items():
        for bucket in buckets:
            cfg = tuner.tune(op, bucket)
            tag = f"{op}/{'x'.join(map(str, bucket))}"
            run_fn, args = at._make_runner(op, bucket, "float32", True)
            tuned_out = np.asarray(run_fn(cfg, *args))
            default_out = np.asarray(run_fn(at.DEFAULTS[op], *args))
            bitwise = bool(np.array_equal(tuned_out, default_out))
            ref_out = _ref_out(op, bucket, args)
            ref_diff = float(np.max(np.abs(tuned_out - ref_out)))
            summary["buckets"][tag] = {
                "config": cfg, "default": at.DEFAULTS[op],
                "bitwise_vs_default": bitwise,
                "ref_max_diff": ref_diff,
                "ref_bitwise": bool(np.array_equal(tuned_out, ref_out)),
            }
            emit(f"autotune/{tag}", 0.0,
                 f"cfg={cfg} bitwise={bitwise} ref_diff={ref_diff:.1e}")
            if not bitwise:
                summary["failures"].append(
                    f"{tag}: tuned config {cfg} output differs bitwise from "
                    f"the default tiles — tile choice moved numerics")
            if ref_diff > 5e-4:
                summary["failures"].append(
                    f"{tag}: tuned output drifts {ref_diff:.2e} > 5e-4 from "
                    f"the ref oracle")
    summary["sweep_s"] = round(time.perf_counter() - t0, 2)
    summary["sweeps_run"] = int(tuner.sweeps)
    summary["verify_rejects"] = int(tuner.verify_rejects)

    # -- tuned never slower: paired default-vs-tuned trials per bucket ---
    ratios_all = []
    summary["paired_ratio"] = {}
    for tag, info in summary["buckets"].items():
        op = tag.split("/")[0]
        bucket = tuple(int(v) for v in tag.split("/")[1].split("x"))
        if (_effective(op, bucket, info["config"])
                == _effective(op, bucket, info["default"])):
            # Same effective tiles after the wrapper's clamp: tuned IS the
            # default launch, ratio exactly 1 by construction.
            summary["paired_ratio"][tag] = 1.0
            ratios_all.extend([1.0] * trials)
            continue
        run_fn, args = at._make_runner(op, bucket, "float32", True)
        for cfg in (info["default"], info["config"]):
            np.asarray(run_fn(cfg, *args))  # compile outside the timed pairs
        ratios = []
        rounds = 0
        while True:
            for t in range(max(trials, 1)):
                # Rotated pair order: neither config systematically eats the
                # cold-cache/frequency hit; correlated machine noise cancels
                # in the per-trial ratio.
                order = ([info["default"], info["config"]] if t % 2 == 0
                         else [info["config"], info["default"]])
                times = {}
                for cfg in order:
                    t1 = time.perf_counter()
                    np.asarray(run_fn(cfg, *args))
                    times[json.dumps(cfg, sort_keys=True)] = (
                        time.perf_counter() - t1)
                ratios.append(
                    times[json.dumps(info["default"], sort_keys=True)]
                    / times[json.dumps(info["config"], sort_keys=True)])
            rounds += 1
            med = sorted(ratios)[len(ratios) // 2]
            # Borderline on a noisy box = too few samples: escalate before
            # declaring the tuned config a regression.
            if med >= 1.0 or rounds >= 3:
                break
        summary["paired_ratio"][tag] = round(med, 4)
        ratios_all.extend(ratios)
        emit(f"autotune/{tag}/paired", 0.0,
             f"default/tuned median x{med:.3f} over {len(ratios)} pairs")
        if med < 0.95:
            summary["failures"].append(
                f"{tag}: tuned config is {1/med:.2f}x SLOWER than default "
                f"(median of {len(ratios)} paired trials) — the sweep "
                f"picked a regression")
    agg = sorted(ratios_all)[len(ratios_all) // 2]
    summary["paired_ratio_median"] = round(agg, 4)
    if agg < 1.0:
        summary["failures"].append(
            f"aggregate tuned-vs-default paired-trial median ratio "
            f"{agg:.3f} < 1.0 — tuning made the kernel pool slower overall")

    # -- persisted cache round-trip: second run sweeps NOTHING -----------
    tuner2 = at.KernelTuner(path=cache_path, iters=2, warmup=1)
    mismatch = []
    for op, buckets in BUCKETS.items():
        for bucket in buckets:
            c2 = tuner2.tune(op, bucket)  # cached -> must not sweep
            if c2 != summary["buckets"][
                    f"{op}/{'x'.join(map(str, bucket))}"]["config"]:
                mismatch.append((op, bucket))
    summary["second_run_sweeps"] = int(tuner2.sweeps)
    summary["cache_entries"] = len(tuner2)
    emit("autotune/cache_roundtrip", 0.0,
         f"{len(tuner2)} entries, {int(tuner2.sweeps)} sweeps on reload")
    if int(tuner2.sweeps) != 0:
        summary["failures"].append(
            f"second run re-swept {int(tuner2.sweeps)} buckets — the "
            f"persisted cache did not serve them")
    if mismatch:
        summary["failures"].append(
            f"persisted configs differ after reload: {mismatch}")
    if tuner2.load_error:
        summary["failures"].append(
            f"cache reload rejected: {tuner2.load_error}")

    # -- kernel-aware bucketing: zero retraces + bitwise encodes ---------
    _trainer_gates(summary, steps, batch, dim, trials, dataset, tuner)


def _ref_out(op, bucket, args):
    from repro.kernels import ref

    if op == "scoring":
        q, e = args
        return np.asarray(ref.scoring_ref(q, e, gamma=1.0, mode="dot"))
    if op == "intersect":
        return np.asarray(ref.intersect_ref(*args))
    return np.asarray(ref.gather_fuse_ref(*args))


def _trainer_gates(summary, steps, batch, dim, trials, dataset, tuner):
    import jax

    from repro.core import PooledExecutor
    from repro.data import load_dataset
    from repro.models import ModelConfig, make_model
    from repro.sampling import OnlineSampler
    from repro.training import AdamConfig, NGDBTrainer, TrainConfig

    kg, _, _ = load_dataset(dataset)
    model = make_model("gqe", ModelConfig(dim=dim, gamma=6.0))

    # Tune the pool-ladder buckets this model/shape regime actually hits, so
    # the snapshotted policy has a tuned tile for EVERY pool the scheduler
    # can form — kernel-aware bucketing is live, not vacuously enabled.
    n_sw = at.tune_for_model(model, tuner, b_max=128, batch=batch)
    policy = at.pool_tile_policy(model, tuner, b_max=128)
    summary["model_sweeps"] = n_sw
    summary["tile_policy_pools"] = len(policy.key()) if policy else 0
    if not policy:
        summary["failures"].append(
            "tune_for_model produced no tile policy — kernel-aware "
            "bucketing never engaged")
        return

    # Encodes bitwise vs the untuned engine: padding may shrink, real rows
    # must not move by a bit.
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations)
    qs = [s.query for s in OnlineSampler(kg, seed=5).sample_batch(batch)]
    enc_tuned = np.asarray(
        PooledExecutor(model, b_max=128, tile_policy=policy)
        .encode(params, qs))
    enc_plain = np.asarray(
        PooledExecutor(model, b_max=128, tile_policy=None)
        .encode(params, qs))
    summary["encode_bitwise_vs_untuned"] = bool(
        np.array_equal(enc_tuned, enc_plain))
    emit(f"autotune/{dataset}/encode_bitwise", 0.0,
         str(summary["encode_bitwise_vs_untuned"]))
    if not summary["encode_bitwise_vs_untuned"]:
        summary["failures"].append(
            "encode with kernel-aware bucketing differs bitwise from the "
            "pow2-padded engine")

    # Zero steady-state retraces, sync + pipelined, with the policy live in
    # every executor ("auto" snapshot from the process tuner).
    at.set_tuner(tuner)
    batches = [OnlineSampler(kg, seed=29).sample_batch(batch)
               for _ in range(4)]

    def stream():
        it = itertools.cycle(batches)
        return lambda: next(it)

    summary["retraces"] = {}
    summary["qps"] = {}
    for mode in ("sync", "pipelined"):
        cfg = TrainConfig(batch_size=batch, n_negatives=8, b_max=128,
                          adam=AdamConfig(lr=1e-3), seed=0, prefetch=2,
                          pipeline=(mode == "pipelined"))
        tr = NGDBTrainer(make_model("gqe", ModelConfig(dim=dim, gamma=6.0)),
                         kg, cfg)
        if not tr.executor.tile_policy:
            summary["failures"].append(
                f"{mode}: trainer executor did not snapshot the tile "
                f"policy from the process tuner")
        tr.train(steps, log_every=0, batches=stream())  # warm signatures
        tr._train_fns.reset_counters()
        tr.executor.reset_cache_counters()
        best = float("inf")
        for _ in range(max(trials, 1)):
            t0 = time.perf_counter()
            tr.train(steps, log_every=0, batches=stream())
            best = min(best, time.perf_counter() - t0)
        cs = tr.compile_cache_stats()
        misses = (int(cs["train_step"]["misses"])
                  + sum(int(cs[k]["misses"])
                        for k in ("schedule", "encode", "encode_jit")))
        summary["retraces"][mode] = misses
        summary["qps"][mode] = round(steps * batch / best, 1)
        emit(f"autotune/{dataset}/{mode}_qps", 1e6 * best / steps,
             f"qps={summary['qps'][mode]} retraces={misses} "
             f"(kernel-aware bucketing on)")
        if misses:
            summary["failures"].append(
                f"{mode}: {misses} steady-state retraces with kernel-aware "
                f"bucketing — the tile policy leaks new signatures")
    summary["autotune_stats"] = tuner.stats()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--trials", type=int, default=6)
    ap.add_argument("--dataset", default="FB15k")
    args = ap.parse_args()
    run(steps=args.steps, batch=args.batch, dim=args.dim,
        trials=args.trials, dataset=args.dataset)


if __name__ == "__main__":
    main()
