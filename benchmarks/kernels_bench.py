"""Pallas kernel microbenches vs jnp references — committed trajectory file.

On this CPU host the kernels execute in interpret mode (Python), so absolute
kernel times are meaningless; we report the REFERENCE path timing (what
XLA:CPU does with the same math), validate kernel outputs against the
oracles, and record which tile configs the autotuner resolves for each
shape — so kernel perf has a trajectory file (``BENCH_kernels.json``, the
plan/serving/obs pattern) that accumulates across PRs. On TPU the same call
sites compile to Mosaic and the reference timings become kernel timings.

Verdict rules: kernel outputs must agree with the oracles within fp32
tolerance; timings are recorded, never gated (CI boxes are noisy — the
autotune suite gates the paired invariants).
"""
from __future__ import annotations

import json
import os
import sys

if __package__ in (None, ""):  # direct `python benchmarks/kernels_bench.py`
    _root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import autotune as at
from repro.kernels import ops
from repro.kernels.ref import gather_fuse_ref, intersect_ref, scoring_ref

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_DEFAULT_OUT = os.path.join(_REPO_ROOT, "BENCH_kernels.json")

_TOL = 5e-4


def run(out_path: str = _DEFAULT_OUT) -> dict:
    summary = {"ok": False, "suite": "kernels", "failures": [],
               "backend": jax.default_backend(), "kernels": {}}

    def publish():
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")

    try:
        _run_inner(summary)
        summary["ok"] = not summary["failures"]
    except BaseException as e:
        summary["failures"].append(f"{type(e).__name__}: {e}")
        publish()
        raise
    publish()
    return summary


def _check(summary, name, err, ref_us, tiles):
    summary["kernels"][name] = {"max_err": err, "ref_us": round(ref_us, 1),
                                "tiles": tiles}
    if err > _TOL:
        summary["failures"].append(
            f"{name}: interpret-mode output drifts {err:.2e} > {_TOL} "
            f"from the jnp oracle")


def _run_inner(summary) -> None:
    rng = np.random.default_rng(0)
    tuner = at.get_tuner()

    B, N, d = 256, 4096, 128
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    ref = jax.jit(lambda q, e: scoring_ref(q, e, 2.0, "dot"))
    t = time_fn(ref, q, e)
    emit("kernel/scoring/jnp_ref", t, f"B{B} N{N} d{d}")
    out = ops.scoring(q[:8], e[:256], gamma=2.0, interpret=True)
    err = float(jnp.max(jnp.abs(out - scoring_ref(q[:8], e[:256], 2.0, "dot"))))
    tiles = tuner.config_for("scoring", at.scoring_bucket(B, N, d))
    emit("kernel/scoring/interpret_maxerr", 0.0, f"{err:.2e}")
    emit("kernel/scoring/tiles", 0.0,
         f"bm{tiles['bm']} bn{tiles['bn']} bk{tiles['bk']} "
         f"(MXU 128-aligned)")
    _check(summary, f"scoring/B{B}xN{N}xd{d}", err, t, tiles)

    n, k, dd, hd = 512, 3, 128, 256
    x = jnp.asarray(rng.normal(size=(n, k, dd)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(dd, hd)) * 0.1, jnp.float32)
    b1 = jnp.zeros((hd,))
    w2 = jnp.asarray(rng.normal(size=(hd, 1)) * 0.1, jnp.float32)
    b2 = jnp.zeros((1,))
    ref2 = jax.jit(lambda *a: intersect_ref(*a))
    t = time_fn(ref2, x, w1, b1, w2, b2)
    emit("kernel/intersect/jnp_ref", t, f"n{n} k{k} d{dd}")
    out = ops.intersect(x[:32], w1, b1, w2, b2, interpret=True)
    err = float(jnp.max(jnp.abs(out - intersect_ref(x[:32], w1, b1, w2, b2))))
    tiles = tuner.config_for("intersect", at.intersect_bucket(n, k, dd, hd))
    emit("kernel/intersect/interpret_maxerr", 0.0, f"{err:.2e}")
    _check(summary, f"intersect/n{n}xk{k}xd{dd}", err, t, tiles)

    n, d2, dl, dp = 256, 64, 32, 16
    E = 1024
    ids = jnp.asarray(rng.integers(0, E, n), jnp.int32)
    h_str = jnp.asarray(rng.normal(size=(E, d2)), jnp.float32)
    h_sem = jnp.asarray(rng.normal(size=(E, dl)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(dl, dp)) * 0.1, jnp.float32)
    bp = jnp.zeros((dp,))
    wf = jnp.asarray(rng.normal(size=(d2 + dp, d2)) * 0.1, jnp.float32)
    bf = jnp.zeros((d2,))
    ref3 = jax.jit(lambda *a: gather_fuse_ref(*a))
    t = time_fn(ref3, ids, h_str, h_sem, wp, bp, wf, bf)
    emit("kernel/gather_fuse/jnp_ref", t, f"n{n} d{d2} dl{dl}")
    small = ids[:32]
    out = ops.gather_fuse(small, h_str, h_sem, wp, bp, wf, bf, interpret=True)
    err = float(jnp.max(jnp.abs(
        out - gather_fuse_ref(small, h_str, h_sem, wp, bp, wf, bf))))
    tiles = tuner.config_for(
        "gather_fuse", at.gather_fuse_bucket(n, d2, dl, dp))
    emit("kernel/gather_fuse/interpret_maxerr", 0.0, f"{err:.2e}")
    _check(summary, f"gather_fuse/n{n}xd{d2}xdl{dl}", err, t, tiles)

    summary["autotune"] = {"entries": len(tuner),
                           "cache_path": tuner.path}


if __name__ == "__main__":
    run()
