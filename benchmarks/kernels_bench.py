"""Pallas kernel microbenches vs jnp references.

On this CPU host the kernels execute in interpret mode (Python), so absolute
times are meaningless; we report the REFERENCE path timing (what XLA:CPU does
with the same math) and validate kernel outputs, plus the roofline-relevant
tile parameters. On TPU the same call sites compile to Mosaic."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops
from repro.kernels.ref import intersect_ref, scoring_ref


def run() -> None:
    rng = np.random.default_rng(0)
    B, N, d = 256, 4096, 128
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    ref = jax.jit(lambda q, e: scoring_ref(q, e, 2.0, "dot"))
    t = time_fn(ref, q, e)
    emit("kernel/scoring/jnp_ref", t, f"B{B} N{N} d{d}")
    out = ops.scoring(q[:8], e[:256], gamma=2.0, interpret=True)
    err = float(jnp.max(jnp.abs(out - scoring_ref(q[:8], e[:256], 2.0, "dot"))))
    emit("kernel/scoring/interpret_maxerr", 0.0, f"{err:.2e}")
    emit("kernel/scoring/tiles", 0.0, "bm128 bn256 bk128 (MXU 128-aligned)")

    n, k, dd, hd = 512, 3, 128, 256
    x = jnp.asarray(rng.normal(size=(n, k, dd)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(dd, hd)) * 0.1, jnp.float32)
    b1 = jnp.zeros((hd,))
    w2 = jnp.asarray(rng.normal(size=(hd, 1)) * 0.1, jnp.float32)
    b2 = jnp.zeros((1,))
    ref2 = jax.jit(lambda *a: intersect_ref(*a))
    t = time_fn(ref2, x, w1, b1, w2, b2)
    emit("kernel/intersect/jnp_ref", t, f"n{n} k{k} d{dd}")
    out = ops.intersect(x[:32], w1, b1, w2, b2, interpret=True)
    err = float(jnp.max(jnp.abs(out - intersect_ref(x[:32], w1, b1, w2, b2))))
    emit("kernel/intersect/interpret_maxerr", 0.0, f"{err:.2e}")


if __name__ == "__main__":
    run()
