"""Quickstart: train a BetaE NGDB with operator-level batching in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import generate_synthetic_kg, split_kg
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training import AdamConfig, NGDBTrainer, TrainConfig, evaluate

# 1. A knowledge graph (synthetic stand-in; swap in your own triples array).
full_kg = generate_synthetic_kg(n_entities=400, n_relations=12, n_triples=5000, seed=0)
train_kg, valid, test = split_kg(full_kg)
print(f"KG: {train_kg.n_entities} entities / {len(train_kg)} train triples")

# 2. A query-encoder backbone (gqe | q2b | betae | q2p | fuzzqe | complex).
model = make_model("betae", ModelConfig(dim=32, gamma=12.0))

# 3. The operator-level trainer: online sampling -> Max-Fillness scheduling
#    -> cross-query fused kernels -> vectorized loss -> Adam.
cfg = TrainConfig(batch_size=64, n_negatives=16,
                  patterns=("1p", "2p", "2i", "3i", "2u"),
                  adam=AdamConfig(lr=3e-3), prefetch=0)
trainer = NGDBTrainer(model, train_kg, cfg)
trainer.train(n_steps=40, log_every=10)

# 4. Filtered-MRR evaluation against the full graph (predictive answers).
queries = [b.query for b in OnlineSampler(train_kg, patterns=("1p", "2i"),
                                          seed=1).sample_batch(32)]
metrics = evaluate(model, trainer.params, trainer.executor, full_kg, queries,
                   train_kg=train_kg)
print({k: round(float(v), 4) for k, v in metrics.items() if "/" not in k})
