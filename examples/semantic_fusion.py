"""Decoupled semantic integration (paper §4.4) end to end:
offline PTE precompute -> unload -> device-resident gather-fused training,
vs the joint PTE-in-the-loop design it replaces.

  PYTHONPATH=src python examples/semantic_fusion.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import generate_synthetic_kg
from repro.models import ModelConfig, make_model
from repro.semantic import PTEConfig, StubPTE, precompute_semantic_table
from repro.training import AdamConfig, NGDBTrainer, TrainConfig

kg = generate_synthetic_kg(500, 10, 6000, seed=0)

# ---- offline phase: encode every entity once, then UNLOAD the PTE ----------
pte = StubPTE(PTEConfig(d_l=128, n_layers=2, d_model=64))
t0 = time.time()
H_sem = precompute_semantic_table(kg, pte)
print(f"H_sem: {H_sem.shape} precomputed in {time.time()-t0:.1f}s; "
      f"PTE unloaded={pte.unloaded}")

# ---- training is now inference-free: semantics = one gather (Eq. 11) -------
model = make_model("q2b", ModelConfig(dim=32, semantic_dim=128))
cfg = TrainConfig(batch_size=48, n_negatives=16, patterns=("1p", "2p", "2i"),
                  adam=AdamConfig(lr=3e-3), prefetch=0)
trainer = NGDBTrainer(model, kg, cfg, semantic_table=H_sem)
trainer.train_step()  # compile
t0 = time.time()
for _ in range(8):
    trainer.train_step()
decoupled_qps = 8 * cfg.batch_size / (time.time() - t0)
print(f"decoupled: {decoupled_qps:.0f} queries/s")

# ---- compare: the Pallas gather_fuse kernel computes the same fusion -------
from repro.kernels import ops

p = trainer.params
ids = jnp.arange(32, dtype=jnp.int32)
fused_kernel = ops.gather_fuse(ids, p["entity"], p["sem_table"],
                               p["sem_proj_w"], p["sem_proj_b"],
                               p["fuse_w"], p["fuse_b"], interpret=True)
fused_model = model.fused_entity_vec(p, ids)
print("kernel == model fusion:",
      bool(np.allclose(np.asarray(fused_kernel), np.asarray(fused_model),
                       atol=1e-5)))
