"""The assigned-architecture zoo: pick any --arch, run a reduced-config train
step + prefill + decode on CPU, and show the full config's dry-run inputs.

  PYTHONPATH=src python examples/lm_arch_zoo.py --arch mixtral-8x22b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.lm.model import init_params
from repro.lm.shapes import SHAPES, cell_supported, input_specs
from repro.lm.steps import make_decode_step, make_prefill_step, make_train_step
from repro.training.optim import adam_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=sorted(ARCHS))
    args = ap.parse_args()

    full = ARCHS[args.arch]
    print(f"== {full.name} [{full.family}] ==")
    print(f"  {full.n_layers}L d_model={full.d_model} heads={full.n_heads}/"
          f"{full.n_kv_heads} d_ff={full.d_ff} vocab={full.vocab_size} "
          f"experts={full.n_experts} ssm_state={full.ssm_state}")
    print(f"  params: {full.param_count()/1e9:.1f}B total, "
          f"{full.active_param_count()/1e9:.1f}B active")
    for shape in SHAPES:
        skip = cell_supported(full, shape)
        note = f"SKIP ({skip.split(':')[0]})" if skip else "ok"
        print(f"  cell {shape:12s}: {note}")

    cfg = reduced_config(full)
    print(f"\nrunning reduced config on CPU ({cfg.n_layers}L d={cfg.d_model})...")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 32
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["embeddings"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.is_encdec:
        batch["encoder_frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                            jnp.bfloat16)
    _, _, loss = jax.jit(make_train_step(cfg))(params, adam_init(params), batch)
    print(f"  train step: loss={float(loss):.3f}")
    caches, _ = jax.jit(make_prefill_step(cfg))(params, batch)
    logits, _ = jax.jit(make_decode_step(cfg))(
        params, caches, jnp.zeros((B, 1), jnp.int32), jnp.int32(S))
    print(f"  prefill+decode: logits {tuple(logits.shape)}, "
          f"finite={bool(np.isfinite(np.asarray(logits, np.float32)).all())}")
    print("\n(dry-run at production scale: "
          f"PYTHONPATH=src python -m repro.launch.dryrun --arch {args.arch} "
          "--shape train_4k --multi-pod)")


if __name__ == "__main__":
    main()
