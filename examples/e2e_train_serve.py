"""End-to-end driver: train an NGDB on a larger synthetic graph with
semantics + adaptive sampling + checkpointing, simulate a mid-run crash,
auto-resume, finish training, then SERVE batched mixed-pattern queries.

  PYTHONPATH=src python examples/e2e_train_serve.py [--steps 120]
"""
import argparse
import os
import shutil
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import load_dataset
from repro.launch.serve import serve_batch
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.semantic import PTEConfig, StubPTE, precompute_semantic_table
from repro.training import AdamConfig, NGDBTrainer, TrainConfig, evaluate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--dim", type=int, default=48)
    args = ap.parse_args()

    ckpt_dir = "/tmp/ngdb_zoo_e2e_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    kg, full_kg, _ = load_dataset("ogbl-wikikg2")  # reduced stand-in
    print(f"graph: {kg.n_entities} entities, {len(kg)} triples")
    pte = StubPTE(PTEConfig(d_l=128, n_layers=2, d_model=64))
    table = precompute_semantic_table(kg, pte)
    print(f"semantic table {table.shape}; PTE unloaded={pte.unloaded}")

    model = make_model("betae", ModelConfig(dim=args.dim, semantic_dim=128))
    cfg = TrainConfig(batch_size=args.batch_size, n_negatives=16,
                      adam=AdamConfig(lr=2e-3), adaptive=True,
                      checkpoint_dir=ckpt_dir, checkpoint_every=20)

    # phase 1: train halfway, then "crash"
    tr = NGDBTrainer(model, kg, cfg, semantic_table=table)
    half = args.steps // 2
    t0 = time.time()
    tr.train(half, log_every=20)
    print(f"--- simulated failure at step {tr.step} "
          f"({half*args.batch_size/(time.time()-t0):.0f} q/s) ---")
    del tr

    # phase 2: a fresh process auto-resumes from the newest valid checkpoint
    tr = NGDBTrainer(model, kg, cfg, semantic_table=table)
    assert tr.resume(), "no checkpoint found"
    print(f"resumed at step {tr.step}; continuing")
    tr.train(args.steps - tr.step, log_every=20)

    qs = [b.query for b in OnlineSampler(kg, seed=5).sample_batch(32)]
    metrics = evaluate(model, tr.params, tr.executor, full_kg, qs, train_kg=kg)
    print("eval:", {k: round(float(v), 4) for k, v in metrics.items()
                    if "/" not in k})

    # phase 3: serve batched requests on the trained model
    queries = [b.query for b in OnlineSampler(kg, seed=9).sample_batch(16)]
    results, _ = serve_batch(model, tr.params, tr.executor, queries, top_k=5)
    print("serve sample:", results[0])


if __name__ == "__main__":
    main()
