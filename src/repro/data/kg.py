"""Knowledge-graph storage: triple store + CSR adjacency + synthetic generators.

The container is offline, so the paper's six benchmark KGs (Table 4) are
represented two ways:
  * ``full``   — exact Table 4 statistics, used ONLY by the dry-run
                 (ShapeDtypeStruct; never materialized).
  * ``reduced``— small synthetic graphs with the same family (power-law
                 degrees, same relation/entity ratio) for CPU tests and
                 benchmarks.

Live-write layer (DESIGN.md §LiveStore): the store is append-only but no
longer read-only — ``add_triples``/``add_entities`` mutate it online while
queries keep running on other threads. The concurrency contract is
snapshot-based:

  * every write builds the new CSR ASIDE and publishes it as ONE reference
    assignment of an immutable ``_Adjacency`` tuple, so a lock-free reader
    (serving batcher, sampler workers) always sees a matched
    (triples, hr, tails) — never new ``hr`` paired with old ``tails``;
  * every committed write bumps the monotonic ``graph_version`` and retains
    an immutable ``KGSnapshot`` of the pre-existing adjacency, so queries
    can PIN a version and replay bit-identically against the graph state
    they were admitted under (the serving engine keys its caches on it);
  * a write that changes nothing (empty input, all rows already present) is
    a true no-op: no rebuild, no version bump, no listener fire — warm
    materialized caches survive it;
  * invalidation listeners are held by WEAKREF (the ``obs/registry.py``
    idiom), so a discarded ``MaterializedSubqueryCache`` is collected and
    its dead listener pruned on the next write.
"""
from __future__ import annotations

import dataclasses
import weakref
from functools import cached_property
from typing import Dict, List, NamedTuple, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class KGStats:
    """Table 4 row."""

    name: str
    n_entities: int
    n_relations: int
    n_train: int
    n_valid: int
    n_test: int

    @property
    def n_total(self) -> int:
        return self.n_train + self.n_valid + self.n_test


# Exact statistics from Table 4 of the paper.
TABLE4: Dict[str, KGStats] = {
    "FB15k": KGStats("FB15k", 14_951, 1_345, 483_142, 50_000, 59_071),
    "FB15k-237": KGStats("FB15k-237", 14_505, 237, 272_115, 17_526, 20_438),
    "NELL995": KGStats("NELL995", 63_361, 200, 114_213, 14_324, 14_267),
    "FB400k": KGStats("FB400k", 409_829, 918, 1_075_837, 537_917, 537_917),
    "ogbl-wikikg2": KGStats("ogbl-wikikg2", 2_500_604, 535, 16_109_182, 429_456, 598_543),
    "ATLAS-Wiki-Triple-4M": KGStats(
        "ATLAS-Wiki-Triple-4M", 4_035_238, 512_064, 23_040_868, 2_880_108, 2_880_110
    ),
}


class SnapshotUnavailable(KeyError):
    """A pinned ``graph_version`` is no longer retained (or never existed)."""


class _Adjacency(NamedTuple):
    """One immutable CSR build. Readers grab the WHOLE tuple in a single
    reference read, so the three arrays can never be observed torn."""

    triples: np.ndarray   # [n, 3] int64, lexsorted by (h, r, t), deduped
    hr: np.ndarray        # triples[:, 0] * R + triples[:, 1] (sorted)
    tails: np.ndarray     # contiguous triples[:, 2] (sorted within hr spans)


def _build_adjacency(triples: np.ndarray, n_relations: int) -> _Adjacency:
    """Dedup + sort by (h, r, t) and index by (h, r).

    Ordering/dedup uses ``np.lexsort`` over the COLUMNS — the composite key
    ``(h*R + r)*E + t`` silently overflows int64 at ATLAS-Wiki-Triple-4M
    scale (max key ≈ 8.3e18, within 10% of INT64_MAX; any larger graph
    wraps, corrupting dedup and the CSR sort order). The 2-term ``h*R + r``
    index below stays safe to E·R ≈ 9.2e18 — ~4.5e6x the paper's largest
    graph — and is asserted anyway.
    """
    tri = np.asarray(triples, dtype=np.int64)
    assert tri.ndim == 2 and tri.shape[1] == 3
    order = np.lexsort((tri[:, 2], tri[:, 1], tri[:, 0]))
    tri = tri[order]
    if len(tri):
        keep = np.concatenate([[True], np.any(tri[1:] != tri[:-1], axis=1)])
        tri = tri[keep]
        assert tri[:, 0].max() <= (np.iinfo(np.int64).max - n_relations) // max(n_relations, 1)
    tri = np.ascontiguousarray(tri)
    hr = tri[:, 0] * n_relations + tri[:, 1]
    return _Adjacency(tri, hr, np.ascontiguousarray(tri[:, 2]))


class _AdjacencyReader:
    """Lock-free read API shared by the live graph and its snapshots. Every
    method reads ``self._adj`` exactly ONCE, so concurrent writes (which
    swap the whole tuple) can never tear a read."""

    _adj: _Adjacency
    n_relations: int

    @property
    def triples(self) -> np.ndarray:
        return self._adj.triples

    def __len__(self) -> int:
        return self._adj.triples.shape[0]

    def neighbors(self, h: int, r: int) -> np.ndarray:
        """All tails t with (h, r, t) in the graph."""
        adj = self._adj
        hr = h * self.n_relations + r
        lo = np.searchsorted(adj.hr, hr, side="left")
        hi = np.searchsorted(adj.hr, hr, side="right")
        return adj.tails[lo:hi]

    def neighbors_of_set(self, heads: np.ndarray, r: int) -> np.ndarray:
        """Union of tails over a set of heads for one relation (Project op)."""
        if len(heads) == 0:
            return np.empty((0,), dtype=np.int64)
        adj = self._adj
        hr = np.asarray(heads, dtype=np.int64) * self.n_relations + r
        lo = np.searchsorted(adj.hr, hr, side="left")
        hi = np.searchsorted(adj.hr, hr, side="right")
        parts = [adj.tails[a:b] for a, b in zip(lo, hi) if b > a]
        if not parts:
            return np.empty((0,), dtype=np.int64)
        return np.unique(np.concatenate(parts))

    def contains(self, rows: np.ndarray) -> np.ndarray:
        """Boolean membership per (h, r, t) row. Within one (h, r) span the
        tails are sorted (triples are lexsorted), so each row is two binary
        searches on ``hr`` plus one on its span."""
        rows = np.asarray(rows, dtype=np.int64).reshape(-1, 3)
        adj = self._adj
        hr = rows[:, 0] * self.n_relations + rows[:, 1]
        lo = np.searchsorted(adj.hr, hr, side="left")
        hi = np.searchsorted(adj.hr, hr, side="right")
        out = np.zeros(len(rows), dtype=bool)
        for i in np.nonzero(hi > lo)[0]:
            span = adj.tails[lo[i]:hi[i]]
            j = np.searchsorted(span, rows[i, 2])
            out[i] = j < len(span) and span[j] == rows[i, 2]
        return out


@dataclasses.dataclass(frozen=True)
class KGSnapshot(_AdjacencyReader):
    """An immutable view of the graph at one ``graph_version``. Shares the
    underlying (immutable) adjacency arrays with the live graph — taking a
    snapshot is O(1) — and never changes after creation, so a query pinned
    to it replays bit-identically regardless of later writes."""

    name: str
    n_entities: int
    n_relations: int
    graph_version: int
    _adj: _Adjacency


class KnowledgeGraph(_AdjacencyReader):
    """Append-only triple store with CSR adjacency for fast traversal.

    Adjacency is keyed by (head, relation) via a sorted (h * R + r) index so
    ``neighbors(h, r)`` is two binary searches — the access pattern the online
    sampler (App. F) hammers.

    The store is immutable between writes; the mutations are ``add_triples``
    / ``insert_triples`` (online KG growth) and ``add_entities``. A committed
    write rebuilds the CSR aside and publishes it atomically, drops every
    ``cached_property`` adjacency view, bumps ``graph_version``, retains a
    ``KGSnapshot`` of the new state, and notifies (weakly-held) invalidation
    listeners — the hook materialized caches (``core/matcache.py``) use to
    bump their version stamp so rows encoded against the old graph are
    never served at the new one.
    """

    # cached_property views derived from ``triples`` — every name here must
    # be dropped from ``__dict__`` on a write or stale adjacency survives.
    _CACHED_VIEWS = ("out_degree", "degree", "edges_with_outgoing",
                     "relations_by_head", "incoming_by_tail",
                     "entities_with_incoming")

    def __init__(self, n_entities: int, n_relations: int, triples: np.ndarray,
                 name: str = "kg", snapshot_retention: int = 8):
        if snapshot_retention < 1:
            raise ValueError("snapshot_retention must be >= 1")
        self.name = name
        self.n_entities = int(n_entities)
        self.n_relations = int(n_relations)
        self.version = 0
        self.snapshot_retention = int(snapshot_retention)
        self._listeners: List = []   # weakref.ref / weakref.WeakMethod
        self._snapshots: Dict[int, KGSnapshot] = {}
        self._adj = _build_adjacency(triples, self.n_relations)
        self._retain_snapshot()

    # ------------------------------------------------------------ versioning
    @property
    def graph_version(self) -> int:
        """Monotonic write counter — the version caches and pinned queries
        key on. Alias of ``version`` (the historical name)."""
        return self.version

    def snapshot(self) -> KGSnapshot:
        """The immutable view of the CURRENT graph state."""
        return self._snapshots[self.version]

    def snapshot_at(self, version: int) -> KGSnapshot:
        """The retained snapshot for ``version``; raises
        ``SnapshotUnavailable`` once it has aged out of the retention window
        (``snapshot_retention`` most-recent versions are kept)."""
        snap = self._snapshots.get(version)
        if snap is None:
            raise SnapshotUnavailable(
                f"graph version {version} is not retained "
                f"(current {self.version}, retention {self.snapshot_retention})")
        return snap

    def retained_versions(self) -> Tuple[int, ...]:
        return tuple(sorted(self._snapshots))

    def _retain_snapshot(self) -> None:
        self._snapshots[self.version] = KGSnapshot(
            self.name, self.n_entities, self.n_relations, self.version,
            self._adj)
        while len(self._snapshots) > self.snapshot_retention:
            del self._snapshots[min(self._snapshots)]

    # ------------------------------------------------------------ KG writes
    def add_invalidation_listener(self, fn) -> None:
        """Register ``fn(reason: str)`` to be called after every committed
        write — e.g. ``MaterializedSubqueryCache.bump_version`` via
        ``watch_kg``. Held WEAKLY (``WeakMethod`` for bound methods — the
        ``obs/registry.py`` idiom): the KG must not keep a discarded cache
        alive; dead refs are pruned on the next notify."""
        ref = (weakref.WeakMethod(fn) if hasattr(fn, "__self__")
               else weakref.ref(fn))
        self._listeners.append(ref)

    def live_listener_count(self) -> int:
        """Number of listeners still alive (prunes dead refs)."""
        self._listeners = [r for r in self._listeners if r() is not None]
        return len(self._listeners)

    def _notify(self, reason: str) -> None:
        live, refs = [], []
        for r in self._listeners:
            fn = r()
            if fn is not None:
                live.append(fn)
                refs.append(r)
        self._listeners = refs
        for fn in live:
            fn(reason)

    def _commit(self, reason: str) -> None:
        for name in self._CACHED_VIEWS:
            self.__dict__.pop(name, None)
        self.version += 1
        self._retain_snapshot()
        self._notify(reason)

    def insert_triples(self, new_triples) -> np.ndarray:
        """Online KG write. Returns the rows actually inserted (deduped
        against the store AND within the input) — empty when the write was a
        no-op, in which case NOTHING happens: no CSR rebuild, no version
        bump, no listener fire. A no-op write must not nuke warm
        materialized caches."""
        new = np.asarray(new_triples, dtype=np.int64).reshape(-1, 3)
        if len(new):
            ents = new[:, [0, 2]]
            if ents.min() < 0 or ents.max() >= self.n_entities:
                raise ValueError("entity id out of range")
            if new[:, 1].min() < 0 or new[:, 1].max() >= self.n_relations:
                raise ValueError("relation id out of range")
            new = new[~self.contains(new)]
            if len(new) > 1:
                new = np.unique(new, axis=0)
        if len(new) == 0:
            return new
        # Build aside, publish with one reference assignment: lock-free
        # readers on other threads (serving batcher, sampler workers) see
        # either the whole old build or the whole new one, never a mix.
        self._adj = _build_adjacency(
            np.concatenate([self._adj.triples, new], axis=0),
            self.n_relations)
        self._commit("kg_write")
        return new

    def add_triples(self, new_triples) -> "KnowledgeGraph":
        """``insert_triples`` with the chaining-friendly historical return."""
        self.insert_triples(new_triples)
        return self

    def add_entities(self, n_new: int) -> range:
        """Grow the entity id space by ``n_new`` (for live writes that
        introduce unseen entities). The CSR is untouched — ``hr = h*R + r``
        does not depend on E — but degree-shaped cached views drop, the
        version bumps and listeners fire. Returns the new id range."""
        if n_new < 0:
            raise ValueError("n_new must be >= 0")
        first = self.n_entities
        if n_new == 0:
            return range(first, first)
        self.n_entities = first + int(n_new)
        self._commit("entity_add")
        return range(first, self.n_entities)

    @cached_property
    def out_degree(self) -> np.ndarray:
        deg = np.zeros(self.n_entities, dtype=np.int64)
        np.add.at(deg, self.triples[:, 0], 1)
        return deg

    @cached_property
    def degree(self) -> np.ndarray:
        deg = np.zeros(self.n_entities, dtype=np.int64)
        np.add.at(deg, self.triples[:, 0], 1)
        np.add.at(deg, self.triples[:, 2], 1)
        return deg

    @cached_property
    def edges_with_outgoing(self) -> np.ndarray:
        """Entities with at least one outgoing edge (valid anchor starts)."""
        return np.unique(self.triples[:, 0])

    @cached_property
    def relations_by_head(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR (indptr, relations, tails) grouped by head for random walks."""
        order = np.argsort(self.triples[:, 0], kind="stable")
        heads = self.triples[order, 0]
        indptr = np.searchsorted(heads, np.arange(self.n_entities + 1))
        return indptr, self.triples[order, 1], self.triples[order, 2]

    @cached_property
    def incoming_by_tail(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR (indptr, relations, heads) grouped by tail — used by the online
        sampler's backward ground-truth instantiation (App. F)."""
        order = np.argsort(self.triples[:, 2], kind="stable")
        tails = self.triples[order, 2]
        indptr = np.searchsorted(tails, np.arange(self.n_entities + 1))
        return indptr, self.triples[order, 1], self.triples[order, 0]

    @cached_property
    def entities_with_incoming(self) -> np.ndarray:
        return np.unique(self.triples[:, 2])


def generate_synthetic_kg(
    n_entities: int,
    n_relations: int,
    n_triples: int,
    seed: int = 0,
    hub_exponent: float = 0.8,
    name: str = "synthetic",
) -> KnowledgeGraph:
    """Power-law synthetic KG (degree-weighted, matching App. C's sampling).

    Head/tail entities are drawn from a Zipf-like distribution so the graph
    has hub structure like FB15k/wikikg2; relation usage is also skewed.
    """
    rng = np.random.default_rng(seed)
    ent_w = (np.arange(1, n_entities + 1, dtype=np.float64)) ** (-hub_exponent)
    ent_p = ent_w / ent_w.sum()
    rel_w = (np.arange(1, n_relations + 1, dtype=np.float64)) ** (-0.5)
    rel_p = rel_w / rel_w.sum()
    # Oversample then dedup to hit ~n_triples unique triples.
    m = int(n_triples * 1.3) + 16
    h = rng.choice(n_entities, size=m, p=ent_p)
    t = rng.choice(n_entities, size=m, p=ent_p)
    r = rng.choice(n_relations, size=m, p=rel_p)
    tri = np.stack([h, r, t], axis=1)
    kg = KnowledgeGraph(n_entities, n_relations, tri, name=name)
    if len(kg) > n_triples:
        keep = rng.choice(len(kg), size=n_triples, replace=False)
        kg = KnowledgeGraph(n_entities, n_relations, kg.triples[keep], name=name)
    return kg


def split_kg(kg: KnowledgeGraph, valid_frac: float = 0.05, test_frac: float = 0.05, seed: int = 0):
    """Edge split into (train_kg, valid_edges, test_edges) — the Predictive
    Query Answering setting: G_train ⊂ G_full."""
    rng = np.random.default_rng(seed)
    n = len(kg)
    perm = rng.permutation(n)
    n_valid = int(n * valid_frac)
    n_test = int(n * test_frac)
    valid = kg.triples[perm[:n_valid]]
    test = kg.triples[perm[n_valid : n_valid + n_test]]
    train = kg.triples[perm[n_valid + n_test :]]
    train_kg = KnowledgeGraph(kg.n_entities, kg.n_relations, train, name=kg.name + "-train")
    return train_kg, valid, test


# Reduced stand-ins used on CPU (same family, ~1000x smaller).
REDUCED_SCALE: Dict[str, Tuple[int, int, int]] = {
    # name -> (entities, relations, triples)
    "FB15k": (600, 40, 8000),
    "FB15k-237": (580, 24, 5000),
    "NELL995": (900, 20, 2500),
    "FB400k": (2000, 60, 9000),
    "ogbl-wikikg2": (4000, 50, 24000),
    "ATLAS-Wiki-Triple-4M": (6000, 200, 34000),
}


def load_dataset(name: str, reduced: bool = True, seed: int = 0):
    """Returns (train_kg, full_kg, stats). ``reduced`` is mandatory on CPU;
    full-scale graphs exist only as ShapeDtypeStructs in the dry-run."""
    stats = TABLE4[name]
    if not reduced:
        raise RuntimeError(
            "Full-scale KGs are dry-run-only in this container; use reduced=True."
        )
    e, r, t = REDUCED_SCALE[name]
    full = generate_synthetic_kg(e, r, t, seed=seed, name=name)
    train_kg, _, _ = split_kg(full, seed=seed)
    return train_kg, full, stats
