"""Knowledge-graph storage: triple store + CSR adjacency + synthetic generators.

The container is offline, so the paper's six benchmark KGs (Table 4) are
represented two ways:
  * ``full``   — exact Table 4 statistics, used ONLY by the dry-run
                 (ShapeDtypeStruct; never materialized).
  * ``reduced``— small synthetic graphs with the same family (power-law
                 degrees, same relation/entity ratio) for CPU tests and
                 benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class KGStats:
    """Table 4 row."""

    name: str
    n_entities: int
    n_relations: int
    n_train: int
    n_valid: int
    n_test: int

    @property
    def n_total(self) -> int:
        return self.n_train + self.n_valid + self.n_test


# Exact statistics from Table 4 of the paper.
TABLE4: Dict[str, KGStats] = {
    "FB15k": KGStats("FB15k", 14_951, 1_345, 483_142, 50_000, 59_071),
    "FB15k-237": KGStats("FB15k-237", 14_505, 237, 272_115, 17_526, 20_438),
    "NELL995": KGStats("NELL995", 63_361, 200, 114_213, 14_324, 14_267),
    "FB400k": KGStats("FB400k", 409_829, 918, 1_075_837, 537_917, 537_917),
    "ogbl-wikikg2": KGStats("ogbl-wikikg2", 2_500_604, 535, 16_109_182, 429_456, 598_543),
    "ATLAS-Wiki-Triple-4M": KGStats(
        "ATLAS-Wiki-Triple-4M", 4_035_238, 512_064, 23_040_868, 2_880_108, 2_880_110
    ),
}


class KnowledgeGraph:
    """Append-only triple store with CSR adjacency for fast traversal.

    Adjacency is keyed by (head, relation) via a sorted (h * R + r) index so
    ``neighbors(h, r)`` is two binary searches — the access pattern the online
    sampler (App. F) hammers.

    The store is immutable between writes; the one mutation is
    ``add_triples`` (online KG growth), which rebuilds the CSR index, drops
    every ``cached_property`` adjacency view and notifies invalidation
    listeners — the hook materialized caches (``core/matcache.py``) use to
    bump their version stamp so rows encoded against the old graph are
    never served.
    """

    # cached_property views derived from ``triples`` — every name here must
    # be dropped from ``__dict__`` on a write or stale adjacency survives.
    _CACHED_VIEWS = ("out_degree", "degree", "edges_with_outgoing",
                     "relations_by_head", "incoming_by_tail",
                     "entities_with_incoming")

    def __init__(self, n_entities: int, n_relations: int, triples: np.ndarray, name: str = "kg"):
        self.name = name
        self.n_entities = int(n_entities)
        self.n_relations = int(n_relations)
        self.version = 0
        self._listeners: list = []
        self._build(triples)

    def _build(self, triples: np.ndarray) -> None:
        assert triples.ndim == 2 and triples.shape[1] == 3
        # Deduplicate and sort by (h, r, t).
        key = (
            triples[:, 0].astype(np.int64) * self.n_relations + triples[:, 1].astype(np.int64)
        ) * self.n_entities + triples[:, 2].astype(np.int64)
        order = np.argsort(key, kind="stable")
        key = key[order]
        keep = np.concatenate([[True], key[1:] != key[:-1]])
        self.triples = triples[order][keep].astype(np.int64)
        # CSR over (h, r).
        self._hr = self.triples[:, 0] * self.n_relations + self.triples[:, 1]
        self._tails = np.ascontiguousarray(self.triples[:, 2])

    def __len__(self) -> int:
        return self.triples.shape[0]

    # ------------------------------------------------------------ KG writes
    def add_invalidation_listener(self, fn) -> None:
        """Register ``fn(reason: str)`` to be called after every write —
        e.g. ``MaterializedSubqueryCache.bump_version`` via ``watch_kg``."""
        self._listeners.append(fn)

    def add_triples(self, new_triples) -> "KnowledgeGraph":
        """Online KG write: merge new (h, r, t) rows (duplicates of existing
        triples are absorbed), rebuild the CSR index, invalidate every
        cached adjacency view and notify listeners. Bumps ``version``."""
        new = np.asarray(new_triples, dtype=np.int64).reshape(-1, 3)
        if len(new):
            ents = new[:, [0, 2]]
            if ents.min() < 0 or ents.max() >= self.n_entities:
                raise ValueError("entity id out of range")
            if new[:, 1].min() < 0 or new[:, 1].max() >= self.n_relations:
                raise ValueError("relation id out of range")
        self._build(np.concatenate([self.triples, new], axis=0))
        for name in self._CACHED_VIEWS:
            self.__dict__.pop(name, None)
        self.version += 1
        for fn in list(self._listeners):
            fn("kg_write")
        return self

    def neighbors(self, h: int, r: int) -> np.ndarray:
        """All tails t with (h, r, t) in the graph."""
        hr = h * self.n_relations + r
        lo = np.searchsorted(self._hr, hr, side="left")
        hi = np.searchsorted(self._hr, hr, side="right")
        return self._tails[lo:hi]

    def neighbors_of_set(self, heads: np.ndarray, r: int) -> np.ndarray:
        """Union of tails over a set of heads for one relation (Project op)."""
        if len(heads) == 0:
            return np.empty((0,), dtype=np.int64)
        hr = np.asarray(heads, dtype=np.int64) * self.n_relations + r
        lo = np.searchsorted(self._hr, hr, side="left")
        hi = np.searchsorted(self._hr, hr, side="right")
        parts = [self._tails[a:b] for a, b in zip(lo, hi) if b > a]
        if not parts:
            return np.empty((0,), dtype=np.int64)
        return np.unique(np.concatenate(parts))

    @cached_property
    def out_degree(self) -> np.ndarray:
        deg = np.zeros(self.n_entities, dtype=np.int64)
        np.add.at(deg, self.triples[:, 0], 1)
        return deg

    @cached_property
    def degree(self) -> np.ndarray:
        deg = np.zeros(self.n_entities, dtype=np.int64)
        np.add.at(deg, self.triples[:, 0], 1)
        np.add.at(deg, self.triples[:, 2], 1)
        return deg

    @cached_property
    def edges_with_outgoing(self) -> np.ndarray:
        """Entities with at least one outgoing edge (valid anchor starts)."""
        return np.unique(self.triples[:, 0])

    @cached_property
    def relations_by_head(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR (indptr, relations, tails) grouped by head for random walks."""
        order = np.argsort(self.triples[:, 0], kind="stable")
        heads = self.triples[order, 0]
        indptr = np.searchsorted(heads, np.arange(self.n_entities + 1))
        return indptr, self.triples[order, 1], self.triples[order, 2]

    @cached_property
    def incoming_by_tail(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR (indptr, relations, heads) grouped by tail — used by the online
        sampler's backward ground-truth instantiation (App. F)."""
        order = np.argsort(self.triples[:, 2], kind="stable")
        tails = self.triples[order, 2]
        indptr = np.searchsorted(tails, np.arange(self.n_entities + 1))
        return indptr, self.triples[order, 1], self.triples[order, 0]

    @cached_property
    def entities_with_incoming(self) -> np.ndarray:
        return np.unique(self.triples[:, 2])


def generate_synthetic_kg(
    n_entities: int,
    n_relations: int,
    n_triples: int,
    seed: int = 0,
    hub_exponent: float = 0.8,
    name: str = "synthetic",
) -> KnowledgeGraph:
    """Power-law synthetic KG (degree-weighted, matching App. C's sampling).

    Head/tail entities are drawn from a Zipf-like distribution so the graph
    has hub structure like FB15k/wikikg2; relation usage is also skewed.
    """
    rng = np.random.default_rng(seed)
    ent_w = (np.arange(1, n_entities + 1, dtype=np.float64)) ** (-hub_exponent)
    ent_p = ent_w / ent_w.sum()
    rel_w = (np.arange(1, n_relations + 1, dtype=np.float64)) ** (-0.5)
    rel_p = rel_w / rel_w.sum()
    # Oversample then dedup to hit ~n_triples unique triples.
    m = int(n_triples * 1.3) + 16
    h = rng.choice(n_entities, size=m, p=ent_p)
    t = rng.choice(n_entities, size=m, p=ent_p)
    r = rng.choice(n_relations, size=m, p=rel_p)
    tri = np.stack([h, r, t], axis=1)
    kg = KnowledgeGraph(n_entities, n_relations, tri, name=name)
    if len(kg) > n_triples:
        keep = rng.choice(len(kg), size=n_triples, replace=False)
        kg = KnowledgeGraph(n_entities, n_relations, kg.triples[keep], name=name)
    return kg


def split_kg(kg: KnowledgeGraph, valid_frac: float = 0.05, test_frac: float = 0.05, seed: int = 0):
    """Edge split into (train_kg, valid_edges, test_edges) — the Predictive
    Query Answering setting: G_train ⊂ G_full."""
    rng = np.random.default_rng(seed)
    n = len(kg)
    perm = rng.permutation(n)
    n_valid = int(n * valid_frac)
    n_test = int(n * test_frac)
    valid = kg.triples[perm[:n_valid]]
    test = kg.triples[perm[n_valid : n_valid + n_test]]
    train = kg.triples[perm[n_valid + n_test :]]
    train_kg = KnowledgeGraph(kg.n_entities, kg.n_relations, train, name=kg.name + "-train")
    return train_kg, valid, test


# Reduced stand-ins used on CPU (same family, ~1000x smaller).
REDUCED_SCALE: Dict[str, Tuple[int, int, int]] = {
    # name -> (entities, relations, triples)
    "FB15k": (600, 40, 8000),
    "FB15k-237": (580, 24, 5000),
    "NELL995": (900, 20, 2500),
    "FB400k": (2000, 60, 9000),
    "ogbl-wikikg2": (4000, 50, 24000),
    "ATLAS-Wiki-Triple-4M": (6000, 200, 34000),
}


def load_dataset(name: str, reduced: bool = True, seed: int = 0):
    """Returns (train_kg, full_kg, stats). ``reduced`` is mandatory on CPU;
    full-scale graphs exist only as ShapeDtypeStructs in the dry-run."""
    stats = TABLE4[name]
    if not reduced:
        raise RuntimeError(
            "Full-scale KGs are dry-run-only in this container; use reduced=True."
        )
    e, r, t = REDUCED_SCALE[name]
    full = generate_synthetic_kg(e, r, t, seed=seed, name=name)
    train_kg, _, _ = split_kg(full, seed=seed)
    return train_kg, full, stats
