from repro.data.kg import (
    TABLE4,
    KGSnapshot,
    KGStats,
    KnowledgeGraph,
    SnapshotUnavailable,
    generate_synthetic_kg,
    load_dataset,
    split_kg,
)

__all__ = [
    "TABLE4",
    "KGSnapshot",
    "KGStats",
    "KnowledgeGraph",
    "SnapshotUnavailable",
    "generate_synthetic_kg",
    "load_dataset",
    "split_kg",
]
