"""Producer/consumer data pipeline (§4.3 "Heterogeneous Pipelining").

While the accelerator executes the current pooled batch, host workers sample
the next queries (CSR traversal + rejection sampling are pure numpy and
release the GIL in the hot loops). This is the TPU analogue of the paper's
CPU↔GPU pipeline: the host side overlaps with async-dispatched device steps.

Straggler mitigation: multiple producers feed one queue; a slow producer
(e.g. pathological rejection sampling streak) cannot stall training because
consumption order is whoever-finishes-first, and a watchdog re-issues work
items that exceed a deadline.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import List, Optional

from repro.sampling.online import OnlineSampler, SampledQuery


class BatchPrefetcher:
    def __init__(
        self,
        sampler: OnlineSampler,
        batch_size: int,
        depth: int = 2,
        workers: int = 2,
        deadline_s: float = 30.0,
    ):
        self.sampler = sampler
        self.batch_size = batch_size
        self.deadline_s = deadline_s
        self._q: "queue.Queue[List[SampledQuery]]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._inflight = 0
        self._lock = threading.Lock()
        self._last_progress = time.monotonic()
        self.restarts = 0
        self._threads = [
            threading.Thread(target=self._produce, args=(i,), daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()
        self._watchdog = threading.Thread(target=self._watch, daemon=True)
        self._watchdog.start()

    def _produce(self, worker_id: int) -> None:
        # Each worker gets an independent RNG stream so batches differ.
        import numpy as np

        local = OnlineSampler(
            self.sampler.kg,
            patterns=self.sampler.patterns,
            seed=hash((id(self), worker_id)) % (2**31),
            max_rejects=self.sampler.max_rejects,
            max_answers=self.sampler.max_answers,
        )
        while not self._stop.is_set():
            try:
                batch = local.sample_batch(self.batch_size)
            except RuntimeError:
                continue  # rejection streak: drop and retry (straggler-safe)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.25)
                    with self._lock:
                        self._last_progress = time.monotonic()
                    break
                except queue.Full:
                    continue

    def _watch(self) -> None:
        """Restart a producer if the queue has been starved past deadline."""
        while not self._stop.is_set():
            time.sleep(self.deadline_s / 4)
            with self._lock:
                starved = (
                    self._q.empty()
                    and time.monotonic() - self._last_progress > self.deadline_s
                )
            if starved:
                self.restarts += 1
                t = threading.Thread(
                    target=self._produce, args=(len(self._threads) + self.restarts,),
                    daemon=True,
                )
                t.start()
                self._threads.append(t)
                with self._lock:
                    self._last_progress = time.monotonic()

    def next(self, timeout: float = 120.0) -> List[SampledQuery]:
        return self._q.get(timeout=timeout)

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
