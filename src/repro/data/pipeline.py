"""Producer/consumer data pipeline (§4.3 "Heterogeneous Pipelining").

While the accelerator executes the current pooled batch, host workers sample
the next queries (CSR traversal + rejection sampling are pure numpy and
release the GIL in the hot loops). This is the TPU analogue of the paper's
CPU↔GPU pipeline: the host side overlaps with async-dispatched device steps.

Two stages (DESIGN.md §Pipeline):

* ``BatchPrefetcher`` — sampling workers producing raw query batches.
* ``PreparedBatchPrefetcher`` — a background *scheduler thread* that consumes
  raw batches and runs everything that used to sit on the training critical
  path: negative sampling arrays, batch canonicalization, and Algorithm-1
  scheduling (``PooledExecutor.prepare``). Its output queue holds fully
  device-ready work items, so the main thread only dispatches jit calls while
  XLA executes the previous step — scheduling for batch k+1 overlaps device
  execution of batch k.

Straggler mitigation: multiple producers feed one queue; a slow producer
(e.g. pathological rejection sampling streak) cannot stall training because
consumption order is whoever-finishes-first, and a watchdog re-issues work
items that exceed a deadline.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from repro.obs.registry import get_registry
from repro.obs.trace import TRACER
from repro.sampling.online import OnlineSampler, SampledQuery


class BatchPrefetcher:
    def __init__(
        self,
        sampler: OnlineSampler,
        batch_size: int,
        depth: int = 2,
        workers: int = 2,
        deadline_s: float = 30.0,
    ):
        self.sampler = sampler
        self.batch_size = batch_size
        self.deadline_s = deadline_s
        self._q: "queue.Queue[List[SampledQuery]]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._inflight = 0
        self._lock = threading.Lock()
        self._last_progress = time.monotonic()
        self.restarts = 0
        self._threads = [
            threading.Thread(target=self._produce, args=(i,), daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()
        self._watchdog = threading.Thread(target=self._watch, daemon=True)
        self._watchdog.start()

    def _produce(self, worker_id: int) -> None:
        # Each worker gets an independent RNG stream so batches differ.
        import numpy as np

        TRACER.set_lane(f"sampling worker {worker_id}")
        local = OnlineSampler(
            self.sampler.kg,
            patterns=self.sampler.patterns,
            seed=hash((id(self), worker_id)) % (2**31),
            max_rejects=self.sampler.max_rejects,
            max_answers=self.sampler.max_answers,
        )
        while not self._stop.is_set():
            try:
                with TRACER.span("sample", n=self.batch_size):
                    batch = local.sample_batch(self.batch_size)
            except RuntimeError:
                continue  # rejection streak: drop and retry (straggler-safe)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.25)
                    with self._lock:
                        self._last_progress = time.monotonic()
                    break
                except queue.Full:
                    continue

    def _watch(self) -> None:
        """Restart a producer if the queue has been starved past deadline."""
        while not self._stop.is_set():
            time.sleep(self.deadline_s / 4)
            with self._lock:
                starved = (
                    self._q.empty()
                    and time.monotonic() - self._last_progress > self.deadline_s
                )
            if starved:
                self.restarts += 1
                t = threading.Thread(
                    target=self._produce, args=(len(self._threads) + self.restarts,),
                    daemon=True,
                )
                t.start()
                self._threads.append(t)
                with self._lock:
                    self._last_progress = time.monotonic()

    def next(self, timeout: float = 120.0) -> List[SampledQuery]:
        return self._q.get(timeout=timeout)

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def batch_entity_ids(queries, pos: np.ndarray, neg: np.ndarray) -> np.ndarray:
    """Every entity id one training step gathers semantic rows for: query
    anchors (EMBED pools) plus the positive/negative score candidates. This
    is the set the semantic hot-set cache must have staged before dispatch."""
    return np.concatenate(
        [np.asarray(q.anchors).ravel() for q in queries]
        + [np.asarray(pos).ravel(), np.asarray(neg).ravel()])


def prepare_work_item(sampler, executor, batch, n_negatives: int,
                      dev_static=None, sem_cache=None,
                      ctx=None, mat_cache=None) -> "PreparedWorkItem":
    """Run the full host side of one training step: negative-sampling arrays,
    plan compilation (canonicalize → CSE → Algorithm-1 lowering, i.e.
    ``executor.prepare`` returning a ``CompiledPlan``), and device transfer
    — the scheduler thread ships fully compiled plans, so the main thread
    only dispatches.

    ``dev_static`` (optional, a ``CompileCache``) caches device-resident
    static slot arrays by STRUCTURE key — under CSE that is the deduped
    topology, so they never change between batches sharing a post-CSE shape
    and transfer once instead of once per step. The structure key is
    essential: the coarser program signature only encodes bucketed shapes,
    and two different structures (e.g. 5 vs 6 queries padding to the same
    buckets) may share a signature while having different slot/answer
    arrays.

    ``sem_cache`` (optional, a ``semantic.store.SemanticCache``) is the
    prefetch half of the out-of-core semantic path: the batch's entity-id
    set is extracted HERE, on the scheduler thread, and the missing rows are
    read from the on-disk store, dequantized and device-put while the
    previous batch executes — the returned ``sem_stage`` is applied by the
    main thread right before this batch dispatches, so steady-state training
    never does a synchronous mid-step store read.

    ``ctx`` (an ``ExecutionContext``) makes every device put here
    sharding-aware: batch-like arrays go straight into the batch shardings
    the fused step was compiled against (``ctx.put_batch``), so the transfer
    happens once, on this thread, and dispatch does zero resharding. When
    omitted (or single-device) the puts are plain ``jnp.asarray`` —
    bit-for-bit the historical path.

    ``mat_cache`` (a ``core.matcache.MaterializedSubqueryCache``) is probed
    HERE, on the scheduler thread, like the semantic prefetch: the work item
    records how many of the batch's queries already have materialized rows
    at the current version (``mat_hits``/``mat_version``). Training itself
    never CONSUMES those rows — a cached constant inside the fused train
    step would detach its subtree's gradient — but the probe exercises the
    cross-thread lock discipline and surfaces reuse-potential counters,
    and inference consumers sharing the cache (eval after training, a
    co-located serving engine) get the rows the trainer's version bumps
    keep honest."""
    import jax.numpy as jnp  # deferred: keep module import light

    put = jnp.asarray
    if ctx is not None and ctx.is_sharded:
        put = ctx.put_batch

    # Per-phase wall times are ALWAYS collected (a perf_counter pair each —
    # nanoseconds against a multi-ms step) so step-time breakdowns work even
    # with the tracer off; the spans only fire when tracing is on.
    phases = {}
    t0 = time.perf_counter()
    queries, pos, neg = sampler.to_training_arrays(batch, n_negatives)
    phases["negatives_s"] = time.perf_counter() - t0
    sem_stage = None
    if sem_cache is not None:
        t0 = time.perf_counter()
        with TRACER.span("sem_prefetch", n=len(queries)):
            sem_stage = sem_cache.plan(batch_entity_ids(queries, pos, neg),
                                       background=True)
        phases["sem_prefetch_s"] = time.perf_counter() - t0
    mat_hits, mat_version = 0, -1
    if mat_cache is not None:
        mat_version = mat_cache.version
        mat_hits = mat_cache.probe([q.key() for q in queries],
                                   version=mat_version)
    t0 = time.perf_counter()
    with TRACER.span("schedule", n=len(queries)):
        prepared = executor.prepare(queries)
    phases["schedule_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    with TRACER.span("transfer", n_steps=len(prepared.bind_arrays)):
        static = (dev_static.get(prepared.structure_key)
                  if dev_static is not None else None)
        if static is None:
            static = (
                [{k: put(v) for k, v in s.items()}
                 for s in prepared.slot_arrays],
                put(prepared.answer_slots),
            )
            if dev_static is not None:
                dev_static.put(prepared.structure_key, static)
        slot_dev, ans = static
        steps = [
            {**s, **{k: put(v) for k, v in b.items()}}
            for s, b in zip(slot_dev, prepared.bind_arrays)
        ]
        pos_dev = put(pos[prepared.order])
        neg_dev = put(neg[prepared.order])
    phases["transfer_s"] = time.perf_counter() - t0
    return PreparedWorkItem(
        prepared=prepared,
        steps=steps,
        ans=ans,
        pos=pos_dev,
        neg=neg_dev,
        patterns=prepared.patterns,
        n_queries=len(queries),
        sem_stage=sem_stage,
        mat_hits=mat_hits,
        mat_version=mat_version,
        phases=phases,
    )


@dataclasses.dataclass
class PreparedWorkItem:
    """One fully host-scheduled training step, ready for device dispatch.

    ``pos``/``neg`` are already permuted into the prepared batch's canonical
    (pattern-sorted) order, and ``steps``/``ans``/``pos``/``neg`` are already
    device arrays (transferred from the scheduler thread), so the consumer
    never touches numpy on the critical path — it just dispatches the jitted
    program."""

    prepared: object            # repro.core.plan.CompiledPlan
    steps: List[dict]           # device-resident slot/bind arrays per step
    ans: object                 # device answer_slots
    pos: object                 # [B] positives, canonical order (device)
    neg: object                 # [B, K] negatives, canonical order (device)
    patterns: List[str]         # canonical order, for adaptive sampling
    n_queries: int
    sem_stage: object = None    # semantic.store.SemStage: rows prefetched on
    #                             the scheduler thread; main thread applies
    #                             it (one donated scatter) before dispatch
    mat_hits: int = 0           # queries with a materialized row resident at
    mat_version: int = -1       # this cache version when the item was staged
    phases: dict = dataclasses.field(default_factory=dict)
    #                             scheduler-thread phase wall times (seconds):
    #                             negatives_s/sem_prefetch_s/schedule_s/
    #                             transfer_s (+ sample_s added by the
    #                             prefetcher) — feeds step-time breakdowns


class PreparedBatchPrefetcher:
    """Background-thread prefetch queue feeding the Algorithm-1 scheduler.

    A single scheduler thread pulls raw batches (from an internal
    ``BatchPrefetcher``, or from ``batch_fn`` when the caller controls the
    workload — e.g. benchmarks replaying a fixed batch list), builds the
    training arrays, and runs ``executor.prepare`` so the schedule cache and
    all bind arrays are ready before the trainer ever sees the item.

    One scheduler thread by design — and deliberately few threads overall:
    ``executor.prepare`` mutates the executor's signature-keyed caches (a
    single consumer makes that race-free without locking the hot path), and
    under the GIL only one Python thread makes progress at a time anyway, so
    extra host threads just add handoff latency. When ``batch_fn`` is given
    (deterministic batch source), it runs inside the scheduler thread;
    otherwise an internal ``BatchPrefetcher`` supplies sampled batches.
    """

    def __init__(
        self,
        sampler: OnlineSampler,
        executor,
        batch_size: int,
        n_negatives: int,
        depth: int = 2,
        workers: int = 2,
        batch_fn: Optional[Callable[[], List[SampledQuery]]] = None,
        sem_cache=None,
        ctx=None,
        mat_cache=None,
    ):
        self.sampler = sampler
        self.executor = executor
        self.n_negatives = n_negatives
        self.sem_cache = sem_cache
        self.ctx = ctx
        self.mat_cache = mat_cache
        self._q: "queue.Queue[PreparedWorkItem]" = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._batches: Optional[BatchPrefetcher] = None
        if batch_fn is None:
            self._batches = BatchPrefetcher(sampler, batch_size, depth=depth,
                                            workers=workers)
            self._next_batch = self._batches.next
        else:
            self._next_batch = batch_fn
        # Device-resident static slot arrays, keyed by structure key. LRU so
        # an unbounded signature stream (e.g. a pattern curriculum) cannot
        # grow device memory without bound.
        from repro.core.compile_cache import CompileCache

        self._dev_static = CompileCache(128, name="dev_static")
        # Scheduler-side telemetry: queue depth (how far ahead of the
        # consumer this thread runs) + cumulative phase seconds.
        self._metrics = get_registry().group("pipeline")
        self._depth_gauge = self._metrics.gauge("prepared_q_depth")
        self._phase_s = {
            name: self._metrics.counter("phase_seconds", phase=name)
            for name in ("sample", "negatives", "sem_prefetch", "schedule",
                         "transfer")}
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        TRACER.set_lane("pipeline scheduler")
        while not self._stop.is_set():
            try:
                t0 = time.perf_counter()
                # "sample" on this lane is raw-batch acquisition: the
                # sampling itself when batch_fn runs inline, queue wait on
                # the workers otherwise (their own lanes carry the real
                # sampling spans).
                with TRACER.span("sample"):
                    batch = self._next_batch()
                sample_s = time.perf_counter() - t0
                item = prepare_work_item(self.sampler, self.executor, batch,
                                         self.n_negatives, self._dev_static,
                                         sem_cache=self.sem_cache,
                                         ctx=self.ctx,
                                         mat_cache=self.mat_cache)
                item.phases["sample_s"] = sample_s
                for name, c in self._phase_s.items():
                    c.inc(item.phases.get(name + "_s", 0.0))
            except BaseException as e:  # surface on the consumer side
                if self._error is None:
                    self._error = e
                self._stop.set()
                return
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.25)
                    self._depth_gauge.set(self._q.qsize())
                    if TRACER.enabled:
                        TRACER.counter("prepared_q_depth",
                                       depth=self._q.qsize())
                    break
                except queue.Full:
                    continue

    def next(self, timeout: float = 120.0) -> PreparedWorkItem:
        while True:
            if self._error is not None:
                raise RuntimeError("prepared-batch prefetcher failed") from self._error
            try:
                return self._q.get(timeout=0.25)
            except queue.Empty:
                timeout -= 0.25
                if timeout <= 0:
                    raise

    def close(self) -> None:
        self._stop.set()
        if self._batches is not None:
            self._batches.close()
        # Keep draining while joining: the scheduler thread may be blocked in
        # a queue.put, and taking items is what wakes it immediately.
        deadline = time.monotonic() + 5.0
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.02)
