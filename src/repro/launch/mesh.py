"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS *before* calling it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e-256); 2 pods multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices are actually alive (elastic restores, examples)."""
    n = len(jax.devices())
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"cannot build a host mesh: {n} visible device(s) not divisible "
            f"by model_parallel={model_parallel}; pass a divisor of {n} "
            f"(e.g. model_parallel=1), or emulate more host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
