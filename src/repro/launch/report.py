"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSON records produced by launch/dryrun.py (via scripts/sweep_dryrun.sh).

  PYTHONPATH=src python -m repro.launch.report results/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

ARCH_ORDER = [
    "jamba-v0.1-52b", "qwen2-72b", "qwen3-4b", "qwen2-0.5b", "internlm2-20b",
    "whisper-large-v3", "llava-next-34b", "grok-1-314b", "mixtral-8x22b",
    "mamba2-1.3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str) -> Dict[str, Dict]:
    out = {}
    for f in glob.glob(os.path.join(results_dir, "*.json")):
        tag = os.path.basename(f)[: -len(".json")]
        try:
            out[tag] = json.load(open(f))
        except Exception:
            out[tag] = {"error": "unparseable"}
    return out


def _fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def _fmt_t(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs: Dict[str, Dict], suffix: str) -> List[str]:
    lines = ["| arch | shape | status | lower | compile | peak bytes/dev | collectives (raw program) |",
             "|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get(f"{arch}_{shape}_{suffix}")
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | |")
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | skip (full-attn @524k) | | | | |")
                continue
            if "error" in r:
                lines.append(f"| {arch} | {shape} | ERROR | | | | |")
                continue
            cnt = r.get("collectives_raw", {}).get("counts", {})
            cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in sorted(cnt.items()))
            lines.append(
                f"| {arch} | {shape} | ok | {r.get('lower_s')}s | {r.get('compile_s')}s "
                f"| {_fmt_b(r.get('memory', {}).get('peak_bytes'))} | {cstr} |")
    return lines


def roofline_table(recs: Dict[str, Dict]) -> List[str]:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS/dev | useful ratio | what would move the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get(f"{arch}_{shape}_sp_exact") or recs.get(f"{arch}_{shape}_sp")
            if not r or "skipped" in r or "error" in r:
                continue
            rf = r.get("roofline", {})
            exact = "cost_exact" in r and "error" not in r.get("cost_exact", {})
            hint = _bottleneck_hint(r)
            lines.append(
                f"| {arch} | {shape} | {_fmt_t(rf.get('compute_s'))} | "
                f"{_fmt_t(rf.get('memory_s'))} | {_fmt_t(rf.get('collective_s'))} | "
                f"**{rf.get('dominant')}**{'' if exact else ' (raw)'} | "
                f"{r.get('model_flops_per_device', 0):.2e} | "
                f"{r.get('useful_flops_ratio', 0):.2f} | {hint} |")
    return lines


def _bottleneck_hint(r: Dict) -> str:
    dom = r.get("roofline", {}).get("dominant")
    kind = r.get("kind")
    by = (r.get("cost_exact") or {}).get("collective_by_type") \
        or r.get("collectives_raw", {}).get("by_type", {})
    if dom == "collective":
        worst = max(by, key=by.get) if by else "?"
        return f"cut {worst} traffic (resharding/overlap)"
    if dom == "memory":
        if kind == "decode":
            return "decode is cache-bandwidth bound: shrink/quantize KV"
        return "reduce activation traffic: fuse, reshard residual stream"
    return "near compute roofline: increase arithmetic intensity"


def main() -> None:
    results_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(results_dir)
    print("## Dry-run, single pod 16x16 (data,model)\n")
    print("\n".join(dryrun_table(recs, "sp")))
    print("\n## Dry-run, multi-pod 2x16x16 (pod,data,model)\n")
    print("\n".join(dryrun_table(recs, "mp")))
    print("\n## Roofline (single pod, exact-cost extrapolation)\n")
    print("\n".join(roofline_table(recs)))
    ngdb = recs.get("ngdb_sp")
    if ngdb and "error" not in ngdb:
        print("\n## NGDB (the paper's model) production cell\n")
        print(json.dumps(ngdb, indent=1)[:2000])


if __name__ == "__main__":
    main()
