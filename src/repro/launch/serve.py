"""NGDB serving driver — a thin CLI over the continuous-batching engine
(``repro/serving``, DESIGN.md §Serving).

Generates a deterministic mixed-pattern request stream and drives the
``ServingEngine`` either closed-loop (``--concurrency`` requests in flight —
the max-throughput probe) or open-loop (``--qps`` fixed arrival rate — the
latency-under-load probe), reporting QPS, p50/p95/p99 latency, flush/batch
shape statistics and steady-state retrace counts.

Composes with the rest of the launch surface:

* ``--semantic-store DIR`` serves out-of-core (DESIGN.md §SemanticStore):
  anchors stage into the bounded device hot set on the batcher thread, and
  all-entity scoring streams H_sem from the mmap store in chunks.
* ``--mesh data=N[,model=M]`` serves mesh-sharded (DESIGN.md §Sharding):
  tables materialize into their NamedShardings and the scorer jit pins its
  logits replicated for host readback.

``serve_batch`` remains the one-shot OFFLINE baseline (used by benchmarks
and tests as the bit-identity oracle): it shares the engine's compiled
encode programs and process-wide cached scorer, so the two paths produce
identical results on identical micro-batch compositions — and repeated
calls trace ``score_all`` exactly once (the historical per-call re-jit is
fixed by routing through ``serving.scorer_for``).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import PooledExecutor
from repro.data import load_dataset
from repro.distributed.context import make_execution_context
from repro.models import ModelConfig, make_model, model_names
from repro.obs import MetricsSink, TRACER, get_registry
from repro.serving import (ServingConfig, ServingEngine, make_workload,
                           run_closed_loop, run_open_loop, scorer_for,
                           topk_desc)
from repro.training.checkpoint import load_checkpoint

__all__ = ["serve_batch", "topk_desc", "main"]  # topk_desc re-exported


def serve_batch(model, params, executor, queries, top_k: int = 10,
                score_all_fn=None, sem_cache=None, ctx=None):
    """One-shot synchronous batch serving — the offline baseline the engine
    is verified against. Encoding goes through the executor's per-signature
    compiled programs and scoring through the process-wide cached jit
    (``scorer_for``; pass the engine's ``ctx`` under a mesh so both paths
    resolve the SAME scorer program) — zero retraces across repeated
    calls."""
    if sem_cache is not None:
        if score_all_fn is None:
            # Hot-set-cache params cannot dense-score (score_all refuses the
            # bounded buffer); fail before doing any staging work.
            raise ValueError(
                "serve_batch with sem_cache needs score_all_fn (e.g. "
                "lambda p, q: model.score_all_chunked(p, q, store.read_rows))")
        # Serving counts as synchronous staging (no pipeline in front of it);
        # steady traffic converges to hits as the hot set fills.
        anchors = np.concatenate([q.anchors for q in queries])
        stage = sem_cache.plan(anchors)
        if stage is not None:
            params = sem_cache.apply_to(params, stage)
    states = executor.encode(params, queries, compiled=True)
    if score_all_fn is None:
        score_all_fn = scorer_for(model, ctx)
    scores = np.asarray(score_all_fn(params, states))
    idx = topk_desc(scores, top_k)
    return [
        {"pattern": q.pattern,
         "anchors": q.anchors.tolist(),
         "relations": q.relations.tolist(),
         "top_entities": idx[i].tolist(),
         "scores": scores[i, idx[i]].round(3).tolist()}
        for i, q in enumerate(queries)
    ], params


def _parse_tenants(tenants_spec, mix_spec):
    """``--tenants "gold:high,bronze:low[:quota]"`` and
    ``--priority-mix "gold=0.25,bronze=0.75"`` -> (specs, weights).
    With no ``--tenants``, everything rides the router's default tenant."""
    from repro.serving import TenantSpec

    if not tenants_spec:
        return [], {}
    specs = []
    for part in tenants_spec.split(","):
        bits = part.strip().split(":")
        if len(bits) not in (2, 3):
            raise ValueError(f"tenant spec {part!r}: want name:priority"
                             f"[:max_inflight]")
        quota = int(bits[2]) if len(bits) == 3 else 0
        specs.append(TenantSpec(bits[0], bits[1], quota))
    weights = {s.name: 1.0 for s in specs}
    if mix_spec:
        weights = {}
        for part in mix_spec.split(","):
            name, w = part.split("=")
            weights[name.strip()] = float(w)
        unknown = set(weights) - {s.name for s in specs}
        if unknown:
            raise ValueError(f"--priority-mix names unknown tenants "
                             f"{sorted(unknown)}")
    total = sum(weights.values())
    return specs, {n: w / total for n, w in weights.items()}


def _serve_tier(args, kg, model, params, ctx) -> None:
    """Multi-replica serving tier (DESIGN.md §ServingTier): rendezvous
    plan-cache-affinity routing over ``--replicas`` engines with per-tenant
    priority admission and typed low-priority sheds."""
    from repro.serving import (ReplicaPool, Router, TenantLoad, run_tenant_mix)

    specs, weights = _parse_tenants(args.tenants, args.priority_mix)
    cfg = ServingConfig(max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms,
                        queue_depth=args.queue_depth, top_k=args.top_k)
    pool = ReplicaPool(model, params, n_replicas=args.replicas, cfg=cfg,
                       mat_budget_rows=args.materialize, ctx=ctx)
    router = Router(pool, tenants=specs)
    workload = make_workload(kg, args.requests, seed=7)

    # Warmup compiles every signature each home replica will see (placement
    # is deterministic, so the timed pass replays onto warm caches).
    t0 = time.time()
    for f in router.submit_many(workload):
        f.result(timeout=120.0)
    print(f"warmup: {args.requests} requests over {args.replicas} replicas "
          f"in {time.time()-t0:.1f}s")
    pool.reset_counters()

    if specs:
        loads = []
        start = 0
        for s in specs:  # contiguous weighted shares, submission-paced
            n = max(1, int(round(weights[s.name] * len(workload))))
            qs = (workload[start:start + n]
                  or workload[: max(1, len(workload) // len(specs))])
            start += len(qs)
            loads.append(TenantLoad(s.name, qs,
                                    qps=args.qps * weights[s.name]))
        reports = run_tenant_mix(router, loads)
        for name in sorted(reports):
            print(reports[name].describe())
    else:
        report = run_open_loop(engine=router, queries=workload, qps=args.qps)
        print(report.describe())

    st = router.stats()
    for rid, rs in sorted(st["pool"]["per_replica"].items()):
        mc = rs.get("mat_cache")
        mat = (f", mat hit rate {mc['hit_rate']:.2%}" if mc else "")
        print(f"replica {rid}: {rs['submitted']} requests, "
              f"{rs['batches']} micro-batches, "
              f"{rs['retraces']} steady-state retraces{mat}")
    print(f"router: {st['routed']} routed, {st['spilled']} spilled, "
          f"{st['shed']} shed")
    for name, ts in sorted(st["tenants"].items()):
        if ts["submitted"] or ts["shed"]:
            sheds = {r: c for r, c in ts["shed"].items() if c}
            print(f"tenant {name} ({ts['priority']}): "
                  f"{ts['completed']}/{ts['submitted']} completed, "
                  f"shed {sheds or 0}, p99 {ts['latency_ms']['p99']:.1f} ms")
    if args.metrics:
        with MetricsSink(args.metrics) as sink:
            sink.write({"kind": "snapshot",
                        "metrics": get_registry().snapshot()})
        print(f"metrics: wrote {args.metrics}")
    router.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--model", default="betae", choices=model_names())
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=256,
                    help="total requests in the generated workload")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop arrival rate; 0 = closed loop at "
                         "--concurrency in-flight requests")
    ap.add_argument("--concurrency", type=int, default=32,
                    help="closed-loop in-flight window (ignored with --qps)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="engine micro-batch size-flush threshold")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="engine age-flush: max wait of the oldest pending "
                         "request before a partial batch dispatches")
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="bounded admission queue (backpressure limit)")
    ap.add_argument("--materialize", type=int, default=0, metavar="N",
                    help="materialized-subquery cache: keep up to N encoded "
                         "rows keyed by query, consulted by the batcher "
                         "before padding so duplicate-heavy traffic skips "
                         "re-encoding entirely (version-stamped — "
                         "invalidated on param updates and KG writes; "
                         "0 = off)")
    ap.add_argument("--no-cse", action="store_true",
                    help="ablation: disable cross-query subexpression "
                         "sharing in the plan compiler (duplicate subqueries "
                         "across co-batched requests are recomputed per "
                         "request)")
    ap.add_argument("--semantic-store", default=None, metavar="DIR",
                    help="serve out-of-core: H_sem stays on disk; device "
                         "holds only the hot-set cache (built by "
                         "launch/train.py --semantic-store)")
    ap.add_argument("--semantic-budget-rows", type=int, default=2048)
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="mesh-shard serving: data=N[,model=M] (DESIGN.md "
                         "§Sharding); emulate devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--profile", default="2d", choices=["2d", "fsdp"])
    ap.add_argument("--latency-window", type=int, default=None,
                    help="latency percentile window size (requests); "
                         "default = engine's built-in window")
    ap.add_argument("--client-threads", type=int, default=1,
                    help="closed-loop client submitter threads (each is a "
                         "named lane in the trace; ignored with --qps)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace-event/Perfetto JSON timeline "
                         "of the timed replay (lanes: client N, serving "
                         "batcher; spans: request/batch/sem_prefetch/encode/"
                         "score/select). Load at ui.perfetto.dev")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write a final registry snapshot (engine counters, "
                         "latency histogram, cache stats) as JSONL; "
                         "summarize with python -m repro.obs.report")
    ap.add_argument("--max-staleness", type=int, default=0, metavar="V",
                    help="staleness-bounded serving (DESIGN.md §LiveStore): "
                         "attach the live graph and admit version-pinned "
                         "requests up to V graph versions behind; out-of-"
                         "bound pins are shed with StaleVersionError")
    ap.add_argument("--live-writes", type=int, default=0, metavar="N",
                    help="fire N live write bursts through LiveNGDB during "
                         "the timed replay (graph commit + background "
                         "incremental fine-tune) and report graph version / "
                         "stale sheds / fine-tune count")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="multi-replica serving tier (DESIGN.md "
                         "§ServingTier): N engines with private plan/"
                         "materialized caches behind a rendezvous-affinity "
                         "router; 1 (default) = the single-engine path, "
                         "byte-for-byte unchanged")
    ap.add_argument("--tenants", default=None, metavar="SPEC",
                    help="router tenants as name:priority[:max_inflight],"
                         "... e.g. 'gold:high,bronze:low' — low priority is "
                         "shed (typed, never blocking) under backpressure; "
                         "needs --replicas")
    ap.add_argument("--priority-mix", default=None, metavar="SPEC",
                    help="traffic share per tenant, e.g. "
                         "'gold=0.25,bronze=0.75' (default: equal shares); "
                         "needs --tenants")
    ap.add_argument("--autotune-cache", default=None, metavar="PATH",
                    help="persisted kernel-tile autotune cache (DESIGN.md "
                         "§Autotuner): tuned configs load from PATH and the "
                         "serving executor pads pools kernel-aware; also the "
                         "default via REPRO_AUTOTUNE_CACHE (run.sh sets it)")
    args = ap.parse_args()

    ctx = make_execution_context(args.mesh, profile=args.profile)
    if ctx.is_sharded:
        print(f"execution context: {ctx.describe()} "
              f"({ctx.n_devices} devices, dp={ctx.dp_size})")

    kg, _, _ = load_dataset(args.dataset)
    store, cache = None, None
    sem_dim = 0
    if args.semantic_store:
        from repro.semantic import SemanticCache, SemanticStore

        store = SemanticStore(args.semantic_store)
        assert store.n_rows == kg.n_entities, (store.n_rows, kg.n_entities)
        sem_dim = store.dim
        cache = SemanticCache(store, budget_rows=min(args.semantic_budget_rows,
                                                     kg.n_entities), ctx=ctx)
        print(f"semantic store: {store.n_rows}x{store.dim} {store.quant}, "
              f"{cache.device_resident_sem_bytes/1e6:.2f} MB device-resident")
    model = make_model(args.model,
                       ModelConfig(dim=args.dim, semantic_dim=sem_dim,
                                   entity_pad=max(1, ctx.n_devices)))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations, semantic_cache=cache, ctx=ctx)
    if args.ckpt_dir:
        restored = load_checkpoint(args.ckpt_dir,
                                   template={"params": params, "opt": None})
        if restored:
            params = restored[1]["params"]
            print(f"loaded checkpoint step={restored[0]}")
            if cache is not None:
                cache.reset()  # restored cache buffers: nothing resident yet

    if args.autotune_cache:
        # Must land before the executor exists: it snapshots its kernel-aware
        # tile policy from the process tuner at construction.
        from repro.kernels import autotune as kat

        tuner = kat.KernelTuner(path=args.autotune_cache)
        kat.set_tuner(tuner)
        if len(tuner):
            print(f"autotune: {len(tuner)} tuned configs loaded "
                  f"from {tuner.path}")

    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.priority_mix and not args.tenants:
        ap.error("--priority-mix needs --tenants")
    if args.replicas > 1 or args.tenants:
        # The tier composes with dense in-memory serving only: the semantic
        # hot set is one shared device buffer and live-graph versioning is a
        # single-engine axis (see serving/replica.py).
        if args.semantic_store or args.live_writes or args.max_staleness:
            ap.error("--replicas/--tenants do not compose with "
                     "--semantic-store/--live-writes/--max-staleness "
                     "(single-engine features)")
        if args.no_cse:
            ap.error("--no-cse is a single-engine ablation")
        _serve_tier(args, kg, model, params, ctx)
        return

    executor = PooledExecutor(model, b_max=256, ctx=ctx, cse=not args.no_cse)
    mat_cache = None
    if args.materialize > 0:
        from repro.core import MaterializedSubqueryCache

        mat_cache = MaterializedSubqueryCache(args.materialize)
        mat_cache.watch_kg(kg)
        print(f"materialized cache: {args.materialize} rows "
              f"(invalidated on param update / KG write)")
    live = args.live_writes > 0 or args.max_staleness > 0
    if live and cache is not None:
        ap.error("--live-writes/--max-staleness do not compose with "
                 "--semantic-store (the device hot set is incompatible with "
                 "version-pinned replay)")
    cfg = ServingConfig(max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms,
                        queue_depth=args.queue_depth, top_k=args.top_k,
                        max_staleness_versions=args.max_staleness)
    engine = ServingEngine(model, params, executor=executor, cfg=cfg,
                           sem_cache=cache,
                           sem_rows_fn=store.read_rows if store else None,
                           ctx=ctx, mat_cache=mat_cache,
                           latency_window=args.latency_window,
                           kg=kg if live else None)
    workload = make_workload(kg, args.requests, seed=7)

    # Warmup pass compiles every signature the replay will form; the timed
    # pass then reports steady-state numbers (and its retrace count).
    t0 = time.time()
    run_closed_loop(engine, workload, concurrency=args.max_batch)
    print(f"warmup: {args.requests} requests in {time.time()-t0:.1f}s "
          f"({engine.retraces()} cold cache misses)")
    engine.reset_counters()

    # Trace only the timed steady-state replay (the batcher lane registered
    # itself at engine start; lane names survive enable()).
    if args.trace:
        TRACER.enable()
        TRACER.set_lane("loadgen main")
    writer, live_db = None, None
    if args.live_writes > 0:
        import threading

        from repro.serving import LiveNGDB

        live_db = LiveNGDB(model, kg, engine, finetune_steps=2)
        wrng = np.random.default_rng(23)

        def _write_bursts():
            for _ in range(args.live_writes):
                cand = np.stack([wrng.integers(0, kg.n_entities, 16),
                                 wrng.integers(0, kg.n_relations, 16),
                                 wrng.integers(0, kg.n_entities, 16)], axis=1)
                live_db.write(cand[~kg.contains(cand)][:4])
                time.sleep(0.01)

        writer = threading.Thread(target=_write_bursts, name="live-writer")
        writer.start()
    if args.qps > 0:
        report = run_open_loop(engine, workload, qps=args.qps)
    else:
        report = run_closed_loop(engine, workload,
                                 concurrency=args.concurrency,
                                 threads=args.client_threads)
    if writer is not None:
        writer.join()
        live_db.flush()
    if args.trace:
        TRACER.write(args.trace)
        TRACER.disable()
        print(f"trace: wrote {args.trace} (load at ui.perfetto.dev)")
    st = engine.stats()
    print(report.describe())
    print(f"engine: {st['batches']} micro-batches "
          f"(mean size {st['mean_batch_size']:.1f}, flushes {st['flushes']}, "
          f"padded rows {st['padded_row_frac']:.1%}), "
          f"{st['retraces']} steady-state retraces")
    sh = st["sharing"]
    print(f"plan compiler: CSE {'off' if args.no_cse else 'on'} — "
          f"{sh['pooled_rows_saved']} pooled rows saved "
          f"({sh['saved_frac']:.1%}), "
          f"{st['coalesced']} duplicate requests coalesced")
    pc = st.get("plan_cache")
    if pc is not None:
        print(f"plan cache: {pc['size']} canonical plans, "
              f"hit rate {pc['hit_rate']:.2%} "
              f"({pc['canonicalize_calls']} canonicalizations)")
    mc = st.get("mat_cache")
    if mc is not None:
        print(f"materialized rows: hit rate {mc['hit_rate']:.2%} "
              f"({mc['hits']} hits / {mc['misses']} misses), "
              f"{mc['live']} live, {mc['evictions']} evictions")
    if live:
        lag = st.get("version_lag_served", {})
        print(f"live graph: version {st['graph_version']} "
              f"(retained {st['retained_versions']}), "
              f"{st['stale_sheds']} stale sheds, "
              f"lag histogram {dict(sorted(lag.items()))}")
    if live_db is not None:
        n_fresh = sum(r.n_written for r in live_db.receipts)
        print(f"live writes: {len(live_db.receipts)} bursts, "
              f"{n_fresh} fresh triples, "
              f"{live_db.finetunes_done} background fine-tunes")
        live_db.close()
    print(f"first: {json.dumps(report.results[0])[:140]}...")
    if cache is not None:
        cs = cache.stats()
        print(f"semantic cache: hit rate {cs['hit_rate']:.2%}, "
              f"{cs['rows_staged']} rows staged from store")
    if args.metrics:
        with MetricsSink(args.metrics) as sink:
            sink.write({"kind": "snapshot",
                        "metrics": get_registry().snapshot()})
        print(f"metrics: wrote {args.metrics}")
    engine.close()


if __name__ == "__main__":
    main()
