"""Batched query-answering service (Atom-style serving on the same
operator-level engine). Loads a checkpoint, accepts batches of mixed-pattern
queries and returns top-k entities per query — the NGDB retrieval path.

Top-k selection is O(E) (``np.argpartition`` + a partial sort of the k
survivors) instead of a full O(E log E) ``argsort`` per query, and the
driver reports p50/p95 batch latency alongside throughput.

With ``--semantic-store`` the service runs out-of-core (DESIGN.md
§SemanticStore): query anchors are staged into the bounded device hot-set
cache before encoding, and all-entity scoring streams H_sem in bounded
chunks from the mmap store (``score_all_chunked``) — the full ``[E, d_l]``
table is never materialized.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import PooledExecutor
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training.checkpoint import load_checkpoint


def topk_desc(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest entries per row, descending — argpartition
    (linear in E) followed by an O(k log k) sort of just the survivors."""
    k = min(k, scores.shape[1])
    part = np.argpartition(-scores, k - 1, axis=1)[:, :k]
    part_scores = np.take_along_axis(scores, part, axis=1)
    order = np.argsort(-part_scores, axis=1, kind="stable")
    return np.take_along_axis(part, order, axis=1)


def serve_batch(model, params, executor, queries, top_k: int = 10,
                score_all_fn=None, sem_cache=None):
    if sem_cache is not None:
        # Serving counts as synchronous staging (no pipeline in front of it);
        # steady traffic converges to hits as the hot set fills.
        anchors = np.concatenate([q.anchors for q in queries])
        stage = sem_cache.plan(anchors)
        if stage is not None:
            params = sem_cache.apply_to(params, stage)
    states = executor.encode(params, queries)
    if score_all_fn is not None:
        scores = np.asarray(score_all_fn(params, states))
    else:
        scores = np.asarray(jax.jit(model.score_all)(params, states))
    idx = topk_desc(scores, top_k)
    return [
        {"pattern": q.pattern,
         "anchors": q.anchors.tolist(),
         "relations": q.relations.tolist(),
         "top_entities": idx[i].tolist(),
         "scores": scores[i, idx[i]].round(3).tolist()}
        for i, q in enumerate(queries)
    ], params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--model", default="betae")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--semantic-store", default=None, metavar="DIR",
                    help="serve out-of-core: H_sem stays on disk; device "
                         "holds only the hot-set cache (built by "
                         "launch/train.py --semantic-store)")
    ap.add_argument("--semantic-budget-rows", type=int, default=2048)
    args = ap.parse_args()

    kg, _, _ = load_dataset(args.dataset)
    store, cache, score_all_fn = None, None, None
    sem_dim = 0
    if args.semantic_store:
        from repro.semantic import SemanticCache, SemanticStore

        store = SemanticStore(args.semantic_store)
        assert store.n_rows == kg.n_entities, (store.n_rows, kg.n_entities)
        sem_dim = store.dim
        cache = SemanticCache(store, budget_rows=min(args.semantic_budget_rows,
                                                     kg.n_entities))
        print(f"semantic store: {store.n_rows}x{store.dim} {store.quant}, "
              f"{cache.device_resident_sem_bytes/1e6:.2f} MB device-resident")
    model = make_model(args.model, ModelConfig(dim=args.dim, semantic_dim=sem_dim))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities,
                               kg.n_relations, semantic_cache=cache)
    if args.ckpt_dir:
        restored = load_checkpoint(args.ckpt_dir,
                                   template={"params": params, "opt": None})
        if restored:
            params = restored[1]["params"]
            print(f"loaded checkpoint step={restored[0]}")
            if cache is not None:
                cache.reset()  # restored cache buffers: nothing resident yet
    if cache is not None:
        score_all_fn = lambda p, q: model.score_all_chunked(p, q, store.read_rows)  # noqa: E731

    executor = PooledExecutor(model, b_max=256)
    sampler = OnlineSampler(kg, seed=7)
    total, lat_ms = 0, []
    for b in range(args.batches):
        queries = [s.query for s in sampler.sample_batch(args.batch_size)]
        t0 = time.time()
        results, params = serve_batch(model, params, executor, queries,
                                      args.top_k, score_all_fn=score_all_fn,
                                      sem_cache=cache)
        dt = time.time() - t0
        total += len(queries)
        lat_ms.append(dt * 1e3)
        print(f"batch {b}: {len(queries)} queries in {dt*1e3:.1f} ms "
              f"(first: {json.dumps(results[0])[:120]}...)")
    qps = total / (sum(lat_ms) / 1e3)
    p50, p95 = np.percentile(lat_ms, 50), np.percentile(lat_ms, 95)
    print(f"served {total} queries at {qps:.0f} q/s "
          f"(p50 {p50:.1f} ms, p95 {p95:.1f} ms per batch, post-warmup)")
    if cache is not None:
        cs = cache.stats()
        print(f"semantic cache: hit rate {cs['hit_rate']:.2%}, "
              f"{cs['rows_staged']} rows staged from store")


if __name__ == "__main__":
    main()
