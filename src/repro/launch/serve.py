"""Batched query-answering service (Atom-style serving on the same
operator-level engine). Loads a checkpoint, accepts batches of mixed-pattern
queries and returns top-k entities per query — the NGDB retrieval path."""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import PooledExecutor
from repro.data import load_dataset
from repro.models import ModelConfig, make_model
from repro.sampling import OnlineSampler
from repro.training.checkpoint import load_checkpoint


def serve_batch(model, params, executor, queries, top_k: int = 10):
    states = executor.encode(params, queries)
    scores = np.asarray(jax.jit(model.score_all)(params, states))
    idx = np.argsort(-scores, axis=1)[:, :top_k]
    return [
        {"pattern": q.pattern,
         "anchors": q.anchors.tolist(),
         "relations": q.relations.tolist(),
         "top_entities": idx[i].tolist(),
         "scores": scores[i, idx[i]].round(3).tolist()}
        for i, q in enumerate(queries)
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--model", default="betae")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=5)
    args = ap.parse_args()

    kg, _, _ = load_dataset(args.dataset)
    model = make_model(args.model, ModelConfig(dim=args.dim))
    params = model.init_params(jax.random.PRNGKey(0), kg.n_entities, kg.n_relations)
    if args.ckpt_dir:
        restored = load_checkpoint(args.ckpt_dir,
                                   template={"params": params, "opt": None})
        if restored:
            params = restored[1]["params"]
            print(f"loaded checkpoint step={restored[0]}")

    executor = PooledExecutor(model, b_max=256)
    sampler = OnlineSampler(kg, seed=7)
    total, t_total = 0, 0.0
    for b in range(args.batches):
        queries = [s.query for s in sampler.sample_batch(args.batch_size)]
        t0 = time.time()
        results = serve_batch(model, params, executor, queries, args.top_k)
        dt = time.time() - t0
        total += len(queries)
        t_total += dt
        print(f"batch {b}: {len(queries)} queries in {dt*1e3:.1f} ms "
              f"(first: {json.dumps(results[0])[:120]}...)")
    print(f"served {total} queries at {total/t_total:.0f} q/s (post-warmup)")


if __name__ == "__main__":
    main()
