import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
# cell against the production mesh using ShapeDtypeStruct inputs — no real
# allocation anywhere. Records memory_analysis, cost_analysis and the parsed
# collective schedule for EXPERIMENTS.md §Dry-run / §Roofline.
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k [--multi-pod]
#   python -m repro.launch.dryrun --all [--multi-pod] --out results/
#   python -m repro.launch.dryrun --ngdb            # the paper's own model
#
# NOTE: the XLA_FLAGS assignment above MUST stay the first statement — jax
# locks the host device count on first init.

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    dp_axes,
    tree_param_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, parse_collectives, roofline_terms
from repro.lm.config import LMConfig
from repro.lm.model import abstract_params
from repro.lm.shapes import SHAPES, cell_supported, input_specs
from repro.lm.steps import make_decode_step, make_prefill_step, make_train_step
from repro.training.optim import adam_init


def _mem_analysis(compiled) -> Dict:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(m, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(m, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(m, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(m, "alias_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(m, "argument_size_in_bytes", 0)
                + getattr(m, "temp_size_in_bytes", 0)
                + getattr(m, "output_size_in_bytes", 0)
                - getattr(m, "alias_size_in_bytes", 0)
            ),
        }
    except Exception as e:  # some backends don't implement it
        return {"error": repr(e)}


def _cost_analysis(compiled) -> Dict:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return {k: float(v) for k, v in c.items() if np.isscalar(v)}
    except Exception as e:
        return {"error": repr(e)}


def _lower_cell(cfg: LMConfig, shape: str, mesh,
                profile: str = "2d") -> "jax.stages.Lowered":
    """Build jit + in_shardings for one cell and lower it."""
    cell = SHAPES[shape]
    dp = dp_axes(mesh, profile)
    params_abs = abstract_params(cfg)
    p_sh = tree_param_shardings(params_abs, mesh, cfg.moe_mode, profile)
    specs = input_specs(cfg, shape)
    with mesh:
        if cell.kind == "train":
            opt_abs = jax.eval_shape(adam_init, params_abs)
            o_sh = tree_param_shardings(opt_abs, mesh, cfg.moe_mode, profile)
            b_sh = batch_shardings(specs["batch"], mesh, profile)
            fn = make_train_step(cfg, mesh, dp)
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1))
            return jitted.lower(params_abs, opt_abs, specs["batch"])
        if cell.kind == "prefill":
            b_sh = batch_shardings(specs["batch"], mesh, profile)
            fn = make_prefill_step(cfg, mesh, dp)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
            return jitted.lower(params_abs, specs["batch"])
        c_sh = cache_shardings(specs["caches"], mesh)
        t_sh = batch_shardings({"tokens": specs["tokens"]}, mesh)["tokens"]
        fn = make_decode_step(cfg, mesh, dp)
        jitted = jax.jit(
            fn,
            in_shardings=(p_sh, c_sh, t_sh, NamedSharding(mesh, P())),
            donate_argnums=(1,),
        )
        return jitted.lower(params_abs, specs["caches"], specs["tokens"],
                            specs["cache_len"])


def _exact_cost(cfg: LMConfig, shape: str, mesh, n_dev: int,
                profile: str = "2d") -> Dict:
    """Exact per-device cost via k=2/k=3-block fully-unrolled compiles +
    linear extrapolation over the n_rep identical blocks. lax.scan bodies are
    counted once by XLA cost analysis, so the deployable (scanned) program
    cannot be costed directly; unrolled small models + extrapolation is exact
    because blocks are identical (validated: k=3 sits on the k=2/k=4 line to
    0.03%; k=1 is excluded — the partitioner makes different layout choices
    for single-layer models)."""
    from repro.lm.model import block_pattern

    pat = len(block_pattern(cfg))
    n_rep = cfg.n_layers // pat
    # SSM/hybrid blocks unroll every SSD chunk too; k=(1,2) keeps those
    # compiles bounded (multi-layer blocks are already past the k=1 anomaly).
    ks = (1, 2) if (cfg.ssm_state > 0 and pat >= 8) else (2, 3)
    samples = []
    for k in ks:
        over = {"n_layers": pat * k, "exact_cost_mode": True}
        if cfg.encoder_layers:
            over["encoder_layers"] = k
        cfg_k = dataclasses.replace(cfg, **over)
        compiled = _lower_cell(cfg_k, shape, mesh, profile).compile()
        cost = _cost_analysis(compiled)
        coll = parse_collectives(compiled.as_text(), n_dev)
        samples.append(
            (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
             coll.wire_bytes, coll.by_type, coll.counts)
        )
    (f1, b1, w1, t1, c1), (f2, b2, w2, t2, c2) = samples

    def ext(a, b):
        return a + (n_rep - ks[0]) * max(b - a, 0.0)

    by_type = {k: ext(t1.get(k, 0.0), t2.get(k, 0.0))
               for k in set(t1) | set(t2)}
    counts = {k: int(ext(c1.get(k, 0), c2.get(k, 0)))
              for k in set(c1) | set(c2)}
    return {
        "flops": ext(f1, f2),
        "bytes_accessed": ext(b1, b2),
        "wire_bytes": ext(w1, w2),
        "collective_by_type": by_type,
        "collective_counts": counts,
        "blocks_extrapolated": n_rep,
    }


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             cfg: Optional[LMConfig] = None, override=None,
             analyze: bool = True, profile: str = "2d") -> Dict:
    """Lower + compile one cell; returns the full record for EXPERIMENTS.md."""
    cfg = cfg or get_arch(arch)
    if override:
        cfg = dataclasses.replace(cfg, **override)
    cell = SHAPES[shape]
    rec: Dict = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16(pod,data,model)" if multi_pod else "16x16(data,model)",
        "kind": cell.kind,
    }
    skip = cell_supported(cfg, shape)
    if skip:
        rec["skipped"] = skip
        return rec

    rec["profile"] = profile
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered = _lower_cell(cfg, shape, mesh, profile)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()  # REQUIRED: proves the cell compiles
    rec["compile_s"] = round(time.time() - t1, 1)

    rec["memory"] = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    rec["cost_raw"] = {k: cost[k] for k in ("flops", "bytes accessed")
                       if k in cost} or cost
    coll = parse_collectives(compiled.as_text(), n_dev)
    rec["collectives_raw"] = coll.as_dict()

    if analyze:
        try:
            exact = _exact_cost(cfg, shape, mesh, n_dev, profile)
            rec["cost_exact"] = exact
            rec["roofline"] = roofline_terms(
                exact["flops"], exact["bytes_accessed"], exact["wire_bytes"])
        except Exception:
            rec["cost_exact"] = {"error": traceback.format_exc(limit=10)}
            rec["roofline"] = roofline_terms(
                cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
                coll.wire_bytes)
    else:
        rec["roofline"] = roofline_terms(
            cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
            coll.wire_bytes)

    mf = model_flops(cfg, cell, cell.kind)
    rec["model_flops_global"] = mf
    rec["model_flops_per_device"] = mf / n_dev
    got = rec.get("cost_exact", {}).get("flops") or cost.get("flops", 0.0)
    if got:
        rec["useful_flops_ratio"] = (mf / n_dev) / got
    return rec


# ---------------------------------------------------------------- NGDB cell
def run_ngdb_cell(multi_pod: bool = False, dataset: str = "ogbl-wikikg2",
                  model_name: str = "betae", batch: int = 512,
                  n_neg: int = 64, dim: int = 400,
                  entity_pad: int = 4096, sparse_updates: bool = False) -> Dict:
    """Dry-run the paper's own training step at production scale: entity +
    semantic tables sharded over the mesh, one operator-level batch of mixed
    patterns, vectorized loss, Adam."""
    from repro.core.executor import PooledExecutor
    from repro.core.patterns import TEMPLATES, QueryInstance
    from repro.data.kg import TABLE4
    from repro.models.base import ModelConfig, make_model
    from repro.training.loss import negative_sampling_loss
    from repro.training.optim import AdamConfig, adam_update

    stats = TABLE4[dataset]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": f"ngdb-{model_name}-{dataset}", "shape": f"train_b{batch}",
           "mesh": "2x16x16" if multi_pod else "16x16", "kind": "train",
           "entity_pad": entity_pad, "sparse_updates": sparse_updates}
    t0 = time.time()

    model = make_model(model_name, ModelConfig(dim=dim, semantic_dim=1024,
                                               entity_pad=entity_pad))
    # One representative mixed batch (uniform over the 14 patterns).
    rng = np.random.default_rng(0)
    pats = list(TEMPLATES)
    queries = []
    for i in range(batch):
        t = TEMPLATES[pats[i % len(pats)]]
        queries.append(QueryInstance(
            pats[i % len(pats)],
            rng.integers(0, stats.n_entities, t.n_anchors),
            rng.integers(0, stats.n_relations, t.n_relations),
        ))
    ex = PooledExecutor(model, b_max=512)
    prepared = ex.prepare(queries)
    encode = ex.encode_fn(prepared)
    steps_np, ans = prepared.device_args()

    rows = model.padded_entities(stats.n_entities)
    sem_table = jax.ShapeDtypeStruct((rows, 1024), jnp.float32)
    params_abs = jax.eval_shape(
        lambda k, st: model.init_params(k, stats.n_entities, stats.n_relations,
                                        semantic_table=st),
        jax.random.PRNGKey(0), sem_table)
    opt_abs = jax.eval_shape(adam_init, params_abs)
    p_sh = tree_param_shardings(params_abs, mesh)
    o_sh = tree_param_shardings(opt_abs, mesh)
    adam = AdamConfig(lr=1e-4)

    steps_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), (steps_np, ans))
    pos_abs = jax.ShapeDtypeStruct((batch,), jnp.int64)
    neg_abs = jax.ShapeDtypeStruct((batch, n_neg), jnp.int64)

    def train_step(params, opt_state, step_arrays, pos, neg):
        def loss_fn(p):
            q = encode(p, step_arrays[0], step_arrays[1])
            loss, _ = negative_sampling_loss(model, p, q, pos, neg)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adam_update(grads, opt_state, params, adam)
        return params, opt_state, loss

    # §Perf iteration N3: row-sparse embedding updates. One batch touches
    # ~35k unique entity rows; the dense step streams the full 2.5M-row table
    # + both Adam moments every step (~70x waste). The sparse step gathers the
    # touched rows into a minibatch-local table (host dedups + remaps indices
    # — the same Precomputed Indexing machinery), differentiates w.r.t. the
    # LOCAL table only, and scatter-writes rows + moments back.
    u_rows = batch * 3 + batch * (1 + n_neg)  # anchors + pos + negs (padded)
    ids_abs = jax.ShapeDtypeStruct((u_rows,), jnp.int32)

    def train_step_sparse(params, opt_state, step_arrays, ids, pos_l, neg_l):
        ent_rows = params["entity"][ids]
        sem_rows = params["sem_table"][ids]
        m_rows = opt_state["m"]["entity"][ids]
        v_rows = opt_state["v"]["entity"][ids]

        def loss_fn(rows):
            p_local = dict(params, entity=rows, sem_table=sem_rows)
            q = encode(p_local, step_arrays[0], step_arrays[1])
            loss, _ = negative_sampling_loss(model, p_local, q, pos_l, neg_l)
            return loss

        loss, g_rows = jax.value_and_grad(loss_fn)(ent_rows)
        # row-local Adam (global bias correction; standard for sparse KGE)
        step = opt_state["step"] + 1
        b1t = 1.0 - adam.b1 ** step.astype(jnp.float32)
        b2t = 1.0 - adam.b2 ** step.astype(jnp.float32)
        m_rows = adam.b1 * m_rows + (1 - adam.b1) * g_rows
        v_rows = adam.b2 * v_rows + (1 - adam.b2) * jnp.square(g_rows)
        new_rows = ent_rows - adam.lr * (m_rows / b1t) / (
            jnp.sqrt(v_rows / b2t) + adam.eps)
        params = dict(params, entity=params["entity"].at[ids].set(new_rows))
        opt_state = dict(
            opt_state,
            m=dict(opt_state["m"], entity=opt_state["m"]["entity"].at[ids].set(m_rows)),
            v=dict(opt_state["v"], entity=opt_state["v"]["entity"].at[ids].set(v_rows)),
            step=step,
        )
        return params, opt_state, loss

    with mesh:
        repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), steps_abs)
        if sparse_updates:
            jitted = jax.jit(
                train_step_sparse,
                in_shardings=(p_sh, o_sh, repl, NamedSharding(mesh, P()),
                              NamedSharding(mesh, P()), NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, steps_abs, ids_abs,
                                   pos_abs, neg_abs)
        else:
            jitted = jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, repl,
                              NamedSharding(mesh, P()), NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, steps_abs, pos_abs, neg_abs)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    rec["memory"] = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    rec["cost"] = {k: cost[k] for k in ("flops", "bytes accessed") if k in cost} or cost
    coll = parse_collectives(compiled.as_text(), n_dev)
    rec["collectives"] = coll.as_dict()
    rec["roofline"] = roofline_terms(cost.get("flops", 0.0),
                                     cost.get("bytes accessed", 0.0),
                                     coll.wire_bytes)
    rec["schedule_stats"] = prepared.sched.stats
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ngdb", action="store_true")
    ap.add_argument("--no-analyze", action="store_true",
                    help="skip the k=2/k=3 exact-cost compiles (full compile only)")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    args = ap.parse_args()

    cells = []
    if args.ngdb:
        cells = [("ngdb", None)]
    elif args.all:
        cells = [(a, s) for a in sorted(ARCHS) for s in SHAPES]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        try:
            if arch == "ngdb":
                rec = run_ngdb_cell(multi_pod=args.multi_pod)
            else:
                rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                               analyze=not args.no_analyze)
        except Exception:
            rec = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                   "error": traceback.format_exc(limit=20)}
        line = json.dumps(rec)
        print(line, flush=True)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"{rec['arch']}_{rec.get('shape')}_{'mp' if args.multi_pod else 'sp'}"
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                f.write(line)


if __name__ == "__main__":
    main()
