"""NGDB-Zoo training driver (the paper's kind: training).

Runs the full loop — online sampling, operator-level scheduling, fused
execution, vectorized loss, Adam — with checkpoint/auto-resume and optional
decoupled semantic augmentation and adaptive sampling.

  PYTHONPATH=src python -m repro.launch.train --dataset FB15k --model betae \
      --steps 200 --batch-size 128 --dim 64 --semantic --ckpt-dir /tmp/ckpt

Semantic at scale (DESIGN.md §SemanticStore): pass ``--semantic-store DIR``
to keep H_sem on disk (sharded mmap, built once, reused across runs) with
only a bounded device-resident hot set:

  PYTHONPATH=src python -m repro.launch.train --dataset FB15k --model gqe \
      --semantic --semantic-store /tmp/sem --semantic-budget-rows 2048 \
      --semantic-quant fp32 --pipeline --steps 200
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.data import load_dataset
from repro.distributed.context import make_execution_context
from repro.models import ModelConfig, make_model, model_names
from repro.obs import TRACER, get_registry
from repro.sampling import OnlineSampler
from repro.semantic import (PTEConfig, SemanticCache, SemanticStore,
                            SemanticStoreError, StubPTE,
                            precompute_semantic_table,
                            precompute_semantic_table_to_store)
from repro.training import AdamConfig, NGDBTrainer, TrainConfig, evaluate


def open_or_build_store(directory: str, kg, d_l: int, quant: str,
                        shard_rows: int = 65536) -> SemanticStore:
    """Reuse a complete store if one is already on disk (matching shape and
    quant layout); otherwise stream the offline precompute into it."""
    try:
        store = SemanticStore(directory)
        if (store.n_rows, store.dim, store.quant) == (kg.n_entities, d_l, quant):
            print(f"semantic store: reusing {directory} "
                  f"({store.n_rows}x{store.dim} {store.quant}, "
                  f"{store.disk_nbytes/1e6:.1f} MB on disk)")
            return store
        print("semantic store: shape/quant mismatch — rebuilding")
    except SemanticStoreError as e:
        print(f"semantic store: {e}")
    t0 = time.time()
    pte = StubPTE(PTEConfig(d_l=d_l, n_layers=2, d_model=128))
    store = precompute_semantic_table_to_store(
        kg, directory, pte, quant=quant, shard_rows=shard_rows)
    print(f"semantic store: built {store.n_rows}x{store.dim} {quant} at "
          f"{directory} in {time.time()-t0:.1f}s "
          f"({store.disk_nbytes/1e6:.1f} MB, PTE unloaded)")
    return store


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--model", default="betae", choices=model_names())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--negatives", type=int, default=32)
    ap.add_argument("--semantic", action="store_true")
    ap.add_argument("--semantic-dim", type=int, default=256)
    ap.add_argument("--semantic-store", default=None, metavar="DIR",
                    help="out-of-core H_sem: sharded mmap store on disk + a "
                         "bounded device-resident hot-set cache (implies "
                         "--semantic); built at DIR on first use")
    ap.add_argument("--semantic-budget-rows", type=int, default=0,
                    help="device hot-set row budget for --semantic-store "
                         "(0 = auto: 4x the per-batch working set)")
    ap.add_argument("--semantic-quant", default="fp32",
                    choices=["fp32", "int8"],
                    help="on-disk layout: fp32 is bit-identical to "
                         "full-resident training; int8 is 4x smaller with "
                         "per-row scales")
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--executor", default="pooled", choices=["pooled", "query_level"])
    ap.add_argument("--no-cse", action="store_true",
                    help="ablation: disable the plan compiler's cross-query "
                         "subexpression sharing (DESIGN.md §Compiler) — "
                         "every query node becomes its own pooled row, the "
                         "pre-compiler behavior")
    ap.add_argument("--materialized-rows", type=int, default=0,
                    help="attach a MaterializedSubqueryCache of N encoded "
                         "rows to the pooled executor's eval/encode path "
                         "(version-stamped: invalidated on every param "
                         "update and KG write; 0 = off)")
    ap.add_argument("--pipeline", action="store_true",
                    help="pipelined dataflow mode: overlap Algorithm-1 "
                         "scheduling for batch k+1 with device execution of "
                         "batch k (sync mode is the ablation baseline); with "
                         "--semantic-store this also prefetches semantic rows "
                         "on the scheduler thread (zero mid-step store reads)")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="pipelined dispatch window (2 = double-buffered)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="mesh-shard the run: data=N[,model=M] (DESIGN.md "
                         "§Sharding). Tables/Adam state materialize into "
                         "their NamedShardings and the fused step compiles "
                         "with explicit in/out shardings; omit for the "
                         "single-device default. On a CPU host emulate "
                         "devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--profile", default="2d", choices=["2d", "fsdp"],
                    help="sharding profile for --mesh: 2d = TP x FSDP rule "
                         "table; fsdp = ZeRO-3 (every large table/param "
                         "shards its largest divisible dim over all devices "
                         "— the profile that splits the entity table 1/N "
                         "on a pure data mesh)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--live-writes", type=int, default=0, metavar="N",
                    help="post-training live-write smoke (DESIGN.md "
                         "§LiveStore): commit N fresh triple bursts into the "
                         "trained KG and incrementally fine-tune the written "
                         "neighborhoods from the trained params")
    ap.add_argument("--eval-queries", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace-event/Perfetto JSON timeline "
                         "of the run (thread lanes: main dispatch, pipeline "
                         "scheduler, sampling workers; spans: sample/schedule"
                         "/compile/transfer/sem_prefetch/store_io/dispatch/"
                         "retire). Load at ui.perfetto.dev")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write per-step phase durations + bubble fraction "
                         "as JSONL, with a final registry snapshot record; "
                         "summarize with python -m repro.obs.report")
    ap.add_argument("--autotune-cache", default=None, metavar="PATH",
                    help="persisted kernel-tile autotune cache (DESIGN.md "
                         "§Autotuner): tuned tile configs load from PATH and "
                         "make pool padding kernel-aware; also the default "
                         "via REPRO_AUTOTUNE_CACHE (run.sh sets it)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the bounded tile sweep for this model/shape "
                         "regime before training (results persist to "
                         "--autotune-cache when given); without this flag "
                         "only already-tuned configs are used")
    args = ap.parse_args()
    if args.semantic_store:
        args.semantic = True
    if args.trace:
        TRACER.enable()
        TRACER.set_lane("main dispatch")

    ctx = make_execution_context(args.mesh, profile=args.profile)
    if ctx.is_sharded:
        print(f"execution context: {ctx.describe()} "
              f"({ctx.n_devices} devices, dp={ctx.dp_size})")

    kg, full_kg, stats = load_dataset(args.dataset)
    print(f"dataset={args.dataset} (reduced stand-in): "
          f"{kg.n_entities} entities, {kg.n_relations} relations, {len(kg)} train triples")

    table, store, cache = None, None, None
    sem_dim = 0
    if args.semantic_store:
        sem_dim = args.semantic_dim
        store = open_or_build_store(args.semantic_store, kg, sem_dim,
                                    args.semantic_quant)
        # Working set of one step: anchors (<=3/query) + positive + negatives.
        per_batch = args.batch_size * (4 + args.negatives)
        budget = args.semantic_budget_rows or min(kg.n_entities, 4 * per_batch)
        budget = max(budget, min(kg.n_entities, per_batch))
        cache = SemanticCache(store, budget_rows=budget, ctx=ctx)
        print(f"semantic cache: {budget} device rows "
              f"({cache.device_resident_sem_bytes/1e6:.2f} MB device-resident "
              f"vs {kg.n_entities * sem_dim * 4/1e6:.2f} MB full-resident)")
    elif args.semantic:
        t0 = time.time()
        pte = StubPTE(PTEConfig(d_l=args.semantic_dim, n_layers=2, d_model=128))
        table = precompute_semantic_table(kg, pte)
        sem_dim = args.semantic_dim
        print(f"semantic precompute: {table.shape} in {time.time()-t0:.1f}s; PTE unloaded")

    # Pad entity rows to a multiple of the mesh size so the tables divide
    # whichever axis the profile assigns them (§Perf: indivisible rows make
    # the rule table silently replicate the biggest buffer in the run).
    model = make_model(args.model, ModelConfig(dim=args.dim, gamma=12.0,
                                               semantic_dim=sem_dim,
                                               entity_pad=max(1, ctx.n_devices)))
    # Kernel autotuning must be settled BEFORE the trainer exists: the
    # executor snapshots its kernel-aware tile policy at construction.
    if args.autotune_cache or args.autotune:
        from repro.kernels import autotune as kat

        tuner = kat.KernelTuner(path=args.autotune_cache) \
            if args.autotune_cache else kat.get_tuner()
        if args.autotune_cache:
            kat.set_tuner(tuner)
        if args.autotune:
            t0 = time.time()
            n_sw = kat.tune_for_model(model, tuner, batch=args.batch_size)
            print(f"autotune: {n_sw} sweeps in {time.time()-t0:.1f}s, "
                  f"{len(tuner)} cached configs"
                  + (f" @ {tuner.path}" if tuner.path else ""))
        elif len(tuner):
            print(f"autotune: {len(tuner)} tuned configs loaded"
                  + (f" from {tuner.path}" if tuner.path else ""))
    cfg = TrainConfig(
        batch_size=args.batch_size, n_negatives=args.negatives,
        adam=AdamConfig(lr=args.lr), adaptive=args.adaptive,
        executor=args.executor, checkpoint_dir=args.ckpt_dir,
        pipeline=args.pipeline, max_inflight=args.max_inflight,
        cse=not args.no_cse, materialized_rows=args.materialized_rows,
        metrics_path=args.metrics,
    )
    trainer = NGDBTrainer(model, kg, cfg, semantic_table=table,
                          semantic_cache=cache, ctx=ctx)
    if trainer.resume():
        print(f"resumed from checkpoint at step {trainer.step}")

    t0 = time.time()
    trainer.train(args.steps, log_every=args.log_every)
    dt = time.time() - t0
    if args.metrics and trainer.metrics_sink.enabled:
        trainer.metrics_sink.write({"kind": "snapshot",
                                    "metrics": get_registry().snapshot()})
        trainer.metrics_sink.close()
    if args.trace:
        TRACER.write(args.trace)
        TRACER.disable()
        print(f"trace: wrote {args.trace} (load at ui.perfetto.dev)")
    qps = args.steps * args.batch_size / dt
    # pipeline mode requires the pooled executor; train() falls back to the
    # sync loop otherwise — report what actually ran.
    mode = "pipelined" if (args.pipeline and args.executor == "pooled") else "sync"
    if args.pipeline and mode == "sync":
        print("note: --pipeline requires --executor pooled; ran the sync path")
    cc = trainer.compile_cache_stats()["train_step"]
    print(f"trained {args.steps} steps [{mode}] in {dt:.1f}s ({qps:.0f} queries/sec)")
    print(f"compile cache: {cc['size']} programs, "
          f"hit rate {cc['hit_rate']:.2%} ({cc['misses']} traces)")
    sh = trainer.executor.sharing_stats()
    # Report the executor's ACTUAL mode: the query-level baseline pins CSE
    # off regardless of the flag (sharing would hand it the pooled win).
    cse_on = getattr(trainer.executor, "cse", False)
    print(f"plan compiler: CSE {'on' if cse_on else 'off'}"
          f"{' (query-level baseline)' if args.executor != 'pooled' else ''}"
          f" — {sh['pooled_rows_saved']} pooled rows saved "
          f"({sh['saved_frac']:.1%} of {sh['nodes_before']})")
    pc = sh.get("plan_cache")
    if pc is not None:
        print(f"plan cache: {pc['size']} canonical plans, "
              f"hit rate {pc['hit_rate']:.2%} "
              f"({pc['canonicalize_calls']} canonicalizations, "
              f"{pc['misses']} rebuilds)")
    mc = sh.get("materialized")
    if mc is not None:
        print(f"materialized rows: hit rate {mc['hit_rate']:.2%}, "
              f"{mc['live']} live rows, {mc['invalidations']} invalidations "
              f"({mc['stale_drops']} stale inserts dropped)")
    if ctx.is_sharded:
        ent = trainer.params["entity"]
        per_dev = ent.addressable_shards[0].data.nbytes
        print(f"entity table: {ent.nbytes/1e6:.2f} MB logical, "
              f"{per_dev/1e6:.2f} MB/device "
              f"({ent.sharding.spec} over {ctx.describe()})")
    if cache is not None:
        cs = cache.stats()
        print(f"semantic cache: hit rate {cs['hit_rate']:.2%}, "
              f"{cs['evictions']} evictions, "
              f"{cs['device_resident_sem_bytes']/1e6:.2f} MB device-resident, "
              f"prefetch overlap {cs['prefetch_overlap_frac']:.2%} "
              f"({cs['sync_stages']} synchronous mid-step reads)")

    if args.live_writes > 0:
        if cache is not None:
            print("live-write smoke skipped: hot-set (sem_cache) params do "
                  "not support live maintenance")
        else:
            from repro.training.loop import incremental_finetune

            wrng = np.random.default_rng(29)
            v0 = kg.graph_version
            for i in range(args.live_writes):
                cand = np.stack([wrng.integers(0, kg.n_entities, 16),
                                 wrng.integers(0, kg.n_relations, 16),
                                 wrng.integers(0, kg.n_entities, 16)], axis=1)
                fresh = kg.insert_triples(cand[~kg.contains(cand)][:4])
                if not len(fresh):
                    continue
                trainer.params, losses = incremental_finetune(
                    model, trainer.params, fresh, lr=args.lr,
                    seed=kg.graph_version, executor=trainer.executor)
                print(f"live write {i}: v{kg.graph_version} "
                      f"{len(fresh)} fresh triples, fine-tune loss "
                      f"{losses[0]:.4f} -> {losses[-1]:.4f}")
            print(f"live-write smoke: graph version {v0} -> "
                  f"{kg.graph_version}, {len(kg)} triples")

    eval_qs = [b.query for b in OnlineSampler(kg, seed=123).sample_batch(args.eval_queries)]
    score_all_fn = None
    if cache is not None:
        # Encoding eval queries gathers their anchors through the cache;
        # stage them once up front. Scoring streams H_sem from the store.
        anchors = np.unique(np.concatenate([q.anchors for q in eval_qs]))
        try:
            stage = cache.plan(anchors)
        except RuntimeError as e:
            print(f"eval skipped: {e}")
            return
        if stage is not None:
            trainer.params = cache.apply_to(trainer.params, stage)
        score_all_fn = lambda p, q: model.score_all_chunked(p, q, store.read_rows)  # noqa: E731
    metrics = evaluate(model, trainer.params, trainer.executor, full_kg,
                       eval_qs, train_kg=kg, score_all_fn=score_all_fn)
    print("eval:", json.dumps({k: round(float(v), 4) for k, v in metrics.items()}))


if __name__ == "__main__":
    main()
