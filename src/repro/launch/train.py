"""NGDB-Zoo training driver (the paper's kind: training).

Runs the full loop — online sampling, operator-level scheduling, fused
execution, vectorized loss, Adam — with checkpoint/auto-resume and optional
decoupled semantic augmentation and adaptive sampling.

  PYTHONPATH=src python -m repro.launch.train --dataset FB15k --model betae \
      --steps 200 --batch-size 128 --dim 64 --semantic --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.data import load_dataset
from repro.models import ModelConfig, make_model, model_names
from repro.sampling import OnlineSampler
from repro.semantic import PTEConfig, StubPTE, precompute_semantic_table
from repro.training import AdamConfig, NGDBTrainer, TrainConfig, evaluate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="FB15k")
    ap.add_argument("--model", default="betae", choices=model_names())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--negatives", type=int, default=32)
    ap.add_argument("--semantic", action="store_true")
    ap.add_argument("--semantic-dim", type=int, default=256)
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--executor", default="pooled", choices=["pooled", "query_level"])
    ap.add_argument("--pipeline", action="store_true",
                    help="pipelined dataflow mode: overlap Algorithm-1 "
                         "scheduling for batch k+1 with device execution of "
                         "batch k (sync mode is the ablation baseline)")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="pipelined dispatch window (2 = double-buffered)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--eval-queries", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    kg, full_kg, stats = load_dataset(args.dataset)
    print(f"dataset={args.dataset} (reduced stand-in): "
          f"{kg.n_entities} entities, {kg.n_relations} relations, {len(kg)} train triples")

    table = None
    sem_dim = 0
    if args.semantic:
        t0 = time.time()
        pte = StubPTE(PTEConfig(d_l=args.semantic_dim, n_layers=2, d_model=128))
        table = precompute_semantic_table(kg, pte)
        sem_dim = args.semantic_dim
        print(f"semantic precompute: {table.shape} in {time.time()-t0:.1f}s; PTE unloaded")

    model = make_model(args.model, ModelConfig(dim=args.dim, gamma=12.0,
                                               semantic_dim=sem_dim))
    cfg = TrainConfig(
        batch_size=args.batch_size, n_negatives=args.negatives,
        adam=AdamConfig(lr=args.lr), adaptive=args.adaptive,
        executor=args.executor, checkpoint_dir=args.ckpt_dir,
        pipeline=args.pipeline, max_inflight=args.max_inflight,
    )
    trainer = NGDBTrainer(model, kg, cfg, semantic_table=table)
    if trainer.resume():
        print(f"resumed from checkpoint at step {trainer.step}")

    t0 = time.time()
    trainer.train(args.steps, log_every=args.log_every)
    dt = time.time() - t0
    qps = args.steps * args.batch_size / dt
    # pipeline mode requires the pooled executor; train() falls back to the
    # sync loop otherwise — report what actually ran.
    mode = "pipelined" if (args.pipeline and args.executor == "pooled") else "sync"
    if args.pipeline and mode == "sync":
        print("note: --pipeline requires --executor pooled; ran the sync path")
    cc = trainer.compile_cache_stats()["train_step"]
    print(f"trained {args.steps} steps [{mode}] in {dt:.1f}s ({qps:.0f} queries/sec)")
    print(f"compile cache: {cc['size']} programs, "
          f"hit rate {cc['hit_rate']:.2%} ({cc['misses']} traces)")

    eval_qs = [b.query for b in OnlineSampler(kg, seed=123).sample_batch(args.eval_queries)]
    metrics = evaluate(model, trainer.params, trainer.executor, full_kg,
                       eval_qs, train_kg=kg)
    print("eval:", json.dumps({k: round(float(v), 4) for k, v in metrics.items()}))


if __name__ == "__main__":
    main()
