"""Launch-environment tuning: the process-level knobs that must be set
BEFORE the interpreter (or at least before JAX initializes) to take effect.

The PR-1 ``host_parallel_efficiency`` probe showed host threads bottleneck
the pipelined sampler, and the usual large-model launch hygiene (tcmalloc
preloaded, XLA step markers, pinned math dtypes) is all pre-main
environment state — so it lives here as a *launcher*, not a library call:

    python -m repro.launch.env [--host-devices 8] -- python -m repro.launch.train ...

builds the tuned environment and ``exec``s the command under it. ``run.sh``
at the repo root is the shell-native equivalent for the common case.

Knobs (each reported by ``--report`` / skipped gracefully when unavailable):

* **tcmalloc** — ``LD_PRELOAD`` of libtcmalloc: the glibc allocator's arena
  contention is measurable with the pipeline's sampler/scheduler/dispatch
  threads all allocating; also raises the large-alloc report threshold so
  multi-GB table mmaps don't spam stderr. LD_PRELOAD only applies at
  process start — hence the exec-style launcher.
* **XLA_FLAGS** — ``--xla_step_marker_location=1`` (step markers at the
  fused train-step boundary, where the profiler and the §Observability
  span bridge expect them) and optionally
  ``--xla_force_host_platform_device_count=N`` for emulated-mesh runs
  (DESIGN.md §Sharding). Merged into any caller-set XLA_FLAGS without
  duplicating flags the caller already pinned.
* **thread pins** — OMP/MKL/OPENBLAS thread caps so host BLAS doesn't
  oversubscribe the cores the pipeline's own thread lanes need.
* **dtype pins** — ``JAX_ENABLE_X64=0`` + 32-bit default dtype bits: the
  engine's bit-identity contracts are all stated in fp32; a stray x64
  default would silently double every buffer.

Everything is additive to the caller's environment: a variable the caller
already set is NEVER overwritten (report says "kept").
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import shlex
import sys
from typing import Dict, List, Optional, Tuple

#: Common install locations for tcmalloc (gperftools / libtcmalloc-minimal).
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
    "/usr/lib64/libtcmalloc.so.4",
)

#: Sentinel guarding against the launcher re-exec'ing under itself.
_SENTINEL = "REPRO_ENV_LAUNCHED"


def find_tcmalloc() -> Optional[str]:
    for p in TCMALLOC_CANDIDATES:
        if os.path.exists(p):
            return p
    return None


def tcmalloc_active() -> bool:
    """Whether tcmalloc is actually mapped into THIS process (LD_PRELOAD
    must have been set before exec — setting it now does nothing)."""
    try:
        with open("/proc/self/maps") as f:
            return "tcmalloc" in f.read()
    except OSError:
        return False


def _merge_xla_flags(existing: str, wanted: List[str]) -> str:
    """Append wanted flags to an XLA_FLAGS string, skipping any flag (by
    ``--name=`` prefix) the existing string already pins."""
    have = {tok.split("=", 1)[0] for tok in existing.split() if tok}
    out = existing.split()
    for flag in wanted:
        if flag.split("=", 1)[0] not in have:
            out.append(flag)
    return " ".join(out)


@dataclasses.dataclass
class EnvPlan:
    """The computed environment delta + human-readable notes per knob."""

    env: Dict[str, str]
    notes: List[Tuple[str, str]]  # (knob, what happened)

    def apply(self, base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
        merged = dict(os.environ if base is None else base)
        merged.update(self.env)
        return merged

    def report(self) -> str:
        lines = ["launch-env plan:"]
        for knob, what in self.notes:
            lines.append(f"  {knob:<18} {what}")
        return "\n".join(lines)


def build_plan(host_devices: int = 0, threads: Optional[int] = None,
               tcmalloc: bool = True, step_marker: bool = True,
               pin_dtypes: bool = True,
               base: Optional[Dict[str, str]] = None) -> EnvPlan:
    """Compute the environment delta for a tuned launch. Never overwrites a
    variable the caller already set (the note records it as kept)."""
    cur = dict(os.environ if base is None else base)
    env: Dict[str, str] = {}
    notes: List[Tuple[str, str]] = []

    def want(key: str, val: str, why: str) -> None:
        if key in cur:
            notes.append((key, f"kept caller value {cur[key]!r}"))
        else:
            env[key] = val
            notes.append((key, f"{val!r}  ({why})"))

    if tcmalloc:
        lib = find_tcmalloc()
        if lib:
            want("LD_PRELOAD", lib, "arena-contention-free allocator for "
                 "the pipeline's host threads")
            want("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000",
                 "silence large-mmap reports for multi-GB tables")
        else:
            notes.append(("LD_PRELOAD", "skipped — no libtcmalloc found"))
    want("TF_CPP_MIN_LOG_LEVEL", "4", "quiet TF/XLA C++ banner noise")

    xla_wanted: List[str] = []
    if step_marker:
        xla_wanted.append("--xla_step_marker_location=1")
    if host_devices > 0:
        xla_wanted.append(
            f"--xla_force_host_platform_device_count={host_devices}")
    if xla_wanted:
        merged = _merge_xla_flags(cur.get("XLA_FLAGS", ""), xla_wanted)
        if merged != cur.get("XLA_FLAGS", ""):
            env["XLA_FLAGS"] = merged
            notes.append(("XLA_FLAGS", repr(merged)))
        else:
            notes.append(("XLA_FLAGS", "kept — caller already pins these"))

    if threads is not None and threads > 0:
        for var in ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS",
                    "MKL_NUM_THREADS"):
            want(var, str(threads),
                 "cap host BLAS so pipeline lanes keep their cores")

    if pin_dtypes:
        want("JAX_ENABLE_X64", "0", "fp32 bit-identity contracts")
        want("JAX_DEFAULT_DTYPE_BITS", "32", "no silent x64 buffers")

    return EnvPlan(env=env, notes=notes)


def current_report() -> Dict[str, object]:
    """What the CURRENT process actually launched with — recorded by the
    autotune bench so a BENCH json says which knobs were live."""
    return {
        "tcmalloc_active": tcmalloc_active(),
        "tcmalloc_found": find_tcmalloc(),
        "ld_preload": os.environ.get("LD_PRELOAD", ""),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "jax_enable_x64": os.environ.get("JAX_ENABLE_X64", ""),
        "omp_num_threads": os.environ.get("OMP_NUM_THREADS", ""),
        "autotune_cache": os.environ.get("REPRO_AUTOTUNE_CACHE", ""),
        "launched_via_env": _SENTINEL in os.environ,
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.env",
        description="Build a tuned launch environment and exec a command "
                    "under it: python -m repro.launch.env [flags] -- cmd ...")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="emulate N host devices (XLA_FLAGS; 0 = off)")
    ap.add_argument("--threads", type=int, default=None,
                    help="cap OMP/MKL/OpenBLAS threads")
    ap.add_argument("--no-tcmalloc", action="store_true")
    ap.add_argument("--no-step-marker", action="store_true")
    ap.add_argument("--no-dtype-pins", action="store_true")
    ap.add_argument("--autotune-cache", default=None,
                    help=f"set {os.environ.get('REPRO_AUTOTUNE_CACHE', 'REPRO_AUTOTUNE_CACHE')!s} "
                         "for the child (persisted kernel-tile cache)")
    ap.add_argument("--report", action="store_true",
                    help="print the plan (and current-process state) and exit")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the plan + command without exec'ing")
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="command to exec (prefix with --)")
    args = ap.parse_args(argv)

    plan = build_plan(host_devices=args.host_devices, threads=args.threads,
                      tcmalloc=not args.no_tcmalloc,
                      step_marker=not args.no_step_marker,
                      pin_dtypes=not args.no_dtype_pins)
    if args.autotune_cache:
        plan.env["REPRO_AUTOTUNE_CACHE"] = args.autotune_cache
        plan.notes.append(("REPRO_AUTOTUNE_CACHE", repr(args.autotune_cache)))

    if args.report:
        print(plan.report())
        for k, v in sorted(current_report().items()):
            print(f"  current: {k} = {v!r}")
        return 0

    cmd = list(args.cmd)
    if cmd and cmd[0] == "--":
        cmd = cmd[1:]
    if not cmd:
        print(plan.report())
        print("no command given — pass one after `--` (or use --report)",
              file=sys.stderr)
        return 2

    print(plan.report(), file=sys.stderr)
    if args.dry_run:
        print(f"would exec: {shlex.join(cmd)}", file=sys.stderr)
        return 0
    child_env = plan.apply()
    child_env[_SENTINEL] = "1"
    os.execvpe(cmd[0], cmd, child_env)
    return 0  # unreachable


if __name__ == "__main__":
    raise SystemExit(main())
