"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = wire_bytes_per_device / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()`` (the executable is the
SPMD-partitioned per-device module). Collective bytes are NOT in
cost_analysis: we parse the partitioned HLO and sum per-op wire-byte
estimates using ring-algorithm factors and the parsed replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware constants (per chip), per the assignment.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


# Ring-algorithm wire-byte factors per chip, as multiples of the RESULT size.
def _wire_bytes(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":          # receive everyone else's shard
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":      # result is the local shard
        return result_bytes * (g - 1)
    if op == "all-reduce":          # RS + AG
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float
    payload_bytes: float
    by_type: Dict[str, float]
    counts: Dict[str, int]

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    by_type: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    wire = 0.0
    payload = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        size = _type_bytes(type_str)
        g = _group_size(line, total_devices)
        w = _wire_bytes(op, size, g)
        wire += w
        payload += size
        by_type[op] = by_type.get(op, 0.0) + w
        counts[op] = counts.get(op, 0) + 1
    return CollectiveStats(wire, payload, by_type, counts)


def roofline_terms(flops: float, bytes_accessed: float, wire_bytes: float) -> Dict:
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_accessed / HBM_BW
    coll_t = wire_bytes / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = max(bound, 1e-30)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "bound_s": bound,
        "roofline_fraction_compute": compute_t / total,
    }


def model_flops(cfg, shape_cell, kind: str) -> float:
    """Analytic useful FLOPs per step: 6·N·D train, 2·N·D forward-only
    (MoE: N_active)."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = shape_cell.global_batch * shape_cell.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape_cell.global_batch * shape_cell.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence (attention reads the cache; the 2·N·D
    # matmul term is the useful-work yardstick)
    return 2.0 * n * shape_cell.global_batch
