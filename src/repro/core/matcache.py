"""Materialized-subquery cache: encoded pooled rows persisted across batches.

The plan cache (``core/compiler.py::PlanCache``) removes the host-side
compile cost of a repeated subquery; this module removes the DEVICE cost.
A ``MaterializedSubqueryCache`` holds the encoded answer rows of hot queries
keyed by ``QueryInstance.key()`` in a bounded host buffer with CLOCK
(second-chance) eviction — the same slot/owner/ref discipline as
``semantic/store.py::SemanticCache`` — so a duplicate query arriving in a
LATER batch is served off its cached row instead of re-encoded.

Correctness is entirely an invalidation story, and the invalidation is a
single version stamp:

* every row is stamped with the cache ``version`` it was computed under;
* ``bump_version`` is O(1) — it increments the version, so every resident
  row becomes unservable at once (stale slots are reclaimed first by the
  CLOCK sweep, never returned by ``lookup``);
* the stamp bumps on every **param update** (the trainer after each Adam
  step, the serving engine on ``update_params``) and on every **KG/store
  write** (``KnowledgeGraph.add_triples`` notifies listeners registered via
  ``watch_kg``);
* consumers may PIN the version they paired with a params snapshot
  (``version=`` on ``lookup``/``insert``): a lookup serves only rows
  stamped exactly that version, and an insert of rows computed under a
  pinned version is silently DROPPED when the cache has moved on
  (``stale_drops``) — this closes the race where a batch encodes under old
  params while an update lands concurrently;
* GRAPH-version-pinned queries (DESIGN.md §LiveStore) additionally fold the
  pinned ``graph_version`` into the row key itself
  (``PooledExecutor.encode(graph_version=...)``): rows encoded against
  different snapshots of the KG can never alias, even within one cache
  version.

Why cached rows are exempt from the compiler's grad-reassociation ulp
caveat (DESIGN.md §Compiler): materialized rows are consumed on INFERENCE
paths only (``PooledExecutor.encode``, the serving batcher) — never inside
the fused train step, where a constant row would silently detach the
gradient of its subtree. Within one params version, pooled operators are
row-wise and composition-independent, so a cached row is bitwise the row a
fresh no-cache compute would produce; across param updates the version
stamp forbids reuse. There is no cross-step accumulation to reassociate.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.registry import get_registry


class MaterializedSubqueryCache:
    """Bounded, version-stamped cache of encoded query rows.

    Thread-safe: the serving batcher, the pipeline scheduler thread and
    trainer/eval callers share one instance. All buffer reads/writes happen
    under the lock; ``lookup`` returns row COPIES so a slot reused by a
    concurrent insert can never tear a row a caller already holds.
    """

    def __init__(self, budget_rows: int, name: str = "materialized"):
        if budget_rows < 1:
            raise ValueError(f"budget_rows must be >= 1, got {budget_rows}")
        self.budget_rows = budget_rows
        self.name = name
        self._lock = threading.Lock()
        self._version = 0
        self._buf: Optional[np.ndarray] = None     # [budget, dim], lazy
        self._slot_of: Dict[Tuple, int] = {}       # key -> slot
        self._owner: List[Optional[Tuple]] = [None] * budget_rows
        self._stamp = np.full(budget_rows, -1, dtype=np.int64)
        self._ref = np.zeros(budget_rows, dtype=bool)
        self._hand = 0
        self._metrics = get_registry().group("mat_cache", cache=name)
        self.hits = self._metrics.counter("hits")
        self.misses = self._metrics.counter("misses")
        self.probe_hits = self._metrics.counter("probe_hits")
        self.probe_misses = self._metrics.counter("probe_misses")
        self.inserts = self._metrics.counter("inserts")
        self.evictions = self._metrics.counter("evictions")
        self.invalidations = self._metrics.counter("invalidations")
        self.stale_drops = self._metrics.counter("stale_drops")
        self._inval_reasons: Dict[str, int] = {}

    # -------------------------------------------------------------- version
    @property
    def version(self) -> int:
        return self._version

    def bump_version(self, reason: str = "param_update") -> int:
        """O(1) whole-cache invalidation: every resident row's stamp no
        longer matches, so nothing encoded before this call can be served
        at the new version."""
        with self._lock:
            self._version += 1
            self.invalidations += 1
            self._inval_reasons[reason] = self._inval_reasons.get(reason, 0) + 1
            return self._version

    def watch_kg(self, kg) -> None:
        """Subscribe to KG writes: a committed ``KnowledgeGraph`` write
        calls the listener (reason ``"kg_write"`` / ``"entity_add"``),
        bumping the version stamp. A no-op write (empty input, all rows
        already present) never fires, so warm rows survive it. The KG holds
        the listener WEAKLY (``weakref.WeakMethod`` around this bound
        method), so dropping the cache lets it be collected — no explicit
        unsubscribe needed."""
        kg.add_invalidation_listener(self.bump_version)

    # --------------------------------------------------------------- access
    def lookup(self, keys: Sequence[Tuple], version: Optional[int] = None
               ) -> Dict[int, np.ndarray]:
        """Rows for ``keys`` valid at ``version`` (default: current), as
        ``{index -> row copy}``. A key whose slot carries any other stamp is
        a miss — stale rows are never returned."""
        out: Dict[int, np.ndarray] = {}
        with self._lock:
            v = self._version if version is None else version
            for i, k in enumerate(keys):
                s = self._slot_of.get(k)
                if s is not None and self._stamp[s] == v:
                    self._ref[s] = True
                    self.hits += 1
                    out[i] = self._buf[s].copy()
                else:
                    self.misses += 1
        return out

    def probe(self, keys: Sequence[Tuple], version: Optional[int] = None
              ) -> int:
        """Count how many of ``keys`` are resident at ``version`` WITHOUT
        copying rows or touching the hit/miss counters — the pipeline
        scheduler thread's staging probe (training can never consume
        materialized rows in the grad path, so it only observes)."""
        n = 0
        with self._lock:
            v = self._version if version is None else version
            for k in keys:
                s = self._slot_of.get(k)
                if s is not None and self._stamp[s] == v:
                    n += 1
            self.probe_hits += n
            self.probe_misses += len(keys) - n
        return n

    def insert(self, keys: Sequence[Tuple], rows: np.ndarray,
               version: Optional[int] = None) -> int:
        """Store ``rows[i]`` under ``keys[i]``, stamped ``version`` (default:
        current). If the caller pinned a version and the cache has since been
        bumped, the whole insert is dropped (``stale_drops``): rows computed
        under superseded params/KG state must never become servable. Returns
        the number of rows stored."""
        rows = np.asarray(rows)
        if len(keys) != len(rows):
            raise ValueError(f"{len(keys)} keys for {len(rows)} rows")
        with self._lock:
            v = self._version if version is None else version
            if v != self._version:
                self.stale_drops += len(keys)
                return 0
            if self._buf is None:
                self._buf = np.empty((self.budget_rows, rows.shape[1]),
                                     dtype=rows.dtype)
            elif rows.shape[1] != self._buf.shape[1]:
                raise ValueError(
                    f"row dim {rows.shape[1]} != cache dim {self._buf.shape[1]}"
                    " — one cache serves one model")
            for k, row in zip(keys, rows):
                s = self._slot_of.get(k)
                if s is None:
                    s = self._take_slot()
                    old = self._owner[s]
                    if old is not None:
                        del self._slot_of[old]
                        self.evictions += 1
                    self._owner[s] = k
                    self._slot_of[k] = s
                self._buf[s] = row
                self._stamp[s] = v
                self._ref[s] = True
                self.inserts += 1
            return len(keys)

    def _take_slot(self) -> int:
        """CLOCK sweep (lock held): free and STALE slots are reclaimed
        immediately — a row stamped with a superseded version is dead weight
        regardless of its reference bit; live rows get one second chance."""
        for _ in range(2 * self.budget_rows):
            s = self._hand
            self._hand = (self._hand + 1) % self.budget_rows
            if self._owner[s] is None or self._stamp[s] != self._version:
                return s
            if self._ref[s]:
                self._ref[s] = False
                continue
            return s
        return self._hand  # unreachable: a full sweep clears every ref bit

    # -------------------------------------------------------------- metrics
    @property
    def hit_rate(self) -> float:
        n = int(self.hits) + int(self.misses)
        return int(self.hits) / n if n else 0.0

    def stats(self) -> Dict:
        with self._lock:
            live = int(np.count_nonzero(
                (self._stamp == self._version)
                & np.asarray([o is not None for o in self._owner])))
            return {
                "name": self.name,
                "capacity": self.budget_rows,
                "resident": len(self._slot_of),
                "live": live,                  # resident AND current-version
                "version": self._version,
                "hits": int(self.hits),
                "misses": int(self.misses),
                "hit_rate": self.hit_rate,
                "probe_hits": int(self.probe_hits),
                "probe_misses": int(self.probe_misses),
                "inserts": int(self.inserts),
                "evictions": int(self.evictions),
                "invalidations": int(self.invalidations),
                "stale_drops": int(self.stale_drops),
                "invalidation_reasons": dict(self._inval_reasons),
            }

    def reset_counters(self) -> None:
        """Zero the counters (contents, version and stamps kept) — e.g.
        after serving warmup so the steady-state hit rate is measured over
        the timed phase only."""
        with self._lock:
            self._metrics.reset()
            self._inval_reasons = {}

    def clear(self) -> None:
        with self._lock:
            self._slot_of.clear()
            self._owner = [None] * self.budget_rows
            self._stamp.fill(-1)
            self._ref.fill(False)
            self._hand = 0

    # ---------------------------------------------------------------- debug
    def check_consistent(self) -> None:
        """Invariant check for the concurrency tests: the key->slot map and
        the slot->owner array must be exact inverses, and every mapped slot
        must be in range."""
        with self._lock:
            for k, s in self._slot_of.items():
                assert 0 <= s < self.budget_rows, (k, s)
                assert self._owner[s] == k, (k, s, self._owner[s])
            owners = [o for o in self._owner if o is not None]
            assert len(owners) == len(self._slot_of)
            assert set(owners) == set(self._slot_of)
