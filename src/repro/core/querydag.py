"""Batched QueryDAG construction (§4.1, "Graph Decomposition").

A mini-batch of queries with arbitrary mixed patterns is merged into one
global DAG; node ids are batch-global so operators from *different* queries
can later live in the same execution pool (cross-query operator fusion).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.ops import OpType
from repro.core.patterns import TEMPLATES, QueryInstance


@dataclasses.dataclass
class BatchedDAG:
    """Structure-of-arrays DAG for a query batch."""

    op: np.ndarray              # [n_nodes] int8 OpType
    rel: np.ndarray             # [n_nodes] int64, -1 if not PROJECT
    anchor: np.ndarray          # [n_nodes] int64, -1 if not EMBED
    query_id: np.ndarray        # [n_nodes] int64
    inputs: List[Tuple[int, ...]]   # per-node input node ids
    n_consumers: np.ndarray     # [n_nodes] refcount seed for Eq. 7
    answer_node: np.ndarray     # [n_queries] node id of each answer
    patterns: List[str]         # per-query pattern name

    @property
    def n_nodes(self) -> int:
        return len(self.op)

    @property
    def n_queries(self) -> int:
        return len(self.answer_node)

    def structure_key(self) -> Tuple:
        """Hashable multiset key: schedules depend only on the pattern
        multiset, so this keys the schedule cache."""
        names, counts = np.unique(np.array(self.patterns), return_counts=True)
        return tuple(zip(names.tolist(), counts.tolist()))


def build_batched_dag(queries: Sequence[QueryInstance]) -> BatchedDAG:
    ops: List[int] = []
    rels: List[int] = []
    anchors: List[int] = []
    qids: List[int] = []
    inputs: List[Tuple[int, ...]] = []
    answers: List[int] = []
    patterns: List[str] = []

    for qi, q in enumerate(queries):
        tpl = TEMPLATES[q.pattern]
        base = len(ops)
        a_i = r_i = 0
        for node in tpl.nodes:
            ops.append(int(node.op))
            if node.op == OpType.EMBED:
                anchors.append(int(q.anchors[a_i]))
                a_i += 1
            else:
                anchors.append(-1)
            if node.op == OpType.PROJECT:
                rels.append(int(q.relations[r_i]))
                r_i += 1
            else:
                rels.append(-1)
            qids.append(qi)
            inputs.append(tuple(base + j for j in node.inputs))
        answers.append(base + tpl.answer_node)
        patterns.append(q.pattern)

    n = len(ops)
    n_consumers = np.zeros(n, dtype=np.int64)
    for inp in inputs:
        for j in inp:
            n_consumers[j] += 1
    # Answer nodes have one extra logical consumer: the scoring head. This
    # keeps their slots live through the end of the schedule (Eq. 7).
    for a in answers:
        n_consumers[a] += 1

    return BatchedDAG(
        op=np.asarray(ops, dtype=np.int8),
        rel=np.asarray(rels, dtype=np.int64),
        anchor=np.asarray(anchors, dtype=np.int64),
        query_id=np.asarray(qids, dtype=np.int64),
        inputs=inputs,
        n_consumers=n_consumers,
        answer_node=np.asarray(answers, dtype=np.int64),
        patterns=patterns,
    )
