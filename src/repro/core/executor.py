"""Pooled (operator-level) and query-level (baseline) execution engines.

The pooled executor traces the host-computed ``ExecutionSchedule`` into one
jit program: every PoolStep is a gather → fused-operator-kernel → scatter on a
slot-reused workspace tensor (DESIGN.md §3). Compiled programs are cached by
schedule signature in an LRU ``CompileCache`` with hit/miss counters
(DESIGN.md §Pipeline); pool sizes are bucketed so the signature set is small
and — after warmup — every lookup hits, i.e. zero retraces in steady state.

Batch preparation is delegated to the plan compiler (``core/compiler.py``,
DESIGN.md §Compiler): ``prepare`` canonicalizes the batch, merges identical
subqueries across all queries via CSE (``cse=False`` is the ablation path),
lowers through the Max-Fillness scheduler, and memoizes everything
binding-independent by the deduped topology — so each repeated structure
only rebinds anchor/relation ids, and shared subtrees are computed once for
every query that consumes them."""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile_cache import CompileCache
from repro.core.compiler import PlanCache, compile_batch
from repro.core.ops import OpType
from repro.core.patterns import QueryInstance
from repro.core.plan import CompiledPlan
from repro.obs.registry import get_registry

# Backwards-compatible name: the prepared-batch artifact is now the
# compiler's output (same fields plus the sharing report).
PreparedBatch = CompiledPlan


class PooledExecutor:
    """Operator-level batching engine (the paper's contribution 1).

    ``ctx`` (``distributed.context.ExecutionContext``, default single-device)
    is the placement policy: under a mesh context the traced programs pin the
    workspace batch-sharded over the data axes with a sharding constraint, so
    pool-step gathers/scatters partition instead of replicating. The encode
    closure itself stays signature-keyed — one executor serves one context
    for its lifetime, so the context never enters the cache key."""

    def __init__(self, model, b_max: int = 512, reuse_slots: bool = True,
                 policy: str = "max_fillness", cache_size: int = 128,
                 ctx=None, cse: bool = True, plan_cache: Optional[PlanCache] = None,
                 plan_cache_size: int = 512, mat_cache=None,
                 tile_policy="auto"):
        from repro.distributed.context import ExecutionContext

        self.model = model
        self.b_max = b_max
        self.reuse_slots = reuse_slots
        self.policy = policy
        self.cse = cse
        self.ctx = ctx or ExecutionContext.single_device()
        # Kernel-aware pool padding (DESIGN.md §Autotuner). "auto" snapshots
        # a policy from the process tuner AT CONSTRUCTION — the policy (and
        # its cache-key contribution) is then immutable for this executor's
        # lifetime, so its signature universe stays closed. With an untuned
        # tuner the snapshot is None and padding is bare pow2, bit-identical
        # to the pre-autotuner engine.
        if tile_policy == "auto":
            from repro.kernels.autotune import pool_tile_policy

            tile_policy = pool_tile_policy(model, b_max=b_max)
        self.tile_policy = tile_policy
        self._sched_cache = CompileCache(cache_size, name="schedule")
        self._encode_cache = CompileCache(cache_size, name="encode")
        self._encode_jit_cache = CompileCache(cache_size, name="encode_jit")
        # Cross-batch plan cache (DESIGN.md §Compiler): persists compiled
        # plans across prepare() calls so a repeated batch is one dict
        # lookup, no canonicalize/hash-cons/bind work. Always on — plans
        # never go stale (keyed on query keys + compile config only).
        self._plan_cache = plan_cache or PlanCache(plan_cache_size)
        # Optional materialized-row cache consulted by encode() (inference
        # paths only; the fused train step's encode closure never sees it —
        # a constant row inside grad would detach its subtree's gradient).
        self.mat_cache = mat_cache
        # Cumulative sharing-report totals across every prepared batch
        # (registry counters so process snapshots see CSE effect too).
        self._exec_metrics = get_registry().group("executor")
        self._nodes_before = self._exec_metrics.counter("nodes_before")
        self._nodes_after = self._exec_metrics.counter("nodes_after")
        self._stats_lock = threading.Lock()

    def cache_stats(self) -> Dict[str, Dict[str, float]]:
        """Hit/miss/eviction counters for every SIGNATURE-keyed cache — the
        set whose misses define ``retraces``. The plan cache is deliberately
        absent: a plan-cache miss on fresh traffic re-runs host hash-consing
        but compiles nothing (its counters live in ``sharing_stats``)."""
        return {"schedule": self._sched_cache.stats(),
                "encode": self._encode_cache.stats(),
                "encode_jit": self._encode_jit_cache.stats()}

    def reset_cache_counters(self) -> None:
        """Zero counters on every cache (contents kept) — e.g. after serving
        warmup so steady-state retraces are measured over traffic only.
        Scoped to THIS executor's caches; ``obs.get_registry().reset()`` is
        the process-wide variant."""
        for c in (self._sched_cache, self._encode_cache,
                  self._encode_jit_cache, self._plan_cache):
            c.reset_counters()
        if self.mat_cache is not None:
            self.mat_cache.reset_counters()

    # ------------------------------------------------------------------ prep
    def prepare(self, queries: Sequence[QueryInstance],
                graph_version: int = -1) -> PreparedBatch:
        """Thin wrapper over the plan compiler: canonicalize, CSE-merge
        shared subqueries (unless ``cse=False``), lower through the
        Max-Fillness scheduler, memoizing by deduped topology in the
        executor's schedule cache. ``graph_version`` (-1 = unpinned) is
        folded into the plan-cache key only — see ``compile_batch``."""
        plan = compile_batch(
            queries, model_name=self.model.name, b_max=self.b_max,
            reuse_slots=self.reuse_slots, policy=self.policy, cse=self.cse,
            sched_cache=self._sched_cache, plan_cache=self._plan_cache,
            tile_policy=self.tile_policy, graph_version=graph_version,
        )
        with self._stats_lock:
            self._nodes_before += plan.report.nodes_before
            self._nodes_after += plan.report.nodes_after
        return plan

    def sharing_stats(self) -> Dict:
        """Cumulative CSE effect over every batch this executor prepared,
        plus the cross-batch reuse counters: ``plan_cache`` (compiled-plan
        hits/misses/canonicalize_calls) and, when attached, ``materialized``
        (encoded-row hits/misses/invalidations)."""
        with self._stats_lock:
            before, after = int(self._nodes_before), int(self._nodes_after)
        saved = before - after
        out = {
            "nodes_before": before,
            "nodes_after": after,
            "pooled_rows_saved": saved,
            "saved_frac": saved / max(before, 1),
            "plan_cache": self._plan_cache.stats(),
        }
        if self.mat_cache is not None:
            out["materialized"] = self.mat_cache.stats()
        return out

    # ---------------------------------------------------------------- encode
    def encode_fn(self, prepared: PreparedBatch):
        """Returns a pure fn (params, steps, answer_slots) -> q_states that is
        traceable under jit/grad; structure is closed over statically."""
        key = prepared.signature
        fn = self._encode_cache.get(key)
        if fn is not None:
            return fn
        model = self.model
        meta = prepared.meta
        ctx = self.ctx
        n_ws = prepared.n_slots_padded + 1  # +1 trash row for padding scatters
        if ctx.is_sharded:
            # Round the workspace rows up to a multiple of the DP ways so the
            # batch-sharding constraint below can actually bind ("data" must
            # divide dim 0). Rows past the trash row are never gathered or
            # scattered, so the numerics are untouched.
            dp = ctx.dp_size
            n_ws = ((n_ws + dp - 1) // dp) * dp

        def encode(params, steps, answer_slots):
            ws = ctx.constrain_batch(
                jnp.ones((n_ws, model.state_dim), dtype=jnp.float32))
            for (op, card, pn), arr in zip(meta, steps):
                op = OpType(op)
                if op == OpType.EMBED:
                    y = model.embed(params, arr["anchor_ids"])
                elif op == OpType.PROJECT:
                    y = model.project(params, ws[arr["in_slots"][:, 0]], arr["rel_ids"])
                elif op == OpType.NEGATE:
                    y = model.negate(params, ws[arr["in_slots"][:, 0]])
                elif op == OpType.INTERSECT:
                    y = model.intersect(params, ws[arr["in_slots"]])
                elif op == OpType.UNION:
                    y = model.union(params, ws[arr["in_slots"]])
                else:  # pragma: no cover
                    raise ValueError(op)
                ws = ws.at[arr["out_slots"]].set(y)
            return ws[answer_slots]

        self._encode_cache.put(key, encode)
        return encode

    def encode_fn_compiled(self, prepared: PreparedBatch):
        """``jax.jit``-compiled twin of ``encode_fn``, cached per signature.

        The trainer never needs this (its encode closure is embedded inside
        the fused jitted train step), but inference paths that call encode
        standalone — the serving engine and the offline ``serve_batch``
        baseline — would otherwise dispatch every pool step as a separate
        eager op. One compiled program per signature keeps steady-state
        serving at zero retraces, and both serving paths sharing THIS cache
        key is what makes their outputs bit-identical."""
        key = prepared.signature
        fn = self._encode_jit_cache.get(key)
        if fn is None:
            fn = jax.jit(self.encode_fn(prepared))
            self._encode_jit_cache.put(key, fn)
        return fn

    def encode(self, params, queries: Sequence[QueryInstance],
               compiled: bool = False, graph_version: int = -1) -> jnp.ndarray:
        """Convenience path returning states in ORIGINAL query order.

        ``compiled=False`` (default) runs the encode closure eagerly —
        bit-for-bit the historical behavior. ``compiled=True`` routes through
        the per-signature jitted program (``encode_fn_compiled``) — the
        serving path, where the whole-batch program amortizes to zero
        retraces in steady state.

        With a ``mat_cache`` attached, rows cached at the CURRENT version
        are served without touching the device and only the miss subset is
        encoded (then inserted back). Pooled operators are row-wise and
        composition-independent, so subset encode rows are bitwise the rows
        the full batch would have produced — cache on/off is invisible
        GIVEN the version discipline (callers bump on every param update).

        ``graph_version`` (-1 = unpinned) is folded into the materialized
        row keys and the plan-cache key, so a version-pinned replay can
        never be served a row admitted under a different graph state."""
        cache = self.mat_cache
        if cache is None or len(queries) == 0:
            return self._encode_fresh(params, queries, compiled,
                                      graph_version)
        keys = [q.key() if graph_version < 0
                else q.key() + (graph_version,) for q in queries]
        ver = cache.version
        rows = cache.lookup(keys, version=ver)
        if len(rows) == len(queries):
            return jnp.asarray(
                np.stack([rows[i] for i in range(len(queries))]))
        miss = [i for i in range(len(queries)) if i not in rows]
        sub = [queries[i] for i in miss]
        if compiled and len(sub) > 1:
            # Pad the miss subset to pow2 (repeat last) so varying hit
            # counts cannot grow the jitted-encode signature set beyond
            # what cache-off traffic produces; padded rows are discarded.
            b = 1 << (len(sub) - 1).bit_length()
            sub = sub + [sub[-1]] * (b - len(sub))
        fresh = np.asarray(
            self._encode_fresh(params, sub, compiled,
                               graph_version))[: len(miss)]
        cache.insert([keys[i] for i in miss], fresh, version=ver)
        out = np.empty((len(queries), fresh.shape[1]), dtype=fresh.dtype)
        for j, i in enumerate(miss):
            out[i] = fresh[j]
        for i, r in rows.items():
            out[i] = r
        return jnp.asarray(out)

    def _encode_fresh(self, params, queries: Sequence[QueryInstance],
                      compiled: bool, graph_version: int = -1) -> jnp.ndarray:
        prepared = self.prepare(queries, graph_version=graph_version)
        steps, ans = prepared.device_args()
        fn = (self.encode_fn_compiled(prepared) if compiled
              else self.encode_fn(prepared))
        states = fn(params, steps, ans)
        inv = np.empty_like(prepared.order)
        inv[prepared.order] = np.arange(len(prepared.order))
        return states[jnp.asarray(inv)]


class QueryLevelExecutor:
    """The baseline the paper beats: batching restricted to isomorphic query
    groups (KGReasoning/SQE-style). Each pattern group executes as its own
    fragmented sequence of kernels, so a mixed batch of |T| patterns issues
    ~|T|x more, ~|T|x smaller kernels.

    Exposes the same ``prepare`` / ``encode_fn`` / ``cache_stats`` surface as
    ``PooledExecutor`` (delegated to the inner engine), so callers like the
    trainer never reach into ``_inner`` or mutate attributes to mark the
    query-level mode — the per-pattern-group fragmentation lives entirely in
    ``encode`` / the trainer's query-level step, not in the interface."""

    def __init__(self, model, b_max: int = 512, ctx=None):
        self.model = model
        # cse=False: the baseline frameworks never share work across queries
        # — leaving CSE on would quietly hand the baseline the paper's win.
        self._inner = PooledExecutor(model, b_max=b_max, reuse_slots=True,
                                     policy="fifo", ctx=ctx, cse=False)

    @property
    def ctx(self):
        return self._inner.ctx

    def prepare(self, queries: Sequence[QueryInstance]) -> PreparedBatch:
        """Schedule one (single-pattern) group — callers group first."""
        return self._inner.prepare(queries)

    def encode_fn(self, prepared: PreparedBatch):
        return self._inner.encode_fn(prepared)

    def encode_fn_compiled(self, prepared: PreparedBatch):
        return self._inner.encode_fn_compiled(prepared)

    def cache_stats(self) -> Dict[str, Dict[str, float]]:
        return self._inner.cache_stats()

    def sharing_stats(self) -> Dict[str, float]:
        return self._inner.sharing_stats()

    def reset_cache_counters(self) -> None:
        self._inner.reset_cache_counters()

    def prepare_groups(self, queries: Sequence[QueryInstance]):
        groups: Dict[str, List[QueryInstance]] = {}
        idx: Dict[str, List[int]] = {}
        for i, q in enumerate(queries):
            groups.setdefault(q.pattern, []).append(q)
            idx.setdefault(q.pattern, []).append(i)
        return groups, idx

    def encode(self, params, queries: Sequence[QueryInstance],
               compiled: bool = False) -> jnp.ndarray:
        groups, idx = self.prepare_groups(queries)
        out = [None] * len(queries)
        for pat, qs in groups.items():
            # one fragment per pattern
            states = self._inner.encode(params, qs, compiled=compiled)
            for j, i in enumerate(idx[pat]):
                out[i] = states[j]
        return jnp.stack(out)
