"""Signature-keyed compile cache with LRU eviction and hit/miss counters.

The pooled executor compiles one XLA program per ``ExecutionSchedule``
signature (DESIGN.md §Pipeline). Because pool sizes are bucketed to powers of
two and batches are canonicalized by pattern, the signature set is small and
stable — after warmup every lookup should hit. The counters make that claim
measurable: ``benchmarks/throughput.py --compare`` asserts a 100% hit rate
(zero retraces) in steady state, and the training loop can surface
``stats()`` for monitoring.

LRU eviction bounds host memory when a long-running job sees an unbounded
stream of signatures (e.g. curriculum over pattern mixes): evicting a program
is always safe — the next encounter of that signature just recompiles.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Dict, Hashable, Optional

from repro.obs.registry import get_registry


class CompileCache:
    """An LRU mapping ``signature -> compiled program`` with counters.

    Thread-safe: the pipelined engine touches caches from the scheduler
    thread (schedule/encode caches) while the main thread reads stats.

    Counters are registry metrics (``cache_hits{cache=<name>}`` etc., see
    DESIGN.md §Observability); they stay int-comparable attributes so both
    existing call sites and a process-wide snapshot see the same numbers.
    """

    def __init__(self, capacity: int = 128, name: str = "compile"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.name = name
        self._metrics = get_registry().group("cache", cache=name)
        self.hits = self._metrics.counter("hits")
        self.misses = self._metrics.counter("misses")
        self.evictions = self._metrics.counter("evictions")
        self.size_gauge = self._metrics.gauge("size")
        self._d: "collections.OrderedDict[Hashable, Any]" = collections.OrderedDict()
        self._lock = threading.Lock()

    # ------------------------------------------------------------- mapping
    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any) -> Any:
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)
                self.evictions += 1
            self.size_gauge.set(len(self._d))
        return value

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._d  # no counter bump: membership probe, not lookup

    # ------------------------------------------------------------- metrics
    @property
    def hit_rate(self) -> float:
        n = int(self.hits) + int(self.misses)
        return int(self.hits) / n if n else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "name": self.name,
            "size": len(self._d),
            "capacity": self.capacity,
            "hits": int(self.hits),
            "misses": int(self.misses),
            "evictions": int(self.evictions),
            "hit_rate": self.hit_rate,
        }

    def reset_counters(self) -> None:
        """Zero the counters (not the contents) — e.g. after benchmark warmup
        so steady-state hit rate is measured over the timed phase only."""
        with self._lock:
            self._metrics.reset()
            self.size_gauge.set(len(self._d))

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self.size_gauge.set(0)
