# The paper's primary contribution: operator-level batched training.
from repro.core.compile_cache import CompileCache
from repro.core.compiler import PlanCache, build_plan, compile_batch, plan_to_dag
from repro.core.matcache import MaterializedSubqueryCache
from repro.core.executor import PooledExecutor, PreparedBatch, QueryLevelExecutor
from repro.core.ops import OpType
from repro.core.plan import CompiledPlan, PlanGraph, PlanNode, SharingReport
from repro.core.patterns import (
    EVAL_PATTERNS,
    NEGATION_PATTERNS,
    PATTERN_NAMES,
    TEMPLATES,
    QueryInstance,
    answer_query,
)
from repro.core.querydag import BatchedDAG, build_batched_dag
from repro.core.scheduler import ExecutionSchedule, PoolStep, schedule

__all__ = [
    "OpType",
    "TEMPLATES",
    "PATTERN_NAMES",
    "NEGATION_PATTERNS",
    "EVAL_PATTERNS",
    "QueryInstance",
    "answer_query",
    "BatchedDAG",
    "build_batched_dag",
    "ExecutionSchedule",
    "PoolStep",
    "schedule",
    "PooledExecutor",
    "QueryLevelExecutor",
    "PreparedBatch",
    "CompiledPlan",
    "PlanGraph",
    "PlanNode",
    "SharingReport",
    "build_plan",
    "compile_batch",
    "plan_to_dag",
    "CompileCache",
    "PlanCache",
    "MaterializedSubqueryCache",
]
