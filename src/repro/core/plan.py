"""Plan IR: the compiler's intermediate representation and its output.

The paper decouples logical operators from query topologies (§3); this module
is where a *batch* of query topologies becomes one shared program. A
``PlanGraph`` is a hash-consed operator DAG: every node is canonically
identified by ``(op, binding, child ids)``, so two queries whose subtrees are
structurally AND binding-wise identical (same anchor + relation chain — the
common case in 2p/3p/ip/pi workloads and in real serving traffic) point at
the SAME node. Construction (``compiler.build_plan``) interns nodes bottom-up,
which makes cross-query common-subexpression elimination a dictionary lookup
rather than a graph-isomorphism search.

``CompiledPlan`` is the fully lowered artifact every consumer executes:
the Max-Fillness schedule's static slot arrays, the per-batch bind arrays,
the per-query answer-slot map (duplicate answers alias the same slot — the
gather at the end of the encode fans one computed row out to every consuming
query, and gradients through shared nodes sum automatically in reverse mode),
plus a ``SharingReport`` quantifying what CSE bought.

Why CSE is semantically invisible (bitwise): every pooled operator is
row-wise — each output row depends only on that row's input rows, never on
the pool's composition or padded size — so a merged node computes exactly
the bits each duplicate would have computed, and consumers gather the same
values they would have produced locally. DESIGN.md §Compiler carries the
full argument.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PlanNode:
    """One IR node. ``children`` are plan-node ids in template input order —
    deliberately NOT sorted: pooled intersect/union kernels reduce over the
    child axis in order, and commutative canonicalization could reorder a
    floating-point reduction, breaking the bitwise CSE-on == CSE-off
    contract. The canonical identity of a node is the full tuple
    ``(op, anchor, rel, children)`` (its hash-consing key)."""

    op: int                     # OpType value
    anchor: int                 # entity id for EMBED, else -1
    rel: int                    # relation id for PROJECT, else -1
    children: Tuple[int, ...]   # plan-node ids, template order

    def key(self) -> Tuple:
        return (self.op, self.anchor, self.rel, self.children)


@dataclasses.dataclass
class PlanGraph:
    """Deduplicated operator DAG for one canonically ordered query batch."""

    nodes: List[PlanNode]
    answer: np.ndarray          # [n_queries] plan-node id per query answer
    patterns: List[str]         # per-query pattern name (canonical order)
    nodes_before: int           # node count had no subexpression merged

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_queries(self) -> int:
        return len(self.answer)

    def topology_key(self) -> Tuple:
        """Hashable key of the POST-CSE shape, bindings excluded.

        The Max-Fillness schedule (and all slot index arrays) depends only on
        ``(op, children)`` per node plus the answer map — never on which
        entity/relation ids are bound — so two batches whose deduped DAGs
        coincide share one schedule-cache entry (and, after pow2 bucketing,
        usually one jit program) even when their ids differ. Node ids are
        already canonical: interning assigns them in first-use order over the
        canonically sorted batch."""
        return (
            tuple((n.op, n.children) for n in self.nodes),
            tuple(self.answer.tolist()),
        )

    def consumer_counts(self) -> np.ndarray:
        """Eq. 7 refcount seeds on the MERGED graph: consumers are counted
        across every query that reaches a node (plus one scoring-head
        consumer per answer *reference*, so a slot aliased by k queries stays
        live until all k have been scored)."""
        counts = np.zeros(self.n_nodes, dtype=np.int64)
        for node in self.nodes:
            for j in node.children:
                counts[j] += 1
        for a in self.answer:
            counts[a] += 1
        return counts


@dataclasses.dataclass(frozen=True)
class SharingReport:
    """What cross-query subexpression sharing bought for one batch. Each
    merged node is one pooled row that is no longer computed in some
    (possibly padded) pool step, so ``pooled_rows_saved`` is the Eq. 5
    kernel-row reduction and peak slot liveness shrinks with it."""

    nodes_before: int           # one DAG node per query node (no sharing)
    nodes_after: int            # post-CSE node count

    @property
    def pooled_rows_saved(self) -> int:
        return self.nodes_before - self.nodes_after

    @property
    def saved_frac(self) -> float:
        return self.pooled_rows_saved / max(self.nodes_before, 1)


@dataclasses.dataclass
class CompiledPlan:
    """Everything the jitted encoder needs for one batch — the single
    artifact training, serving and the offline baselines all execute.

    ``signature`` keys compiled PROGRAMS (it only encodes bucketed shapes, so
    distinct structures may share one program); ``structure_key`` keys the
    exact schedule — the post-CSE topology under CSE, the pattern multiset
    without — i.e. anything caching the schedule's ARRAYS must use it, not
    the coarser signature. ``answer_slots`` is the per-query answer map:
    entry i is the workspace row holding query i's answer state, and entries
    alias whenever queries share their full tree."""

    signature: Tuple
    structure_key: Tuple
    meta: Tuple[Tuple[int, int, int], ...]      # static (op, card, padded_n) per step
    slot_arrays: List[Dict[str, np.ndarray]]    # static per structure: in/out slots
    bind_arrays: List[Dict[str, np.ndarray]]    # per batch: anchor/rel ids
    answer_slots: np.ndarray                    # [n_queries] workspace rows
    n_slots_padded: int
    sched: object                               # scheduler.ExecutionSchedule
    patterns: List[str]
    order: np.ndarray                           # canonical order -> original order
    report: SharingReport

    def device_args(self):
        steps = [
            {**s, **b} for s, b in zip(self.slot_arrays, self.bind_arrays)
        ]
        return steps, jnp.asarray(self.answer_slots)
