"""The 14 EFO query patterns (§3.1) as operator-DAG templates.

A template is a tuple of node specs ``(op, inputs, negated_inputs)`` where
``inputs`` are indices of earlier nodes. EMBED nodes consume an anchor slot,
PROJECT nodes consume a relation slot (slots are assigned in template order).
The final node is the answer node.

Negation in these 14 patterns only ever feeds an intersection, so symbolic
answer evaluation treats NEGATE lazily: ``I(A, ¬B) = A \\ B``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

from repro.core.ops import OpType
from repro.data.kg import KnowledgeGraph


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    op: OpType
    inputs: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class QueryTemplate:
    name: str
    nodes: Tuple[NodeSpec, ...]

    @property
    def n_anchors(self) -> int:
        return sum(1 for n in self.nodes if n.op == OpType.EMBED)

    @property
    def n_relations(self) -> int:
        return sum(1 for n in self.nodes if n.op == OpType.PROJECT)

    @property
    def answer_node(self) -> int:
        return len(self.nodes) - 1

    @property
    def depth(self) -> int:
        d = [0] * len(self.nodes)
        for i, n in enumerate(self.nodes):
            d[i] = 1 + max((d[j] for j in n.inputs), default=0)
        return max(d)


def _t(name: str, *nodes: Tuple[OpType, Tuple[int, ...]]) -> QueryTemplate:
    return QueryTemplate(name, tuple(NodeSpec(op, tuple(inp)) for op, inp in nodes))


E, P, I, U, N = OpType.EMBED, OpType.PROJECT, OpType.INTERSECT, OpType.UNION, OpType.NEGATE

TEMPLATES: Dict[str, QueryTemplate] = {
    t.name: t
    for t in [
        _t("1p", (E, ()), (P, (0,))),
        _t("2p", (E, ()), (P, (0,)), (P, (1,))),
        _t("3p", (E, ()), (P, (0,)), (P, (1,)), (P, (2,))),
        _t("2i", (E, ()), (E, ()), (P, (0,)), (P, (1,)), (I, (2, 3))),
        _t("3i", (E, ()), (E, ()), (E, ()), (P, (0,)), (P, (1,)), (P, (2,)), (I, (3, 4, 5))),
        # pi: (e1 -r1-> x -r2-> y) AND (e2 -r3-> y)
        _t("pi", (E, ()), (P, (0,)), (P, (1,)), (E, ()), (P, (3,)), (I, (2, 4))),
        # ip: (e1 -r1-> x AND e2 -r2-> x) -r3-> y
        _t("ip", (E, ()), (E, ()), (P, (0,)), (P, (1,)), (I, (2, 3)), (P, (4,))),
        _t("2u", (E, ()), (E, ()), (P, (0,)), (P, (1,)), (U, (2, 3))),
        _t("up", (E, ()), (E, ()), (P, (0,)), (P, (1,)), (U, (2, 3)), (P, (4,))),
        _t("2in", (E, ()), (E, ()), (P, (0,)), (P, (1,)), (N, (3,)), (I, (2, 4))),
        _t(
            "3in",
            (E, ()), (E, ()), (E, ()),
            (P, (0,)), (P, (1,)), (P, (2,)),
            (N, (5,)), (I, (3, 4, 6)),
        ),
        # inp: ((e1 -r1-> x) AND NOT (e2 -r2-> x)) -r3-> y
        _t("inp", (E, ()), (E, ()), (P, (0,)), (P, (1,)), (N, (3,)), (I, (2, 4)), (P, (5,))),
        # pin: (e1 -r1-> x -r2-> y) AND NOT (e2 -r3-> y)
        _t("pin", (E, ()), (P, (0,)), (P, (1,)), (E, ()), (P, (3,)), (N, (4,)), (I, (2, 5))),
        # pni: NOT (e1 -r1-> x -r2-> y) AND (e2 -r3-> y)
        _t("pni", (E, ()), (P, (0,)), (P, (1,)), (N, (2,)), (E, ()), (P, (4,)), (I, (3, 5))),
    ]
}

PATTERN_NAMES: List[str] = list(TEMPLATES.keys())
NEGATION_PATTERNS = ("2in", "3in", "inp", "pin", "pni")
UNION_PATTERNS = ("2u", "up")
EVAL_PATTERNS = PATTERN_NAMES  # all 14 evaluated, as in the paper


@dataclasses.dataclass
class QueryInstance:
    """A grounded query: template + anchor entities + relation ids."""

    pattern: str
    anchors: np.ndarray  # [n_anchors] int64
    relations: np.ndarray  # [n_relations] int64

    def key(self) -> Tuple:
        # Memoized: the serving path hashes the same instance several times
        # (router placement, batch coalescing, materialized-cache keys) and
        # anchors/relations never mutate after grounding.
        k = getattr(self, "_key", None)
        if k is None:
            k = (self.pattern, tuple(self.anchors.tolist()),
                 tuple(self.relations.tolist()))
            self._key = k
        return k


def answer_query(kg: KnowledgeGraph, q: QueryInstance) -> Set[int]:
    """Symbolic (set-semantics) evaluation — the ground-truth oracle used by
    the sampler for rejection sampling and by tests as the logic oracle."""
    tpl = TEMPLATES[q.pattern]
    sets: List[Set[int]] = [set()] * len(tpl.nodes)
    negated: List[bool] = [False] * len(tpl.nodes)
    a_i = 0
    r_i = 0
    for i, node in enumerate(tpl.nodes):
        if node.op == OpType.EMBED:
            sets[i] = {int(q.anchors[a_i])}
            a_i += 1
        elif node.op == OpType.PROJECT:
            heads = np.fromiter(sets[node.inputs[0]], dtype=np.int64) if sets[node.inputs[0]] else np.empty(0, np.int64)
            sets[i] = set(kg.neighbors_of_set(heads, int(q.relations[r_i])).tolist())
            r_i += 1
        elif node.op == OpType.NEGATE:
            sets[i] = sets[node.inputs[0]]
            negated[i] = True
        elif node.op == OpType.INTERSECT:
            pos = [sets[j] for j in node.inputs if not negated[j]]
            neg = [sets[j] for j in node.inputs if negated[j]]
            acc = set(pos[0])
            for s in pos[1:]:
                acc &= s
            for s in neg:
                acc -= s
            sets[i] = acc
        elif node.op == OpType.UNION:
            acc = set()
            for j in node.inputs:
                acc |= sets[j]
            sets[i] = acc
    return sets[tpl.answer_node]
