"""Query-plan compiler: canonicalize → CSE → Max-Fillness lowering.

Sits between ``patterns.py``/``querydag.py`` (the logical layer) and
``scheduler.py`` (Algorithm 1), and is the one place the whole engine turns
a query batch into an executable ``CompiledPlan``:

1. **Canonicalize** — sort the batch by the full query key (pattern, anchors,
   relations). Batches that are permutations of each other now produce the
   identical node numbering, so their topology keys — and schedule-cache
   entries — coincide. The ``order`` permutation is carried in the plan and
   inverted by callers that need original order.
2. **CSE** (``build_plan``) — intern every subquery bottom-up by its
   canonical identity ``(op, binding, child ids)``. Identical subtrees
   across ALL queries in the batch collapse to one node with multi-consumer
   fan-out; Eq. 7 refcounts then count consumers across queries, so slot
   liveness — and peak workspace memory — shrinks with sharing.
3. **Lower** — run the unmodified Max-Fillness scheduler on the merged DAG
   and pad its slot arrays; bind arrays (anchor/relation ids, the only
   batch-varying part) are rebuilt per batch via one vectorized gather over
   a precomputed index plan instead of per-step Python loops — this runs on
   the pipeline's scheduler thread every batch.

``cse=False`` is the ablation path (``--no-cse``): per-query nodes exactly
as ``build_batched_dag`` has always produced them, schedule cache keyed on
the pattern multiset. Per-query encode rows stay bitwise what the
historical engine produced; the one deliberate change is canonical order —
full-key sort instead of pattern-only — so two same-pattern queries may
swap batch rows relative to pre-compiler runs (the per-query loss MEAN can
reassociate by ulps vs old recorded curves, while CSE-on vs CSE-off inside
this engine compare bitwise, both using the same order).

``PlanCache`` makes the whole pipeline above CROSS-BATCH: a repeated batch
(exact query-key tuple) skips steps 1-3 entirely, and a permutation of a
seen batch skips 2-3 — which is what finally takes the per-batch host
compile cost off the steady-state hot path (the CSE throughput regression
BENCH_plan.json used to record).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ops import OpType
from repro.core.patterns import TEMPLATES, QueryInstance
from repro.obs.registry import get_registry as _get_registry
from repro.core.plan import CompiledPlan, PlanGraph, PlanNode, SharingReport
from repro.core.querydag import BatchedDAG, build_batched_dag
from repro.core.scheduler import ExecutionSchedule, schedule


def build_plan(queries: Sequence[QueryInstance]) -> PlanGraph:
    """Hash-consing CSE over a (canonically ordered) query batch.

    Children are interned before their parents (template nodes are listed in
    topological order), so a node's canonical key can use child *ids* —
    structural equality of whole subtrees reduces to one tuple comparison,
    and the merge is O(total nodes) dictionary operations."""
    intern: Dict[Tuple, int] = {}
    nodes: List[PlanNode] = []
    answers: List[int] = []
    patterns: List[str] = []
    nodes_before = 0
    for q in queries:
        tpl = TEMPLATES[q.pattern]
        ids: List[int] = []
        a_i = r_i = 0
        nodes_before += len(tpl.nodes)
        for node in tpl.nodes:
            anchor = rel = -1
            if node.op == OpType.EMBED:
                anchor = int(q.anchors[a_i])
                a_i += 1
            elif node.op == OpType.PROJECT:
                rel = int(q.relations[r_i])
                r_i += 1
            pn = PlanNode(int(node.op), anchor, rel,
                          tuple(ids[j] for j in node.inputs))
            nid = intern.get(pn.key())
            if nid is None:
                nid = len(nodes)
                intern[pn.key()] = nid
                nodes.append(pn)
            ids.append(nid)
        answers.append(ids[tpl.answer_node])
        patterns.append(q.pattern)
    return PlanGraph(
        nodes=nodes,
        answer=np.asarray(answers, dtype=np.int64),
        patterns=patterns,
        nodes_before=nodes_before,
    )


def plan_to_dag(plan: PlanGraph) -> BatchedDAG:
    """Lower the merged IR into the scheduler's structure-of-arrays DAG.
    ``query_id`` is -1 throughout: a shared node belongs to several queries,
    and the scheduler never reads this field."""
    n = plan.n_nodes
    op = np.fromiter((nd.op for nd in plan.nodes), dtype=np.int8, count=n)
    rel = np.fromiter((nd.rel for nd in plan.nodes), dtype=np.int64, count=n)
    anchor = np.fromiter((nd.anchor for nd in plan.nodes), dtype=np.int64,
                         count=n)
    return BatchedDAG(
        op=op,
        rel=rel,
        anchor=anchor,
        query_id=np.full(n, -1, dtype=np.int64),
        inputs=[nd.children for nd in plan.nodes],
        n_consumers=plan.consumer_counts(),
        answer_node=plan.answer.copy(),
        patterns=list(plan.patterns),
    )


def _pad1(a: np.ndarray, n: int, fill: int) -> np.ndarray:
    out = np.full((n,), fill, dtype=np.int64)
    out[: len(a)] = a
    return out


def _pad2(a: np.ndarray, n: int, fill: int) -> np.ndarray:
    out = np.full((n, a.shape[1]), fill, dtype=np.int64)
    out[: len(a)] = a
    return out


class _BindPlan:
    """Precomputed index plan for the per-batch bind-array rebuild.

    The schedule's node order is static per structure; only the anchor and
    relation ids bound to those nodes change between batches. One gather of
    ``dag.rel``/``dag.anchor`` at ``gather_nodes`` plus one scatter into a
    flat padded buffer replaces the per-step Python loops that used to run
    on the scheduler thread every batch; per-step arrays are then zero-copy
    slices of the buffer."""

    def __init__(self, sched: ExecutionSchedule):
        spans: List[Tuple[int, int, int]] = []   # (offset, n_real, padded_n)
        off = 0
        for s in sched.steps:
            spans.append((off, s.n, s.padded_n))
            off += s.padded_n
        self.total = off
        self.spans = spans
        self.gather_nodes = (
            np.concatenate([s.node_ids for s in sched.steps])
            if sched.steps else np.empty(0, dtype=np.int64))
        # flat positions of real rows inside the padded buffer
        self.pad_pos = (
            np.concatenate([o + np.arange(n, dtype=np.int64)
                            for o, n, _ in spans])
            if spans else np.empty(0, dtype=np.int64))

    def bind(self, rel: np.ndarray, anchor: np.ndarray
             ) -> List[Dict[str, np.ndarray]]:
        rel_flat = np.zeros(self.total, dtype=np.int64)
        anc_flat = np.zeros(self.total, dtype=np.int64)
        # clip(min=0): non-PROJECT/EMBED nodes carry -1 and pool kernels read
        # the column unconditionally, same contract as the padded fill.
        rel_flat[self.pad_pos] = np.maximum(rel[self.gather_nodes], 0)
        anc_flat[self.pad_pos] = np.maximum(anchor[self.gather_nodes], 0)
        return [
            {"rel_ids": rel_flat[o:o + p], "anchor_ids": anc_flat[o:o + p]}
            for o, _, p in self.spans
        ]


class PlanCache:
    """Cross-batch compiled-plan cache (DESIGN.md §Compiler, cross-batch).

    The PR-5 compiler memoized the *schedule* by deduped topology but still
    paid canonicalize + hash-consing + bind gathers on the host for EVERY
    batch — enough to lose the device win CSE buys at small dims. This cache
    persists whole ``CompiledPlan`` artifacts across ``compile_batch`` calls,
    at two levels (both bounded LRU, one lock):

    * **exact** — keyed by the submission-order tuple of full query keys
      (plus the compile config). A hit skips everything: no canonicalize
      sort, no IR rebuild, no bind gathers — one dict lookup returns the
      previously compiled plan verbatim (same ``order``, so every downstream
      permutation is valid too).
    * **canonical** — keyed by the canonically sorted key tuple. A batch
      that is a permutation of a seen one hits here after paying only the
      canonicalize sort; the cached plan is reused with the new ``order``
      (everything else in a ``CompiledPlan`` is canonical-order data, so the
      arrays are shared, not copied).

    ``canonicalize_calls`` counts how often the canonicalize sort actually
    ran — the regression surface for "exact hit = one dict lookup": it must
    NOT grow on exact hits. Plans are immutable-by-convention; entries are
    never invalidated (a plan depends only on the query keys and compile
    config, never on params or the KG), which is exactly why this cache
    needs no version stamp while ``MaterializedSubqueryCache`` does.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        # Exact entries are cheap aliases (they share the canonical entry's
        # arrays), so the exact level gets 4x the canonical budget: many
        # submission orders of few canonical batches is the common shape.
        self._exact: "collections.OrderedDict" = collections.OrderedDict()
        self._canon: "collections.OrderedDict" = collections.OrderedDict()
        self._lock = threading.Lock()
        self._metrics = _get_registry().group("plan_cache")
        self.hits = self._metrics.counter("hits")
        self.misses = self._metrics.counter("misses")
        self.evictions = self._metrics.counter("evictions")
        self.canonicalize_calls = self._metrics.counter("canonicalize_calls")

    def _put(self, d, key, value, cap) -> None:
        d[key] = value
        d.move_to_end(key)
        while len(d) > cap:
            d.popitem(last=False)
            self.evictions += 1

    # ``compile_batch`` drives the two-level probe: ``get_exact`` counts only
    # hits (an exact miss falls through to the canonical probe, which settles
    # the lookup as hit or miss), and the canonicalize counter bumps exactly
    # when the sort ran — i.e. on every path past the exact level.
    def get_exact(self, key) -> Optional[CompiledPlan]:
        with self._lock:
            plan = self._exact.get(key)
            if plan is not None:
                self._exact.move_to_end(key)
                self.hits += 1
            return plan

    def get_canonical(self, key) -> Optional[CompiledPlan]:
        with self._lock:
            self.canonicalize_calls += 1
            plan = self._canon.get(key)
            if plan is not None:
                self._canon.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
            return plan

    def put_exact(self, key, plan: CompiledPlan) -> CompiledPlan:
        with self._lock:
            self._put(self._exact, key, plan, 4 * self.capacity)
        return plan

    def put(self, exact_key, canon_key, plan: CompiledPlan) -> CompiledPlan:
        with self._lock:
            self._put(self._canon, canon_key, plan, self.capacity)
            self._put(self._exact, exact_key, plan, 4 * self.capacity)
        return plan

    def __len__(self) -> int:
        return len(self._canon)

    @property
    def hit_rate(self) -> float:
        n = int(self.hits) + int(self.misses)
        return int(self.hits) / n if n else 0.0

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {
                "name": "plan",
                "size": len(self._canon),
                "exact_size": len(self._exact),
                "capacity": self.capacity,
                "hits": int(self.hits),
                "misses": int(self.misses),
                "evictions": int(self.evictions),
                "hit_rate": self.hit_rate,
                "canonicalize_calls": int(self.canonicalize_calls),
            }

    def reset_counters(self) -> None:
        with self._lock:
            self._metrics.reset()

    def clear(self) -> None:
        with self._lock:
            self._exact.clear()
            self._canon.clear()


def compile_batch(
    queries: Sequence[QueryInstance],
    *,
    model_name: str,
    b_max: int = 512,
    reuse_slots: bool = True,
    policy: str = "max_fillness",
    cse: bool = True,
    sched_cache=None,
    plan_cache: Optional[PlanCache] = None,
    tile_policy=None,
    graph_version: int = -1,
) -> CompiledPlan:
    """Compile one query batch into a ``CompiledPlan``.

    ``sched_cache`` (a ``CompileCache``) memoizes the expensive half —
    Algorithm-1 scheduling, slot-array padding and the bind index plan — by
    ``structure_key``; a hit leaves only the two bind gathers per batch.
    ``plan_cache`` (a ``PlanCache``) sits in front of ALL of that: a batch
    whose exact query-key tuple was compiled before returns its plan with
    zero host work beyond building the key tuple.

    ``tile_policy`` (``autotune.PoolTilePolicy`` or None) switches pool
    padding to the kernel-aware rule (see ``scheduler.bucket_size``). Its
    ``key()`` is folded into BOTH cache keys — two executors holding
    different tunings can never alias a schedule, so the signature universe
    stays closed per policy and steady-state retraces stay at zero.

    ``graph_version`` (the KG's monotonic write counter; -1 = not pinned)
    enters ``cfg_key`` — the PLAN-cache key only, never the schedule-cache
    ``key`` below — so a version-pinned query can never replay a plan
    admitted under a different graph state, while schedules (pure topology,
    graph-independent) still hit across writes and device retraces stay at
    zero through a write burst."""
    tile_key = tile_policy.key() if tile_policy is not None else ()
    cfg_key = (model_name, b_max, reuse_slots, policy, cse, tile_key,
               graph_version)
    exact_key = None
    if plan_cache is not None:
        exact_key = (tuple(q.key() for q in queries), cfg_key)
        plan = plan_cache.get_exact(exact_key)
        if plan is not None:
            return plan
    order = np.asarray(
        sorted(range(len(queries)), key=lambda i: queries[i].key()),
        dtype=np.int64)
    qs = [queries[i] for i in order]
    canon_key = None
    if plan_cache is not None:
        canon_key = (tuple(q.key() for q in qs), cfg_key)
        skel = plan_cache.get_canonical(canon_key)
        if skel is not None:
            plan = (skel if np.array_equal(skel.order, order)
                    else dataclasses.replace(skel, order=order))
            return plan_cache.put_exact(exact_key, plan)

    if cse:
        plan = build_plan(qs)
        n = plan.n_nodes
        # Bind sources come straight off the IR; the full scheduler DAG
        # (inputs lists, consumer counts) is only lowered on a cache MISS —
        # the steady-state scheduler-thread path is hash-consing + two
        # array fills + the bind gathers.
        rel = np.fromiter((nd.rel for nd in plan.nodes), np.int64, count=n)
        anchor = np.fromiter((nd.anchor for nd in plan.nodes), np.int64,
                             count=n)
        patterns = list(plan.patterns)
        report = SharingReport(nodes_before=plan.nodes_before,
                               nodes_after=n)
        key = ("cse",) + plan.topology_key() + (b_max, reuse_slots, policy,
                                                tile_key)
        lower = lambda: plan_to_dag(plan)  # noqa: E731
    else:
        dag = build_batched_dag(qs)
        rel, anchor, patterns = dag.rel, dag.anchor, dag.patterns
        report = SharingReport(nodes_before=dag.n_nodes,
                               nodes_after=dag.n_nodes)
        key = dag.structure_key() + (b_max, reuse_slots, policy, tile_key)
        lower = lambda: dag  # noqa: E731

    cached = sched_cache.get(key) if sched_cache is not None else None
    if cached is None:
        sched = schedule(lower(), b_max=b_max, reuse_slots=reuse_slots,
                         policy=policy, tile_policy=tile_policy)
        trash = sched.padded_slots
        meta = tuple(s.signature() for s in sched.steps)
        slot_arrays = [
            {
                "in_slots": _pad2(s.in_slots, s.padded_n, 0),
                "out_slots": _pad1(s.out_slots, s.padded_n, trash),
            }
            for s in sched.steps
        ]
        cached = (sched, meta, slot_arrays, trash, _BindPlan(sched))
        if sched_cache is not None:
            sched_cache.put(key, cached)
    sched, meta, slot_arrays, trash, bind_plan = cached

    out = CompiledPlan(
        signature=sched.signature() + (model_name,),
        structure_key=key,
        meta=meta,
        slot_arrays=slot_arrays,
        bind_arrays=bind_plan.bind(rel, anchor),
        answer_slots=sched.answer_slots,
        n_slots_padded=trash,
        sched=sched,
        patterns=patterns,
        order=order,
        report=report,
    )
    if plan_cache is not None:
        plan_cache.put(exact_key, canon_key, out)
    return out
