"""Atomic operator vocabulary of the QueryDAG (paper §4.1)."""
from __future__ import annotations

import enum


class OpType(enum.IntEnum):
    """Atomic logical operators. The scheduler pools nodes by this type
    (plus input cardinality for the set ops, Eq. 8)."""

    EMBED = 0      # anchor entity -> initial state ("EmbedE" in Table 6)
    PROJECT = 1    # relational projection state x relation -> state
    INTERSECT = 2  # variable-cardinality set intersection
    UNION = 3      # variable-cardinality set union
    NEGATE = 4     # complement

    @property
    def has_relation(self) -> bool:
        return self is OpType.PROJECT

    @property
    def variadic(self) -> bool:
        return self in (OpType.INTERSECT, OpType.UNION)


# Types whose pooled kernels share parameters across every instance in a pool
# (theta_{tau*} in Eq. 5).
POOLED_TYPES = tuple(OpType)
