"""Max-Fillness dynamic scheduler + eager-refcount slot allocation.

This is the paper's Algorithm 1 run AHEAD of device execution (the TPU/XLA
adaptation documented in DESIGN.md §3): the ready-set loop, the Max-Fillness
pool selection (Eq. 4), the cardinality equivalence classes (Eq. 8), and the
eager reference-counting reclamation rule (Eq. 7) all execute verbatim — but
their *output* is a static ``ExecutionSchedule`` whose pooled steps are then
traced into a single jit program. Eq. 7 therefore becomes compile-time slot
liveness: a reclaimed tensor's workspace slot is pushed onto a free list and
reused by a later node, so peak-slot-count == the paper's peak memory.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ops import OpType
from repro.core.querydag import BatchedDAG

# Pool key: (op_type, input_cardinality). Cardinality is the Eq. 8
# equivalence class; it is 0 for EMBED, 1 for PROJECT/NEGATE.
PoolKey = Tuple[int, int]


def bucket_size(n: int, b_max: int, tile: int = 1) -> int:
    """Pad pool sizes to powers of two (capped at b_max) so the set of
    schedule signatures — and hence XLA recompiles — stays bounded. The cap
    applies to the PADDED size too: with a non-pow2 b_max, a pool of n ≤
    b_max rows whose next power of two exceeds b_max pads to b_max exactly
    (padded_n ≥ n always holds because the scheduler never forms a pool
    larger than b_max).

    ``tile > 1`` is the kernel-aware rule (DESIGN.md §Autotuner): pad to the
    smallest multiple of the tuned row tile instead of the bare power of
    two. The tile is clamped to the pow2 bucket first, so the kernel-aware
    pad NEVER exceeds the pow2 pad (often it's smaller — n=288 with a
    128-row tile pads to 384, not 512) while the padded size stays
    launch-aligned for the kernel that will consume the pool. Signatures
    stay bounded: padded sizes live on the (finite) multiples-of-tile
    ladder up to b_max, and the tile policy is part of every schedule cache
    key."""
    if n >= b_max:
        return b_max
    p = 1
    while p < n:
        p <<= 1
    p = min(p, b_max)
    if tile <= 1:
        return p
    t = min(int(tile), p)
    return min(-(-n // t) * t, b_max)


@dataclasses.dataclass
class PoolStep:
    """One fused kernel invocation: every node in the step is the same
    operator type and cardinality, drawn from arbitrary queries."""

    op: OpType
    cardinality: int
    node_ids: np.ndarray      # [n]
    in_slots: np.ndarray      # [n, cardinality] workspace rows to gather
    out_slots: np.ndarray     # [n] workspace rows to scatter
    rel_ids: np.ndarray       # [n] (PROJECT only, else zeros)
    anchor_ids: np.ndarray    # [n] (EMBED only, else zeros)
    padded_n: int             # bucketed size >= n

    @property
    def n(self) -> int:
        return len(self.node_ids)

    def signature(self) -> Tuple[int, int, int]:
        return (int(self.op), self.cardinality, self.padded_n)


@dataclasses.dataclass
class ExecutionSchedule:
    steps: List[PoolStep]
    n_slots: int              # peak workspace rows (refcount-reused)
    answer_slots: np.ndarray  # [n_queries]
    n_nodes: int              # without slot reuse the workspace would be this

    def signature(self) -> Tuple:
        return tuple(s.signature() for s in self.steps) + (self.padded_slots,)

    @property
    def padded_slots(self) -> int:
        return bucket_size(self.n_slots, 1 << 30)

    @property
    def stats(self) -> Dict[str, float]:
        ns = [s.n for s in self.steps]
        return {
            "steps": len(self.steps),
            "nodes": self.n_nodes,
            "peak_slots": self.n_slots,
            "slot_reuse_ratio": self.n_nodes / max(self.n_slots, 1),
            "mean_pool_fill": float(np.mean(ns)) if ns else 0.0,
            "pad_waste": 1.0 - sum(ns) / max(sum(s.padded_n for s in self.steps), 1),
        }


class _SlotAllocator:
    """Free-list allocator implementing Eq. 7 as liveness analysis."""

    def __init__(self) -> None:
        self._free: List[int] = []
        self._next = 0
        self.peak = 0

    def alloc(self) -> int:
        if self._free:
            return heapq.heappop(self._free)
        s = self._next
        self._next += 1
        self.peak = self._next
        return s

    def release(self, slot: int) -> None:
        heapq.heappush(self._free, slot)


def schedule(
    dag: BatchedDAG,
    b_max: int = 512,
    reuse_slots: bool = True,
    policy: str = "max_fillness",
    tile_policy=None,
) -> ExecutionSchedule:
    """Algorithm 1. ``policy`` ∈ {max_fillness, fifo} — fifo is the ablation
    baseline (executes pools in discovery order regardless of fill).

    ``tile_policy`` (duck-typed: ``.tile(op, cardinality, n) -> int``, e.g.
    ``autotune.PoolTilePolicy``) makes pool padding kernel-aware — each
    pool pads to the smallest multiple of the tuned row tile for its
    (op, cardinality) class instead of the bare power of two. ``None``
    keeps pow2 padding (tile 1)."""
    n = dag.n_nodes
    indeg = np.array([len(inp) for inp in dag.inputs], dtype=np.int64)
    refcount = dag.n_consumers.copy()
    consumers: List[List[int]] = [[] for _ in range(n)]
    for i, inp in enumerate(dag.inputs):
        for j in inp:
            consumers[j].append(i)

    pools: Dict[PoolKey, List[int]] = {}
    order_hint: Dict[PoolKey, int] = {}

    def push(v: int) -> None:
        key = (int(dag.op[v]), len(dag.inputs[v]))
        pools.setdefault(key, []).append(v)
        order_hint.setdefault(key, len(order_hint))

    for v in np.nonzero(indeg == 0)[0]:
        push(int(v))

    alloc = _SlotAllocator()
    slot_of = np.full(n, -1, dtype=np.int64)
    steps: List[PoolStep] = []

    while pools:
        if policy == "max_fillness":
            # Eq. 4: rho(tau) = |pool| / B_max; argmax with stable tie-break.
            key = max(pools, key=lambda k: (min(len(pools[k]), b_max), -order_hint[k]))
        else:  # fifo ablation
            key = min(pools, key=lambda k: order_hint[k])
        nodes = pools[key]
        batch = nodes[:b_max]
        rest = nodes[b_max:]
        if rest:
            pools[key] = rest
        else:
            del pools[key]

        op = OpType(key[0])
        card = key[1]
        batch_arr = np.asarray(batch, dtype=np.int64)
        in_slots = np.zeros((len(batch), max(card, 1)), dtype=np.int64)
        for bi, v in enumerate(batch):
            for ci, j in enumerate(dag.inputs[v]):
                in_slots[bi, ci] = slot_of[j]
        out_slots = np.array([alloc.alloc() for _ in batch], dtype=np.int64)
        slot_of[batch_arr] = out_slots

        steps.append(
            PoolStep(
                op=op,
                cardinality=card,
                node_ids=batch_arr,
                in_slots=in_slots if card > 0 else np.zeros((len(batch), 1), np.int64),
                out_slots=out_slots,
                rel_ids=np.where(dag.rel[batch_arr] >= 0, dag.rel[batch_arr], 0),
                anchor_ids=np.where(dag.anchor[batch_arr] >= 0, dag.anchor[batch_arr], 0),
                padded_n=bucket_size(
                    len(batch), b_max,
                    tile_policy.tile(int(op), card, len(batch))
                    if tile_policy is not None else 1),
            )
        )

        # Eager reclamation (Eq. 7) + ready-set update (Alg. 1 lines 11-19).
        for v in batch:
            for j in dag.inputs[v]:
                refcount[j] -= 1
                if refcount[j] == 0 and reuse_slots:
                    alloc.release(int(slot_of[j]))
            for c in consumers[v]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    push(c)

    return ExecutionSchedule(
        steps=steps,
        n_slots=alloc.peak,
        answer_slots=slot_of[dag.answer_node].copy(),
        n_nodes=n,
    )
