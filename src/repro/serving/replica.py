"""Replica lifecycle for the multi-replica serving tier (DESIGN.md
§ServingTier).

A :class:`Replica` is one complete serving stack — its own
``PooledExecutor`` (schedule/encode/jit caches + plan cache), its own
optional ``MaterializedSubqueryCache``, and its own ``ServingEngine`` with
a dedicated batcher thread — over a SHARED read-only model/params. The
whole point of replication here is cache partitioning: schedules, plan
entries, materialized rows and jit programs are all topology-keyed, so a
router that sends each topology to one replica gives every replica a
working set that FITS its caches, where a single engine with the same
per-replica budget would thrash.

The :class:`ReplicaPool` owns N replicas plus a ``membership_token`` the
router uses to invalidate its rendezvous memos on join/leave, and fans
``update_params`` out to every replica — each engine pins in-flight
requests to their admitted params version (``pin_params_on_admit``), so
the swap is bit-safe without draining the pool.

Replicas are dense-params only: the out-of-core ``sem_cache`` hot set is a
single shared device buffer that admitted-params replay cannot coexist
with (the engine rejects the combination), and live-graph attachment
(``kg=``) uses the same version axis — both stay on the single-engine
path.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from repro.core.executor import PooledExecutor
from repro.core.matcache import MaterializedSubqueryCache
from repro.serving.engine import ServingConfig, ServingEngine


class Replica:
    """One serving replica: engine + private executor/cache stack."""

    def __init__(self, rid: int, model, params,
                 cfg: Optional[ServingConfig] = None,
                 mat_budget_rows: int = 0, b_max: int = 256, ctx=None,
                 plan_cache_size: int = 512, started: bool = True):
        self.rid = int(rid)
        cfg = cfg or ServingConfig()
        # The swap contract is per-replica: requests complete on the params
        # they were admitted under even while the pool swaps underneath.
        cfg = ServingConfig(**{**cfg.__dict__, "pin_params_on_admit": True})
        self.mat_cache = (MaterializedSubqueryCache(
            mat_budget_rows, name=f"replica{self.rid}")
            if mat_budget_rows > 0 else None)
        self.executor = PooledExecutor(model, b_max=b_max, ctx=ctx,
                                       plan_cache_size=plan_cache_size)
        self.engine = ServingEngine(
            model, params, executor=self.executor, cfg=cfg,
            mat_cache=self.mat_cache, started=started,
            obs_labels={"replica": str(self.rid)},
            name=f"replica {self.rid}")

    # Thin pass-throughs: the router talks to replicas, not engines.
    def submit(self, query, top_k=None, timeout=None):
        return self.engine.submit(query, top_k=top_k, timeout=timeout)

    def submit_many(self, queries, top_k=None, timeout=None):
        return self.engine.submit_many(queries, top_k=top_k, timeout=timeout)

    def queue_depth(self) -> int:
        return self.engine.queue_depth()

    def update_params(self, params) -> None:
        self.engine.update_params(params)

    def retraces(self) -> int:
        return self.engine.retraces()

    def reset_counters(self, clear_log: bool = True) -> None:
        self.engine.reset_counters(clear_log=clear_log)

    def stats(self) -> Dict:
        return self.engine.stats()

    def start(self) -> None:
        self.engine.start()

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        self.engine.close(drain=drain, timeout=timeout)


class ReplicaPool:
    """N replicas over one shared read-only model/params.

    ``membership_token`` bumps on every join/leave; the router memoizes its
    rendezvous rankings against it, so membership changes remap topologies
    (at most ~1/N of them — the rendezvous property) without any explicit
    invalidation call.
    """

    def __init__(self, model, params, n_replicas: int = 1,
                 cfg: Optional[ServingConfig] = None,
                 mat_budget_rows: int = 0, b_max: int = 256, ctx=None,
                 plan_cache_size: int = 512, started: bool = True):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self.model = model
        self.params = params
        self._cfg = cfg or ServingConfig()
        self._mat_budget_rows = mat_budget_rows
        self._b_max = b_max
        self._ctx = ctx
        self._plan_cache_size = plan_cache_size
        self._lock = threading.Lock()
        self._next_rid = 0
        self._replicas: Dict[int, Replica] = {}
        self.membership_token = 0
        for _ in range(n_replicas):
            self.add_replica(started=started)

    def _make(self, rid: int, started: bool) -> Replica:
        return Replica(rid, self.model, self.params, cfg=self._cfg,
                       mat_budget_rows=self._mat_budget_rows,
                       b_max=self._b_max, ctx=self._ctx,
                       plan_cache_size=self._plan_cache_size,
                       started=started)

    def add_replica(self, started: bool = True) -> int:
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._replicas[rid] = self._make(rid, started)
            self.membership_token += 1
        return rid

    def remove_replica(self, rid: int, drain: bool = True) -> None:
        with self._lock:
            rep = self._replicas.pop(rid)
            self.membership_token += 1
        rep.close(drain=drain)

    def replicas(self) -> Dict[int, Replica]:
        """Point-in-time member snapshot (copy — safe to iterate while
        membership changes)."""
        with self._lock:
            return dict(self._replicas)

    def __len__(self) -> int:
        with self._lock:
            return len(self._replicas)

    def update_params(self, params) -> None:
        """Hot model swap, pool-wide and without draining: each engine swaps
        under its own lock, bumps its params version and mat-cache stamp, and
        keeps serving in-flight requests on their ADMITTED params snapshot.
        New replicas added after the swap start on the new params."""
        with self._lock:
            self.params = params
            reps = list(self._replicas.values())
        for rep in reps:
            rep.update_params(params)

    def retraces(self) -> Dict[int, int]:
        return {rid: rep.retraces() for rid, rep in self.replicas().items()}

    def reset_counters(self, clear_log: bool = True) -> None:
        for rep in self.replicas().values():
            rep.reset_counters(clear_log=clear_log)

    def stats(self) -> Dict:
        per = {rid: rep.stats() for rid, rep in self.replicas().items()}
        return {
            "replicas": len(per),
            "membership_token": self.membership_token,
            "per_replica": per,
            "submitted": sum(s["submitted"] for s in per.values()),
            "completed": sum(s["completed"] for s in per.values()),
            "failures": sum(s["failures"] for s in per.values()),
        }

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        for rep in self.replicas().values():
            rep.close(drain=drain, timeout=timeout)

    def __enter__(self) -> "ReplicaPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
