"""Continuous-batching serving engine (DESIGN.md §Serving).

The ROADMAP's north star is serving heavy traffic, and the paper's core
claim — operator-level dataflow execution sustains high utilization across
diverse logical patterns — applies to inference exactly as to training:
requests arrive one at a time with arbitrary patterns, and the engine's job
is to coalesce them into the same pooled micro-batches the trainer runs.

Pieces:

* **Bounded admission queue** — ``submit`` enqueues a request and returns a
  ``concurrent.futures.Future``; a full queue blocks the caller (or raises
  ``queue.Full`` with a timeout), which is the backpressure contract: load
  beyond capacity queues at the CLIENT, not in unbounded engine memory.
* **Batcher thread** — drains the queue into operator-level micro-batches
  with a size/age flush policy: flush as soon as ``max_batch`` requests are
  pending, or when the oldest pending request has waited ``max_wait_ms``.
  One batcher thread by design (mirrors the pipeline's single scheduler
  thread): it owns the params handle, so semantic-cache staging — the same
  ``plan``/``apply_to`` handshake ``data/pipeline.py`` uses for training —
  needs no cross-thread sequencing.
* **Cross-request sharing** — exact-duplicate in-flight requests (same
  ``QueryInstance.key()``) coalesce onto ONE computed row before the batch
  is padded (``coalesced`` counter in ``stats()``), and the executor's plan
  compiler (DESIGN.md §Compiler) CSE-merges identical *subtrees* of the
  distinct queries that remain — duplicate subqueries across concurrent
  requests are computed once per micro-batch. With a ``mat_cache``
  (``core/matcache.py``) the reuse goes CROSS-batch: the batcher consults
  the materialized-row cache before padding, encodes only the misses, and
  duplicate-heavy traffic serves repeat queries off cached rows (version-
  stamped, invalidated on ``update_params`` and KG writes).
* **Signature-bucketed padding** — micro-batches pad to the next power-of-
  two size by repeating the last query (padded rows are computed and
  discarded). Bounding the batch-size set bounds the jit signature set: the
  all-entity scorer sees only pow2 ``B``s, and the executor's per-signature
  compiled encode programs (``PooledExecutor.encode_fn_compiled``) stay hot,
  so a replayed workload runs at ZERO steady-state retraces.
* **Chunked all-entity scoring** — with a semantic store the engine scores
  through ``score_all_chunked`` (streams H_sem from the mmap store in
  bounded chunks; the full ``[E, d_l]`` table never materializes); dense
  mode scores through one process-wide cached jit per model (``scorer_for``
  — also the fix for ``serve_batch`` retracing ``score_all`` per call).
* **Per-request latency accounting** — each future's result carries its
  end-to-end latency; ``stats()`` aggregates p50/p95/p99 over a bounded
  window of completed requests.

Offline/online parity: the engine and the one-shot ``launch/serve.py::
serve_batch`` baseline share the SAME compiled encode programs, the SAME
cached scorer and the SAME ``topk_desc`` — so on identical micro-batch
compositions their per-request top-k is bit-identical, which
``benchmarks/serving.py`` asserts under load.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.compile_cache import CompileCache
from repro.core.executor import PooledExecutor
from repro.core.patterns import QueryInstance
from repro.obs.registry import get_registry
from repro.obs.trace import TRACER


class StaleVersionError(RuntimeError):
    """A version-pinned request fell outside the engine's staleness bound.

    Raised synchronously by ``submit`` when the pin is already out of bound
    (or no longer retained) at admission, and set on the future when writes
    land while the request is queued. Typed so clients can distinguish
    load-shedding from real failures and re-submit unpinned (or re-pin to
    ``engine.graph_version``)."""

    def __init__(self, pinned: int, current: int, bound: int):
        super().__init__(
            f"graph version {pinned} is stale: current {current}, "
            f"max_staleness_versions {bound}")
        self.pinned = pinned
        self.current = current
        self.bound = bound


def topk_desc(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest entries per row, descending — argpartition
    (linear in E) followed by an O(k log k) sort of just the survivors."""
    k = min(k, scores.shape[1])
    part = np.argpartition(-scores, k - 1, axis=1)[:, :k]
    part_scores = np.take_along_axis(scores, part, axis=1)
    order = np.argsort(-part_scores, axis=1, kind="stable")
    return np.take_along_axis(part, order, axis=1)


# --------------------------------------------------------------------------
# Process-wide scorer cache (the serve_batch re-jit fix)
# --------------------------------------------------------------------------

class CachedScorer:
    """One jitted ``model.score_all`` with a host-side trace counter.

    The counter bumps only while jax is TRACING the body, so ``traces`` is
    exactly the number of compilations — the regression surface for the old
    ``serve_batch`` bug (``jax.jit(model.score_all)`` rebuilt per call, so
    every batch retraced)."""

    def __init__(self, model, ctx=None):
        self._counter = {"traces": 0}
        counter = self._counter

        def _score(params, q):
            counter["traces"] += 1  # runs at trace time only
            return model.score_all(params, q)

        kwargs = ctx.replicated_out_kwargs() if ctx is not None else {}
        self._fn = jax.jit(_score, **kwargs)

    def __call__(self, params, q):
        return self._fn(params, q)

    @property
    def traces(self) -> int:
        return self._counter["traces"]


_SCORER_CACHE = CompileCache(32, name="score_all_jit")
_SCORER_LOCK = threading.Lock()


def scorer_for(model, ctx=None) -> CachedScorer:
    """Process-wide cached jit of ``model.score_all``.

    Keyed by everything ``score_all`` actually closes over — model class,
    config and entity count (plus the mesh layout when sharded) — so two
    instances of the same zoo family share one compiled program, and
    repeated ``serve_batch`` calls trace exactly once per scorer shape."""
    key = (type(model).__name__, model.cfg,
           getattr(model, "n_entities", None),
           ctx.describe() if ctx is not None and ctx.is_sharded else None)
    with _SCORER_LOCK:
        s = _SCORER_CACHE.get(key)
        if s is None:
            s = _SCORER_CACHE.put(key, CachedScorer(model, ctx))
    return s


def pad_to_bucket(queries: Sequence[QueryInstance]):
    """Pad a micro-batch to the next power-of-two length by repeating the
    last query. Real rows are untouched (pattern-sorted canonicalization and
    pool padding happen downstream in ``prepare`` regardless); the duplicate
    rows are scored and dropped. Returns ``(padded, n_real)``."""
    n = len(queries)
    if n == 0:
        return [], 0
    b = 1 << (n - 1).bit_length()
    return list(queries) + [queries[-1]] * (b - n), n


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServingConfig:
    max_batch: int = 16        # size-triggered flush threshold
    max_wait_ms: float = 5.0   # age-triggered flush: oldest pending request
    queue_depth: int = 256     # bounded admission queue (backpressure)
    top_k: int = 10
    bucket: bool = True        # signature-bucketed (pow2) batch padding
    record_batches: bool = False  # keep a log of (padded batch, results)
    latency_window: int = 8192    # completed-request latencies retained
    # Staleness-bounded serving (DESIGN.md §LiveStore; needs ``kg=``): a
    # version-pinned request is served from its pinned snapshot's params as
    # long as the live graph is at most this many versions ahead; beyond
    # the bound it is SHED with a typed StaleVersionError instead of being
    # silently served stale rows. 0 = pinned requests only survive until
    # the next write.
    max_staleness_versions: int = 0
    # Hot-swap semantics (DESIGN.md §ServingTier): when True, every request
    # is stamped with the params version current at ADMISSION and served on
    # exactly those params even if ``update_params`` lands while it queues —
    # the replica-tier swap contract (in-flight requests complete on the
    # params they were admitted under). Off by default: the single-engine
    # path serves whatever params are current at execute time, unchanged.
    pin_params_on_admit: bool = False


@dataclasses.dataclass
class _Request:
    query: QueryInstance
    top_k: int
    future: Future
    t_submit: float
    # Async-span id threaded submit -> flush -> dispatch -> complete. 0 when
    # tracing is off. Coalesced duplicates keep DISTINCT ids (each opened at
    # its own submit) while sharing one batch/encode/score span.
    trace_id: int = 0
    # Pinned graph version (None = serve at whatever version is current at
    # execute time). Pinned requests are grouped per version by the batcher
    # and served from that version's retained params snapshot.
    pin_version: Optional[int] = None
    # Params version current at admission (``pin_params_on_admit`` only;
    # stays 0 otherwise). Batches group per version so a swap landing
    # mid-queue never mixes old- and new-params rows in one micro-batch.
    params_version: int = 0


@dataclasses.dataclass
class BatchRecord:
    """One executed micro-batch, for offline-oracle replay: the exact padded
    composition the engine ran (duplicate in-flight requests coalesce to one
    computed row first, so ``queries`` holds UNIQUE real rows), plus one
    result per computed real row, in first-submission order. Each logged
    row records the selection at the engine's default ``top_k`` whenever any
    request for that row used it (so fixed-k oracle replay compares
    row-for-row); rows requested ONLY at custom k carry that k."""

    queries: List[QueryInstance]   # padded unique composition as executed
    n_real: int                    # unique real rows (pre-padding)
    flush: str                     # size | age | drain
    results: List[Dict]            # one per real row


class ServingEngine:
    """Async continuous-batching NGDB query service.

    ``submit`` is thread-safe and returns a future; a single batcher thread
    coalesces pending requests into pooled micro-batches and resolves the
    futures. ``sem_cache``/``sem_rows_fn`` switch on out-of-core serving:
    anchor rows are staged into the device hot set on the batcher thread
    before encode, and all-entity scoring streams H_sem via ``sem_rows_fn``
    (e.g. ``SemanticStore.read_rows``) instead of a full-resident table.
    """

    def __init__(self, model, params, executor=None,
                 cfg: Optional[ServingConfig] = None, sem_cache=None,
                 sem_rows_fn=None, ctx=None, started: bool = True,
                 mat_cache=None, latency_window: Optional[int] = None,
                 kg=None, obs_labels: Optional[Dict[str, str]] = None,
                 name: Optional[str] = None):
        self.model = model
        self.params = params
        # ``name`` labels the batcher thread and its tracer lane (replicas
        # pass "replica 0" etc. so lanes stay distinguishable); ``obs_labels``
        # labels every registry metric this engine publishes (e.g.
        # replica="0"). Both default to the historical unlabeled identity.
        self.name = name or "serving"
        self.cfg = cfg or ServingConfig()
        if latency_window is not None:
            # Constructor-level override so callers that never build a
            # ServingConfig can still size the percentile window.
            self.cfg = dataclasses.replace(self.cfg,
                                           latency_window=latency_window)
        if self.cfg.max_batch < 1 or self.cfg.queue_depth < 1:
            raise ValueError("max_batch and queue_depth must be >= 1")
        if self.cfg.latency_window < 1:
            raise ValueError("latency_window must be >= 1")
        self.ctx = ctx
        self.executor = executor or PooledExecutor(model, b_max=256, ctx=ctx)
        if sem_cache is not None and sem_rows_fn is None:
            raise ValueError(
                "out-of-core serving needs sem_rows_fn (e.g. store.read_rows)"
                " to stream H_sem for all-entity scoring")
        self.sem_cache = sem_cache
        self.sem_rows_fn = sem_rows_fn
        # Materialized-subquery cache (core/matcache.py): the batcher
        # consults it BEFORE padding, so a duplicate-of-an-earlier-batch
        # request costs one host row copy instead of a device encode. The
        # engine owns the consult/insert; leave the executor's own
        # ``mat_cache`` unset here or every miss would be double-counted.
        self.mat_cache = mat_cache
        if (mat_cache is not None
                and getattr(self.executor, "mat_cache", None) is not None):
            raise ValueError(
                "pass mat_cache to the engine OR the executor, not both")
        # Live-graph attachment (DESIGN.md §LiveStore): with ``kg`` set the
        # engine tracks the graph's monotonic ``graph_version``, retains the
        # params active at each recent version, and enforces the
        # ``max_staleness_versions`` admission bound for pinned requests.
        # The write listener is held WEAKLY by the KG, so a discarded engine
        # is collected. Out-of-core sem staging mutates a device hot set
        # shared across params snapshots, which version-pinned replay cannot
        # coexist with — explicitly unsupported rather than silently wrong.
        if kg is not None and sem_cache is not None:
            raise ValueError(
                "staleness-bounded serving (kg=...) does not support the "
                "out-of-core sem_cache hot set yet — pass one or the other")
        if self.cfg.max_staleness_versions < 0:
            raise ValueError("max_staleness_versions must be >= 0")
        self.kg = kg
        self._graph_version = kg.graph_version if kg is not None else -1
        self._version_retention = max(self.cfg.max_staleness_versions + 1, 4)
        self._version_params: Dict[int, object] = (
            {self._graph_version: params} if kg is not None else {})
        if kg is not None:
            kg.add_invalidation_listener(self._on_kg_write)
        # Params-version pinning (replica-tier hot swap). Mutually exclusive
        # with the graph-version machinery (one version axis per engine; the
        # replica tier is dense-params) and with sem staging (the device hot
        # set is shared across params snapshots, so admitted-params replay
        # cannot coexist with it) — explicit rather than silently wrong.
        if self.cfg.pin_params_on_admit and (kg is not None
                                             or sem_cache is not None):
            raise ValueError(
                "pin_params_on_admit does not compose with kg= or sem_cache=")
        self._params_version = 0
        self._params_retention = 4
        self._params_by_version: Dict[int, object] = (
            {0: params} if self.cfg.pin_params_on_admit else {})
        self._scorer = scorer_for(model, ctx)
        self._scorer_traces0 = self._scorer.traces
        self._sharing0 = dict(self.executor.sharing_stats())
        self._q: "queue.Queue[_Request]" = queue.Queue(maxsize=self.cfg.queue_depth)
        # Unpack buffer for grouped admissions (``submit_many`` enqueues a
        # whole batch as ONE queue entry); owned by the batcher thread.
        self._pending: "deque[_Request]" = deque()
        self._stop = threading.Event()
        self._closed = False
        self._lock = threading.Lock()
        # Registry metrics (DESIGN.md §Observability): same counters the
        # engine always kept, now visible in process-wide snapshots. The
        # latency ring buffer is a Histogram whose window IS
        # cfg.latency_window, reported as window_n in stats().
        self._metrics = get_registry().group("serving", **(obs_labels or {}))
        self._latency = self._metrics.histogram(
            "latency_ms", window=self.cfg.latency_window)
        self._submitted = self._metrics.counter("submitted")
        self._completed = self._metrics.counter("completed")
        self._batches = self._metrics.counter("batches")
        self._batch_rows = self._metrics.counter("batch_rows")
        self._padded_rows = self._metrics.counter("padded_rows")
        self._coalesced = self._metrics.counter("coalesced")
        self._failures = self._metrics.counter("failures")
        self._flushes = {k: self._metrics.counter("flushes", kind=k)
                         for k in ("size", "age", "drain")}
        self._queue_depth = self._metrics.gauge("queue_depth")
        self._occupancy = self._metrics.gauge("batch_occupancy")
        # §LiveStore counters: requests shed for staleness (typed error, NOT
        # failures) and per-version-lag served counts (lag 0 = current).
        self._stale_sheds = self._metrics.counter("stale_sheds")
        self._version_served: Dict[int, object] = {}
        self._graph_version_gauge = self._metrics.gauge("graph_version")
        self._graph_version_gauge.set(self._graph_version)
        # After a registry-wide reset() the derived deltas (scorer traces,
        # sharing) must re-baseline or they would go negative; the hook is
        # held weakly, so a collected engine takes it along.
        get_registry().on_reset(self._rebaseline)
        self.batch_log: List[BatchRecord] = []
        self._thread: Optional[threading.Thread] = None
        if started:
            self.start()

    def _rebaseline(self) -> None:
        """Registry-reset hook: zero the derived deltas that live outside
        the registry (jit-trace counts, cumulative sharing totals)."""
        self._scorer_traces0 = self._scorer.traces
        self._sharing0 = dict(self.executor.sharing_stats())
        with self._lock:
            for k in list(self._flushes):
                if k not in ("size", "age", "drain"):
                    del self._flushes[k]
            self._version_served = {}

    def _on_kg_write(self, reason: str) -> None:
        """KG write listener (weakly held by the graph): advance the tracked
        graph version and retain the CURRENT params under the new version —
        until incremental maintenance publishes fine-tuned params via
        ``update_params``, the new version serves with the old weights (the
        staleness bound is about ROW consistency, which the version-keyed
        caches own). Old versions age out of retention; a request pinned to
        an evicted version is shed."""
        with self._lock:
            if self.kg is None:
                return
            self._graph_version = self.kg.graph_version
            self._version_params[self._graph_version] = self.params
            while len(self._version_params) > self._version_retention:
                del self._version_params[min(self._version_params)]
        self._graph_version_gauge.set(self._graph_version)

    @property
    def graph_version(self) -> int:
        """The newest graph version this engine has observed (-1 when no
        ``kg`` is attached)."""
        with self._lock:
            return self._graph_version

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"{self.name}-batcher")
        self._thread.start()

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop accepting requests; by default serve everything already
        admitted (the batcher flushes the tail immediately once the queue
        is empty), then join the batcher thread."""
        with self._lock:
            self._closed = True
        if drain and self._thread is not None and self._thread.is_alive():
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                with self._lock:
                    if self._completed >= self._submitted:
                        break
                time.sleep(0.005)
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        # Anything still queued (drain=False or timeout) fails loudly rather
        # than leaving callers blocked on forever-pending futures.
        self._fail_queued()

    def _fail_queued(self) -> None:
        try:
            while True:
                entry = self._q.get_nowait()
                for r in (entry if type(entry) is list else (entry,)):
                    if r.trace_id:
                        TRACER.async_end("request", r.trace_id, failed=True)
                    r.future.set_exception(
                        RuntimeError("serving engine closed"))
                    with self._lock:
                        self._completed += 1
        except queue.Empty:
            pass

    def __enter__(self) -> "ServingEngine":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ admission
    def submit(self, query: QueryInstance, top_k: Optional[int] = None,
               timeout: Optional[float] = None,
               pin_version: Optional[int] = None) -> Future:
        """Admit one request. Blocks when the admission queue is full
        (bounded-memory backpressure); with ``timeout`` raises ``queue.Full``
        instead. The returned future resolves to the same result dict
        ``serve_batch`` produces, plus ``latency_ms``/``batch_size``.

        ``pin_version`` (needs ``kg=`` at construction) pins the request to
        one graph version: it is served from that version's retained params
        with version-keyed plan/materialized rows — bit-identical replay
        against the pinned snapshot — or shed with ``StaleVersionError``
        when the live graph has moved more than
        ``cfg.max_staleness_versions`` ahead (checked both here at
        admission and again at execute time, since writes can land while
        the request queues)."""
        k = self.cfg.top_k if top_k is None else top_k
        if k < 1:
            raise ValueError(f"top_k must be >= 1, got {k}")
        if pin_version is not None:
            if self.kg is None:
                raise ValueError(
                    "pin_version needs a live graph: construct the engine "
                    "with kg=...")
            with self._lock:
                cur = self._graph_version
                if pin_version < 0 or pin_version > cur:
                    raise ValueError(
                        f"unknown graph version {pin_version} (current {cur})")
                if (cur - pin_version > self.cfg.max_staleness_versions
                        or pin_version not in self._version_params):
                    self._stale_sheds += 1
                    raise StaleVersionError(pin_version, cur,
                                            self.cfg.max_staleness_versions)
        with self._lock:
            if self._closed:
                raise RuntimeError("serving engine is closed")
            self._submitted += 1
            pv = self._params_version if self.cfg.pin_params_on_admit else 0
        trace_id = 0
        if TRACER.enabled:
            trace_id = TRACER.next_id()
            TRACER.async_begin("request", trace_id, pattern=query.pattern,
                               top_k=k)
        r = _Request(query, k, Future(), time.perf_counter(), trace_id,
                     pin_version, pv)
        try:
            self._q.put(r, timeout=timeout)
        except queue.Full:
            with self._lock:
                self._submitted -= 1
            if trace_id:
                TRACER.async_end("request", trace_id, rejected=True)
            raise
        # The queue-depth gauge is refreshed by the batcher at every flush;
        # updating it per admission too would cost a qsize() mutex round
        # trip on the hot path for no extra observability.
        # close() may have stopped the batcher and drained the queue between
        # our _closed check and the put; a straggler landing in the
        # now-unwatched queue must fail, not strand its future forever.
        if self._stop.is_set():
            self._fail_queued()
        return r.future

    def submit_many(self, queries: Sequence[QueryInstance],
                    top_k: Optional[int] = None,
                    timeout: Optional[float] = None) -> List[Future]:
        """Admit a batch as ONE admission action: a single closed-check /
        counter update under the lock and a single queue entry for the whole
        group, so per-request admission costs (lock round trips, queue
        handoffs) are paid once per batch instead of once per query. The
        batcher unpacks the group in order, so batching behavior and results
        are identical to a ``submit`` loop. All requests in a group share
        one admission timestamp and params version — a group admits
        atomically with respect to hot swap. The bounded queue counts a
        group as one entry (one arrival event for backpressure purposes).
        Graph-version pinning stays on the single-request path."""
        if not queries:
            return []
        k = self.cfg.top_k if top_k is None else top_k
        if k < 1:
            raise ValueError(f"top_k must be >= 1, got {k}")
        with self._lock:
            if self._closed:
                raise RuntimeError("serving engine is closed")
            self._submitted += len(queries)
            pv = self._params_version if self.cfg.pin_params_on_admit else 0
        t0 = time.perf_counter()
        group = []
        for q in queries:
            trace_id = 0
            if TRACER.enabled:
                trace_id = TRACER.next_id()
                TRACER.async_begin("request", trace_id, pattern=q.pattern,
                                   top_k=k)
            group.append(_Request(q, k, Future(), t0, trace_id, None, pv))
        try:
            self._q.put(group, timeout=timeout)
        except queue.Full:
            with self._lock:
                self._submitted -= len(group)
            for r in group:
                if r.trace_id:
                    TRACER.async_end("request", r.trace_id, rejected=True)
            raise
        if self._stop.is_set():
            self._fail_queued()
        return [r.future for r in group]

    def queue_depth(self) -> int:
        """Entries currently waiting in the admission queue (the router's
        spill signal — approximate by nature, exact enough for load shaping).
        A grouped admission counts as one entry until the batcher unpacks
        it."""
        return self._q.qsize()

    # -------------------------------------------------------------- batcher
    def _next_request(self, timeout: Optional[float]) -> _Request:
        """Next single request for the batcher: drains the unpack buffer
        first, then the queue; a grouped entry (``submit_many``) refills the
        buffer. ``timeout=None`` means non-blocking. Raises ``queue.Empty``
        exactly like ``Queue.get`` — and only when the buffer is empty, so
        the batcher can never exit with unpacked requests stranded."""
        if self._pending:
            return self._pending.popleft()
        entry = (self._q.get_nowait() if timeout is None
                 else self._q.get(timeout=timeout))
        if type(entry) is list:
            self._pending.extend(entry)
            return self._pending.popleft()
        return entry

    def _run(self) -> None:
        TRACER.set_lane(f"{self.name} batcher")
        while True:
            try:
                first = self._next_request(0.05)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            batch = [first]
            # Age from SUBMIT time, not dequeue time: a request that sat in
            # the admission queue behind a long batch has already spent its
            # wait budget, so the latency bound covers queueing too.
            deadline = first.t_submit + self.cfg.max_wait_ms / 1e3
            flush = "size"
            while len(batch) < self.cfg.max_batch:
                try:
                    # Greedy first: coalesce everything ALREADY queued before
                    # consulting the age deadline — an expired deadline bounds
                    # additional waiting, it must not collapse a backlogged
                    # engine into size-1 batches.
                    batch.append(self._next_request(None))
                    continue
                except queue.Empty:
                    pass
                # Unlocked read: _closed is a GIL-atomic bool that only ever
                # flips False -> True; at worst this loop notices one 50 ms
                # get-timeout late, which close()'s drain wait absorbs —
                # not worth a contended lock acquisition per empty poll.
                if self._closed:
                    flush = "drain"  # tail: don't sit out the age window
                    break
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    flush = "age"
                    break
                try:
                    batch.append(self._next_request(min(remaining, 0.05)))
                except queue.Empty:
                    continue
            self._queue_depth.set(self._q.qsize())
            if TRACER.enabled:
                TRACER.counter("serving_queue_depth", depth=self._q.qsize())
            self._execute(batch, flush)

    def _execute(self, batch: List[_Request], flush: str) -> None:
        batch = self._shed_stale(batch)
        if not batch:
            return
        # Pinned requests are served per pinned version (one params snapshot
        # + one cache keyspace per micro-batch); a mixed flush splits into
        # one group per distinct pin. Unpinned requests (pin None) ride the
        # current-version group. With ``pin_params_on_admit`` the admitted
        # params version splits the same way, so a hot swap landing between
        # dequeue and execute never mixes params generations in one batch.
        groups: Dict[Tuple, List[_Request]] = {}
        for r in batch:
            groups.setdefault((r.pin_version, r.params_version),
                              []).append(r)
        if len(groups) > 1:
            for g in groups.values():
                self._execute_group(g, flush)
            return
        self._execute_group(batch, flush)

    def _shed_stale(self, batch: List[_Request]) -> List[_Request]:
        """Execute-time staleness re-check: writes that landed while a
        pinned request queued can push it out of bound. Shed requests fail
        with the typed error and count as ``stale_sheds`` — never as
        ``failures``, and never through the poison-isolation retry path
        (a shed is deterministic, a solo retry would just shed again)."""
        if self.kg is None:
            return batch
        with self._lock:
            cur = self._graph_version
            bound = self.cfg.max_staleness_versions
            keep: List[_Request] = []
            shed: List[_Request] = []
            for r in batch:
                if (r.pin_version is not None
                        and (cur - r.pin_version > bound
                             or r.pin_version not in self._version_params)):
                    shed.append(r)
                else:
                    keep.append(r)
            self._stale_sheds += len(shed)
            self._completed += len(shed)
        for r in shed:
            if r.trace_id:
                TRACER.async_end("request", r.trace_id, shed=True)
            r.future.set_exception(StaleVersionError(r.pin_version, cur, bound))
        return keep

    def _execute_group(self, batch: List[_Request], flush: str) -> None:
        # Exception, not BaseException: SystemExit/KeyboardInterrupt take
        # the batcher down rather than being swallowed into futures. Within
        # Exception, only recoverable per-request errors (e.g. malformed
        # pattern → KeyError) get poison isolation — MemoryError fails the
        # whole batch at once, never an N-fold solo-retry storm of the same
        # allocation.
        try:
            with TRACER.span("batch", n=len(batch), flush=flush,
                             trace_ids=[r.trace_id for r in batch]):
                results = self._serve(batch, flush)
        except Exception as e:
            if isinstance(e, StaleVersionError):
                # Deterministic shed (pin evicted mid-batch by a concurrent
                # write): typed error, stale_sheds accounting, and NO solo
                # retry — a retry would just shed again.
                for r in batch:
                    if r.trace_id:
                        TRACER.async_end("request", r.trace_id, shed=True)
                    r.future.set_exception(e)
                with self._lock:
                    self._stale_sheds += len(batch)
                    self._completed += len(batch)
                return
            if len(batch) > 1 and not isinstance(e, MemoryError):
                # Isolate the poison request: one malformed query must not
                # fail its co-batched neighbors. Solo retries carry their own
                # flush label so stats/batch_log report what actually ran,
                # not the original batch's trigger.
                for r in batch:
                    self._execute([r], "retry")
                return
            for r in batch:
                # End the span BEFORE resolving the future: a client that
                # snapshots the trace right after its future resolves must
                # never see a dangling request span.
                if r.trace_id:
                    TRACER.async_end("request", r.trace_id, failed=True)
                r.future.set_exception(e)
            with self._lock:
                self._failures += len(batch)
                self._completed += len(batch)
            return
        t_done = time.perf_counter()
        n = len(batch)
        lats = []
        for r, res in zip(batch, results):
            lat_ms = (t_done - r.t_submit) * 1e3
            res["latency_ms"] = lat_ms
            res["batch_size"] = n
            lats.append(lat_ms)
        # One lock acquisition covers the whole batch's bookkeeping; futures
        # resolve after it so a drain poll never sees completed > resolved-
        # or-being-resolved.
        with self._lock:
            for lat_ms in lats:
                self._latency.observe(lat_ms)
            self._completed += n
        for r, res, lat_ms in zip(batch, results, lats):
            # Span end precedes set_result: once the future resolves, the
            # trace must already contain the request's full b/e pair.
            if r.trace_id:
                TRACER.async_end("request", r.trace_id, latency_ms=lat_ms)
            r.future.set_result(res)

    def update_params(self, params) -> None:
        """Hot-swap the serving params (e.g. after an online training step
        or incremental fine-tune). The swap and the materialized-cache
        invalidation happen under ONE lock acquisition, so no batch observes
        new params with old rows: a batch that snapshotted before the swap
        keeps serving (old params, old-version rows) consistently, and its
        late inserts are dropped by the version check. With a live graph
        attached, the new params also become the CURRENT graph version's
        retained snapshot — requests pinned to older versions keep their
        original params."""
        with self._lock:
            self.params = params
            if self.kg is not None:
                self._version_params[self._graph_version] = params
            if self.cfg.pin_params_on_admit:
                # New admissions stamp the new version; requests already
                # queued keep their admitted version and are served from the
                # retained snapshot below (hot swap without draining).
                self._params_version += 1
                self._params_by_version[self._params_version] = params
                while len(self._params_by_version) > self._params_retention:
                    del self._params_by_version[min(self._params_by_version)]
            if self.mat_cache is not None:
                self.mat_cache.bump_version("param_update")

    def _states_for(self, params, uniq: List[QueryInstance],
                    padded: List[QueryInstance], n_real: int, mat_ver: int,
                    gv: int = -1, use_cache: bool = True):
        """Encoded states for the padded unique composition, serving rows
        out of the materialized cache where possible. The assembled array is
        bitwise what ``executor.encode(params, padded)`` would return —
        pooled ops are row-wise, so subset encodes reproduce full-batch rows
        exactly, cached rows were such subset rows at the same version, and
        pad rows repeat the last unique row just as ``pad_to_bucket``'s
        repeated query would — so scoring and offline-oracle replay are
        untouched by the cache.

        ``gv`` (the batch's graph version; -1 = no live graph) keys both the
        plan cache and the materialized rows: rows encoded against different
        graph snapshots can never alias, even though all pins share one
        cache ``mat_ver`` stamp (the stamp owns PARAM freshness, the key
        owns graph state)."""
        if self.mat_cache is None or not use_cache:
            # ``use_cache=False``: the batch runs on a RETAINED (pre-swap)
            # params snapshot, while the cache stamp tracks the CURRENT
            # params — neither its rows nor inserts from this batch would be
            # valid, so the old-generation tail encodes around the cache.
            return self.executor.encode(params, padded, compiled=True,
                                        graph_version=gv)
        keys = [q.key() if gv < 0 else q.key() + (gv,) for q in uniq]
        cached = self.mat_cache.lookup(keys, version=mat_ver)
        miss = [j for j in range(len(uniq)) if j not in cached]
        fresh = None
        if miss:
            sub, sub_n = [uniq[j] for j in miss], len(miss)
            if self.cfg.bucket:
                sub, sub_n = pad_to_bucket(sub)
            fresh = np.asarray(
                self.executor.encode(params, sub, compiled=True,
                                     graph_version=gv))[: len(miss)]
            self.mat_cache.insert([keys[j] for j in miss], fresh,
                                  version=mat_ver)
        dim = (fresh.shape[1] if fresh is not None
               else next(iter(cached.values())).shape[0])
        states = np.empty((len(padded), dim), dtype=np.float32)
        for j, row in cached.items():
            states[j] = row
        for i, j in enumerate(miss):
            states[j] = fresh[i]
        states[n_real:] = states[n_real - 1]
        return states

    def _serve(self, batch: List[_Request], flush: str) -> List[Dict]:
        # Exact-duplicate coalescing: in-flight requests whose query keys
        # match share ONE computed row — encode + all-entity scoring run once
        # and the result fans out to every waiting future (requests with
        # different top_k still share the row; only the cheap final selection
        # differs). Partially overlapping requests are handled one layer
        # down: the executor's plan compiler CSE-merges shared subtrees of
        # DISTINCT queries in the same micro-batch.
        row_of: List[int] = []
        uniq: List[QueryInstance] = []
        index: Dict[Tuple, int] = {}
        for r in batch:
            key = r.query.key()
            j = index.get(key)
            if j is None:
                j = index[key] = len(uniq)
                uniq.append(r.query)
            row_of.append(j)
        if self.cfg.bucket:
            padded, n_real = pad_to_bucket(uniq)
        else:
            padded, n_real = list(uniq), len(uniq)
        # Snapshot (params, cache version, graph version) together under the
        # lock: ``update_params`` swaps and bumps under the same lock, so a
        # batch can never pair new params with rows materialized under old
        # ones (or vice versa) — the staleness contract
        # tests/test_plan_cache.py pins. A pinned batch (all requests share
        # one pin after grouping) serves from the pinned version's RETAINED
        # params instead of the live handle; ``_shed_stale`` already
        # guaranteed the pin is in bound and retained.
        pin = batch[0].pin_version
        use_mat = True
        with self._lock:
            if pin is not None:
                params = self._version_params.get(pin)
                if params is None:
                    # A write on another thread evicted the pin between the
                    # shed check and this snapshot — shed, don't fail.
                    raise StaleVersionError(pin, self._graph_version,
                                            self.cfg.max_staleness_versions)
                gv = pin
            else:
                params = self.params
                gv = self._graph_version
            if self.cfg.pin_params_on_admit:
                # The swap contract: serve on the params the batch was
                # ADMITTED under (all requests share one version after
                # grouping). An aged-out snapshot falls forward to current —
                # retention bounds memory, and the window (4 swaps) dwarfs
                # any realistic queue residency.
                pv = batch[0].params_version
                if pv != self._params_version:
                    params = self._params_by_version.get(pv, params)
                    use_mat = False
            mat_ver = (self.mat_cache.version
                       if self.mat_cache is not None else -1)
            lag = self._graph_version - gv if self.kg is not None else 0
        if self.sem_cache is not None:
            # Staging folds into the batcher thread: the plan's store read +
            # device put and the apply scatter happen here, once per
            # micro-batch, before the encode that gathers the rows. Single
            # batcher thread ⇒ plan order == apply order for free. No
            # mat-cache bump: staging changes WHERE rows live, not their
            # values, so materialized rows stay valid.
            anchors = np.concatenate([q.anchors for q in padded])
            with TRACER.span("sem_prefetch", rows=len(anchors)):
                stage = self.sem_cache.plan(anchors)
            if stage is not None:
                params = self.sem_cache.apply_to(params, stage)
                self.params = params
        with TRACER.span("encode", n=len(padded), graph_version=gv):
            states = self._states_for(params, uniq, padded, n_real, mat_ver,
                                      gv, use_cache=use_mat)
        with TRACER.span("score", n=len(padded)):
            if self.sem_cache is not None:
                scores = self.model.score_all_chunked(params, states,
                                                      self.sem_rows_fn)
            else:
                scores = np.asarray(self._scorer(params, states))
        # Select per DISTINCT (row, k) group, not one k_max selection sliced
        # per request: argpartition at k_max can arrange boundary-tied ids
        # differently than argpartition at k, and the contract is exact
        # per-request equality with serve_batch(top_k=k). Mixed-k batches
        # are rare, so this is one topk_desc call in the common case.
        ks = scores.shape[1]
        with TRACER.span("select", n=len(batch)):
            sel_of: Dict[Tuple[int, int], np.ndarray] = {}
            for i, r in enumerate(batch):
                sel_of.setdefault((row_of[i], min(r.top_k, ks)), None)
            by_k: Dict[int, List[int]] = {}   # k -> unique computed rows
            for row, k in sel_of:
                by_k.setdefault(k, []).append(row)
            for k, rows in by_k.items():
                # Unique rows appear in ascending order, so the common
                # single-k group covers the contiguous prefix — slice (a
                # view) instead of fancy-indexing (a copy).
                sub = (scores[:len(rows)] if len(rows) == len(uniq)
                       else scores[rows])
                idx = topk_desc(sub, k)
                for j, row in enumerate(rows):
                    sel_of[(row, k)] = idx[j]
        results: List[Optional[Dict]] = [None] * len(batch)
        log_rows: List[Optional[Dict]] = [None] * n_real
        default_k = min(self.cfg.top_k, ks)
        # One elementwise round of the whole matrix replaces a per-request
        # round of each selected slice — identical values (round is
        # elementwise), one vectorized call instead of batch-size tiny ones.
        rounded = scores.round(3)
        for i, r in enumerate(batch):
            row = row_of[i]
            k = min(r.top_k, ks)
            sel = sel_of[(row, k)]
            results[i] = {
                "pattern": r.query.pattern,
                "anchors": r.query.anchors.tolist(),
                "relations": r.query.relations.tolist(),
                "top_entities": sel.tolist(),
                "scores": rounded[row, sel].tolist(),
            }
            # Log rows prefer the engine's default k: offline-oracle replay
            # (check_against_offline) serves rec.queries at ONE fixed k, so
            # a coalesced row whose first submitter asked a custom k must
            # not shadow a co-batched duplicate at the default.
            if log_rows[row] is None or (
                    k == default_k
                    and len(log_rows[row]["top_entities"]) != default_k):
                log_rows[row] = results[i]
        with self._lock:
            self._batches += 1
            self._batch_rows += len(padded)
            self._padded_rows += len(padded) - n_real
            self._coalesced += len(batch) - len(uniq)
            self._occupancy.set(n_real / len(padded) if padded else 0.0)
            fc = self._flushes.get(flush)
            if fc is None:
                fc = self._flushes[flush] = self._metrics.counter(
                    "flushes", kind=flush)
            fc.inc()
            if self.kg is not None:
                # Per-version-lag served accounting (lag 0 = current graph
                # version): the §LiveStore observability hook for "how stale
                # is the traffic we actually serve".
                vc = self._version_served.get(lag)
                if vc is None:
                    vc = self._version_served[lag] = self._metrics.counter(
                        "version_lag_served", lag=str(lag))
                vc += len(batch)
            if self.cfg.record_batches:
                # The log holds the UNIQUE composition as executed (one
                # result per computed row), so offline-oracle replay compares
                # row-for-row against serve_batch on the same composition.
                self.batch_log.append(BatchRecord(
                    queries=padded, n_real=n_real, flush=flush,
                    results=log_rows))
        return results

    # -------------------------------------------------------------- metrics
    def retraces(self) -> int:
        """Cold signature work since the last ``reset_counters``: executor
        cache misses (schedule/encode/encode_jit — a new signature misses
        all three, so this over-counts distinct XLA programs on purpose) +
        scorer traces. The serving steady-state claim is that a replayed
        workload keeps this at ZERO: no scheduling, closure-building or
        compile work of any kind."""
        cs = self.executor.cache_stats()
        return (sum(int(v["misses"]) for v in cs.values())
                + self._scorer.traces - self._scorer_traces0)

    def reset_counters(self, clear_log: bool = True) -> None:
        """Zero retrace/latency/flush counters (after warmup) — compiled
        programs and cache contents are kept. Scoped to THIS engine (and its
        executor/caches): submitted/completed survive so ``close``'s drain
        accounting stays truthful. ``obs.get_registry().reset()`` is the
        process-wide variant (zeroes everything at once)."""
        self.executor.reset_cache_counters()
        if self.mat_cache is not None:
            self.mat_cache.reset_counters()
        self._scorer_traces0 = self._scorer.traces
        self._sharing0 = dict(self.executor.sharing_stats())
        with self._lock:
            self._latency.reset()
            self._metrics.reset(only=[
                self._batches, self._batch_rows, self._padded_rows,
                self._coalesced, self._failures, self._stale_sheds])
            for k in list(self._flushes):
                if k in ("size", "age", "drain"):
                    self._flushes[k].reset()
                else:
                    del self._flushes[k]
            for c in self._version_served.values():
                c.reset()
            if clear_log:
                self.batch_log = []

    def stats(self) -> Dict:
        with self._lock:
            lat = np.asarray(self._latency.window_values(), dtype=np.float64)
            out = {
                "submitted": int(self._submitted),
                "completed": int(self._completed),
                "failures": int(self._failures),
                "batches": int(self._batches),
                "flushes": {k: int(c) for k, c in self._flushes.items()},
                "mean_batch_size": (int(self._batch_rows) / int(self._batches)
                                    if self._batches else 0.0),
                "padded_row_frac": (
                    int(self._padded_rows) / int(self._batch_rows)
                    if self._batch_rows else 0.0),
                # duplicate in-flight requests served off a co-batched twin's
                # computation (same QueryInstance.key())
                "coalesced": int(self._coalesced),
            }
            if self.cfg.pin_params_on_admit:
                out["params_version"] = self._params_version
            if self.kg is not None:
                out["graph_version"] = self._graph_version
                out["retained_versions"] = sorted(self._version_params)
                out["stale_sheds"] = int(self._stale_sheds)
                out["version_lag_served"] = {
                    lag: int(c) for lag, c in self._version_served.items()}
        if len(lat):
            from repro.serving.loadgen import latency_summary

            out["latency_ms"] = {**latency_summary(lat),
                                 "max": float(lat.max()),
                                 "window_n": int(len(lat)),
                                 "window": int(self._latency.window)}
        out["retraces"] = self.retraces()
        out["caches"] = self.executor.cache_stats()
        # Same window as the engine's own counters: delta since the last
        # reset_counters(), not the executor's lifetime totals.
        sh = self.executor.sharing_stats()
        before = sh["nodes_before"] - self._sharing0["nodes_before"]
        after = sh["nodes_after"] - self._sharing0["nodes_after"]
        out["sharing"] = {
            "nodes_before": before,
            "nodes_after": after,
            "pooled_rows_saved": before - after,
            "saved_frac": (before - after) / max(before, 1),
        }
        out["scorer_traces"] = self._scorer.traces - self._scorer_traces0
        out["plan_cache"] = sh["plan_cache"]
        if self.sem_cache is not None:
            out["sem_cache"] = self.sem_cache.stats()
        if self.mat_cache is not None:
            # Duplicate-heavy traffic shows up here as the hit rate: rows
            # served without re-encoding since the last reset_counters.
            out["mat_cache"] = self.mat_cache.stats()
        return out
