"""LiveNGDB: online KG writes with incremental embedding maintenance
(DESIGN.md §LiveStore).

This is the write front door that turns the read-optimized serving stack
into a database: ``write`` validates and commits a triple burst into the
``KnowledgeGraph`` (atomic CSR publish, version bump, snapshot retention),
grows the entity table / on-disk ``SemanticStore`` when the burst introduces
unseen entities, and enqueues the written neighborhood for BACKGROUND
fine-tuning on a maintenance thread — serving continues uninterrupted on
the engine's batcher, bounded by its ``max_staleness_versions`` knob.

Division of labor per write:

  main/writer thread (synchronous, cheap)        maintenance thread (async)
  ---------------------------------------        --------------------------
  grow params entity rows (+ store append)       incremental_finetune on the
  kg.add_entities / kg.insert_triples            written triples (no input
  -> version bump, snapshot, listeners fire      donation — live params stay
  enqueue (version, fresh rows)                  readable), then
  return WriteReceipt                            engine.update_params(new)

The fine-tune is a pure function of (params, triples, seed), so a
synchronous rerun from the same inputs reproduces the background thread's
output bitwise — the determinism gate ``benchmarks/live.py`` holds.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class WriteReceipt:
    """What one ``LiveNGDB.write`` actually did."""

    graph_version: int        # version the write committed at (or the
    #                           pre-existing version for a no-op write)
    n_written: int            # fresh triples inserted (post-dedup)
    n_new_entities: int       # entity ids added ahead of the triples
    fresh_triples: np.ndarray  # the deduped rows, [n_written, 3]


def grow_entity_rows(model, params, n_new: int, *, seed: int = 0,
                     version: int = 0, sem_rows=None):
    """Append ``n_new`` entity rows to the params tables, returning new
    params (the input dict is not mutated; shared arrays are reused).

    New embeddings use the same ``N(0, 1/sqrt(d))`` init as ``init_params``,
    keyed by ``fold_in(seed, version)`` so every write burst gets distinct
    but reproducible rows. Rows already present as alignment padding
    (``cfg.entity_pad``) are claimed first — the pad rows were initialized
    identically, so claiming one is just widening the score mask.

    ``model.n_entities`` is advanced; ``score_all`` reads it at trace time,
    so programs compiled for the NEW table shape mask correctly while
    cached old-shape programs keep serving version-pinned replays with
    their admitted-state masking.

    ``sem_rows`` ([n_new, d_l] fp32) extends a full-resident ``sem_table``.
    The out-of-core hot-set layout (``sem_slot``/``sem_cache``) fixes its
    indirection size at construction — growing it live is not supported.
    """
    if n_new < 0:
        raise ValueError("n_new must be >= 0")
    if n_new == 0:
        return params
    if "sem_slot" in params:
        raise NotImplementedError(
            "live entity growth with the out-of-core semantic hot set is "
            "not supported (sem_slot indirection is fixed-size); rebuild "
            "the store offline instead")
    old_n = model.n_entities
    new_n = old_n + int(n_new)
    rows = int(params["entity"].shape[0])
    new_rows = model.padded_entities(new_n)
    params = dict(params)
    if new_rows > rows:
        d = int(params["entity"].shape[1])
        key = jax.random.fold_in(jax.random.PRNGKey(seed), version)
        extra = jax.random.normal(key, (new_rows - rows, d)) * (1.0 / np.sqrt(d))
        params["entity"] = jnp.concatenate(
            [params["entity"], extra.astype(params["entity"].dtype)], axis=0)
    if "sem_table" in params:
        if sem_rows is None:
            raise ValueError(
                "params carry a sem_table: pass sem_rows ([n_new, d_l]) "
                "for the new entities")
        sem_rows = jnp.asarray(sem_rows, dtype=params["sem_table"].dtype)
        if sem_rows.shape != (n_new, params["sem_table"].shape[1]):
            raise ValueError(
                f"sem_rows shape {sem_rows.shape} != "
                f"({n_new}, {params['sem_table'].shape[1]})")
        # The stored table is padded to the entity-row count; place the new
        # semantic rows at their entity ids and re-pad to the new row count.
        st = params["sem_table"][:old_n]
        st = jnp.concatenate([st, sem_rows], axis=0)
        if new_rows > new_n:
            st = jnp.pad(st, ((0, new_rows - new_n), (0, 0)))
        params["sem_table"] = st
    model.n_entities = new_n
    return params


class LiveNGDB:
    """Write coordinator binding a ``KnowledgeGraph``, a ``ServingEngine``
    and (optionally) a ``SemanticStore`` into a live database.

    One daemon maintenance thread consumes committed writes in order and
    publishes fine-tuned params through ``engine.update_params`` — the same
    path online training uses, so every staleness/invalidation contract
    (mat-cache bumps, version-pinned params retention) holds for free.
    ``flush()`` joins the queue and re-raises the first background error.
    """

    def __init__(self, model, kg, engine, store=None, *,
                 finetune_steps: int = 4, finetune_lr: float = 1e-3,
                 n_negatives: int = 8, seed: int = 0):
        self.model = model
        self.kg = kg
        self.engine = engine
        self.store = store
        self.finetune_steps = finetune_steps
        self.finetune_lr = finetune_lr
        self.n_negatives = n_negatives
        self.seed = seed
        self.finetunes_done = 0
        self.receipts: List[WriteReceipt] = []
        self._errors: List[BaseException] = []
        self._q: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._maintain, daemon=True,
                                        name="live-maintenance")
        self._thread.start()

    # -------------------------------------------------------------- writes
    def write(self, triples, n_new_entities: int = 0,
              sem_rows=None) -> WriteReceipt:
        """Commit one write burst. ``triples`` may reference the
        ``n_new_entities`` ids immediately above the current entity count;
        params (and the semantic store, if attached) grow FIRST so the ids
        are valid everywhere before the graph commit makes them reachable.

        Returns synchronously once the write is durable in the graph; the
        embedding fine-tune happens in the background (``flush()`` to
        wait). A no-op burst (all duplicates) changes nothing and enqueues
        nothing."""
        if n_new_entities:
            version = self.kg.graph_version
            table_rows = (sem_rows if "sem_table" in self.engine.params
                          else None)
            params = grow_entity_rows(
                self.model, self.engine.params, n_new_entities,
                seed=self.seed, version=version, sem_rows=table_rows)
            if self.store is not None:
                if sem_rows is None:
                    raise ValueError(
                        "a SemanticStore is attached: pass sem_rows for the "
                        "new entities")
                self.store.append_rows(np.asarray(sem_rows, np.float32))
            self.kg.add_entities(n_new_entities)
            # Publish the grown tables through the engine's own swap path
            # so the params/mat-version pairing stays consistent.
            self.engine.update_params(params)
        fresh = self.kg.insert_triples(triples)
        receipt = WriteReceipt(self.kg.graph_version, len(fresh),
                               int(n_new_entities), fresh)
        self.receipts.append(receipt)
        if len(fresh):
            self._q.put(receipt)
        return receipt

    # --------------------------------------------------------- maintenance
    def _maintain(self) -> None:
        from repro.training.loop import incremental_finetune

        while not self._stop.is_set():
            try:
                receipt = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                params, _ = incremental_finetune(
                    self.model, self.engine.params, receipt.fresh_triples,
                    steps=self.finetune_steps, lr=self.finetune_lr,
                    n_negatives=self.n_negatives,
                    seed=self.seed + receipt.graph_version)
                self.engine.update_params(params)
                self.finetunes_done += 1
            except BaseException as e:  # surfaced by flush()/close()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def flush(self, timeout: float = 60.0) -> None:
        """Block until every enqueued fine-tune has been applied, then
        re-raise the first background error (if any)."""
        import time

        deadline = time.monotonic() + timeout
        while self._q.unfinished_tasks and time.monotonic() < deadline:
            time.sleep(0.005)
        if self._q.unfinished_tasks:
            raise TimeoutError("live maintenance queue did not drain")
        if self._errors:
            raise self._errors[0]

    def close(self, flush: bool = True) -> None:
        if flush and not self._errors:
            self.flush()
        self._stop.set()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "LiveNGDB":
        return self

    def __exit__(self, *exc) -> None:
        self.close(flush=exc[0] is None)
