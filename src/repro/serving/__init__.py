"""Continuous-batching serving subsystem (DESIGN.md §Serving, §LiveStore,
§ServingTier)."""
from repro.serving.engine import (BatchRecord, CachedScorer, ServingConfig,
                                  ServingEngine, StaleVersionError,
                                  pad_to_bucket, scorer_for, topk_desc)
from repro.serving.live import LiveNGDB, WriteReceipt, grow_entity_rows
from repro.serving.loadgen import (LoadReport, TenantLoad, TenantReport,
                                   check_against_offline, latency_summary,
                                   make_workload, run_closed_loop,
                                   run_open_loop, run_tenant_mix)
from repro.serving.replica import Replica, ReplicaPool
from repro.serving.router import (Router, RouterConfig, ShedError, TenantSpec,
                                  query_topology_key, rendezvous_rank)

__all__ = [
    "BatchRecord", "CachedScorer", "ServingConfig", "ServingEngine",
    "StaleVersionError", "pad_to_bucket", "scorer_for", "topk_desc",
    "LiveNGDB", "WriteReceipt", "grow_entity_rows",
    "LoadReport", "TenantLoad", "TenantReport", "check_against_offline",
    "latency_summary", "make_workload", "run_closed_loop", "run_open_loop",
    "run_tenant_mix",
    "Replica", "ReplicaPool",
    "Router", "RouterConfig", "ShedError", "TenantSpec",
    "query_topology_key", "rendezvous_rank",
]
