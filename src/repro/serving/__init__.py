"""Continuous-batching serving subsystem (DESIGN.md §Serving, §LiveStore)."""
from repro.serving.engine import (BatchRecord, CachedScorer, ServingConfig,
                                  ServingEngine, StaleVersionError,
                                  pad_to_bucket, scorer_for, topk_desc)
from repro.serving.live import LiveNGDB, WriteReceipt, grow_entity_rows
from repro.serving.loadgen import (LoadReport, check_against_offline,
                                   latency_summary, make_workload,
                                   run_closed_loop, run_open_loop)

__all__ = [
    "BatchRecord", "CachedScorer", "ServingConfig", "ServingEngine",
    "StaleVersionError", "pad_to_bucket", "scorer_for", "topk_desc",
    "LiveNGDB", "WriteReceipt", "grow_entity_rows",
    "LoadReport", "check_against_offline", "latency_summary",
    "make_workload", "run_closed_loop", "run_open_loop",
]
