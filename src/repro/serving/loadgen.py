"""Closed- and open-loop load generation for the serving engine.

Shared by ``launch/serve.py`` (the CLI driver) and ``benchmarks/serving.py``
(the invariant-asserting load test):

* **closed loop** — a fixed number of in-flight requests (``concurrency``);
  a new request is submitted only when one completes. Measures the maximum
  sustainable throughput of the engine (the classic closed-system probe).
* **open loop** — requests arrive on a fixed schedule (``qps``; 0 = burst,
  i.e. submit as fast as admission allows). Measures latency UNDER a given
  offered load, including queueing — the number a latency SLO is written
  against. Arrival pacing never waits for completions, so a saturated
  engine shows up as growing p99, exactly as in production.

Workloads are deterministic (seeded sampler), so a warmup pass followed by a
replay exercises the zero-steady-state-retrace claim: every micro-batch
composition the replay forms was already compiled.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.patterns import QueryInstance
from repro.obs.trace import TRACER
from repro.sampling.online import OnlineSampler


def make_workload(kg, n: int, seed: int = 11,
                  patterns: Optional[Sequence[str]] = None) -> List[QueryInstance]:
    """Deterministic mixed-pattern request stream (same seed ⇒ same queries,
    so warmup and replay see identical micro-batch compositions)."""
    sampler = (OnlineSampler(kg, patterns=patterns, seed=seed)
               if patterns is not None else OnlineSampler(kg, seed=seed))
    return [s.query for s in sampler.sample_batch(n)]


def latency_summary(lat_ms: Sequence[float]) -> Dict[str, float]:
    lat = np.asarray(lat_ms, dtype=np.float64)
    if len(lat) == 0:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "n": 0}
    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
            "mean": float(lat.mean()), "n": int(len(lat))}


@dataclasses.dataclass
class LoadReport:
    mode: str                  # closed | open
    results: List[Dict]        # per-request result dicts, submission order
    wall_s: float
    qps: float
    latency_ms: Dict[str, float]
    # Open loop only: the arrival rate the generator ACTUALLY offered —
    # submissions / submit-phase wall time. Historically this was never
    # recorded (the bench re-reported the --qps argument, so burst mode
    # showed "qps_offered": 0.0 next to a 4000+ qps_open); now it is
    # measured, including any pacing slip on a loaded box.
    offered_qps: float = 0.0

    def describe(self) -> str:
        l = self.latency_ms
        offered = (f" (offered {self.offered_qps:.0f} q/s)"
                   if self.mode == "open" else "")
        return (f"[{self.mode}] {len(self.results)} requests in "
                f"{self.wall_s:.2f}s = {self.qps:.0f} q/s{offered} | "
                f"latency p50 {l['p50']:.1f} ms, p95 {l['p95']:.1f} ms, "
                f"p99 {l['p99']:.1f} ms")


def _closed_window(engine, queries, indices, results, concurrency, timeout,
                   lane: Optional[str] = None):
    """One submitter's closed window over its share of the workload."""
    if lane is not None:
        TRACER.set_lane(lane)
    window: deque = deque()
    for i in indices:
        while len(window) >= concurrency:
            j, f = window.popleft()
            results[j] = f.result(timeout=timeout)
        window.append((i, engine.submit(queries[i])))
    while window:
        j, f = window.popleft()
        results[j] = f.result(timeout=timeout)


def run_closed_loop(engine, queries: Sequence[QueryInstance],
                    concurrency: int = 32, timeout: float = 120.0,
                    threads: int = 1) -> LoadReport:
    """Keep ``concurrency`` requests in flight until the workload drains.

    ``threads > 1`` splits the workload round-robin over that many client
    threads, each keeping its share of the window in flight — a multi-client
    probe (and, when tracing, one named "client N" lane per submitter in the
    trace). ``threads=1`` is bit-for-bit the historical single-submitter
    loop running on the calling thread."""
    if concurrency < 1:
        raise ValueError("concurrency must be >= 1")
    if threads < 1:
        raise ValueError("threads must be >= 1")
    results: List[Optional[Dict]] = [None] * len(queries)
    t0 = time.perf_counter()
    if threads == 1:
        TRACER.set_lane("client 0")
        _closed_window(engine, queries, range(len(queries)), results,
                       concurrency, timeout)
    else:
        per = max(concurrency // threads, 1)
        ts = [threading.Thread(
                  target=_closed_window,
                  args=(engine, queries, range(w, len(queries), threads),
                        results, per, timeout, f"client {w}"),
                  daemon=True)
              for w in range(threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    wall = time.perf_counter() - t0
    return LoadReport(
        mode="closed", results=results, wall_s=wall,
        qps=len(queries) / max(wall, 1e-9),
        latency_ms=latency_summary([r["latency_ms"] for r in results]))


def check_against_offline(batch_log, serve_fn) -> int:
    """Replay recorded engine micro-batches (``ServingEngine`` with
    ``record_batches=True``) through an offline oracle and demand EXACT
    per-request equality of top-k ids and scores — the engine⇔``serve_batch``
    bit-identity contract (DESIGN.md §Serving), shared by the load test and
    the conformance/serving test suites. ``serve_fn(queries) -> results``
    is typically a ``launch/serve.py::serve_batch`` closure. Returns the
    number of requests checked."""
    checked = 0
    for rec in batch_log:
        oracle = serve_fn(rec.queries)
        for got, want in zip(rec.results[: rec.n_real], oracle[: rec.n_real]):
            assert got["top_entities"] == want["top_entities"], (
                f"top-k id mismatch vs offline oracle ({got['pattern']}): "
                f"{got['top_entities']} != {want['top_entities']}")
            assert got["scores"] == want["scores"], (
                f"top-k score mismatch vs offline oracle "
                f"({got['pattern']}): {got['scores']} != {want['scores']}")
            checked += 1
    return checked


def run_open_loop(engine, queries: Sequence[QueryInstance], qps: float = 0.0,
                  timeout: float = 120.0) -> LoadReport:
    """Submit on a fixed arrival schedule (``qps``; 0 = burst) and then wait
    for every future. Submission never waits on completions — the bounded
    admission queue is the only brake (blocking ``submit`` = backpressure),
    so latency includes real queueing delay."""
    futures = []
    t0 = time.perf_counter()
    for i, q in enumerate(queries):
        if qps > 0:
            lag = t0 + i / qps - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
        futures.append(engine.submit(q))
    # Offered rate = what the arrival process actually delivered over the
    # SUBMIT phase (pacing slip and admission blocking included); qps below
    # is the end-to-end rate over submit + drain.
    t_submitted = time.perf_counter()
    results = [f.result(timeout=timeout) for f in futures]
    wall = time.perf_counter() - t0
    return LoadReport(
        mode="open", results=results, wall_s=wall,
        qps=len(queries) / max(wall, 1e-9),
        latency_ms=latency_summary([r["latency_ms"] for r in results]),
        offered_qps=len(queries) / max(t_submitted - t0, 1e-9))


# ---------------------------------------------------------------------------
# Multi-tenant mixed-SLO workloads (DESIGN.md §ServingTier)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TenantLoad:
    """One tenant's open-loop arrival process: ``qps=0`` floods (submits as
    fast as the router admits — the overload aggressor)."""

    tenant: str
    queries: List[QueryInstance]
    qps: float = 0.0


@dataclasses.dataclass
class TenantReport:
    tenant: str
    offered: int               # submit() calls attempted
    completed: int
    shed: int                  # typed ShedError admissions (never blocking)
    failures: int              # futures that resolved with a real error
    wall_s: float
    offered_qps: float
    latency_ms: Dict[str, float]
    # Distribution of individual submit() call durations. For a shed
    # (low-priority) tenant this is the "never blocking" evidence: sheds
    # return in microseconds (p99 stays tiny) while a blocked high-priority
    # submit would show the queue wait here. ``max`` is reported too but is
    # scheduler-noise-sensitive on a loaded box — gate on p99.
    submit_ms: Dict[str, float] = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        l = self.latency_ms
        s = self.submit_ms or {"p99": 0.0}
        return (f"[tenant {self.tenant}] offered {self.offered} "
                f"({self.offered_qps:.0f} q/s), completed {self.completed}, "
                f"shed {self.shed}, failed {self.failures} | p50 "
                f"{l['p50']:.1f} ms, p99 {l['p99']:.1f} ms | submit p99 "
                f"{s['p99']:.2f} ms")


def _tenant_loop(router, load: TenantLoad, report_slot: Dict, timeout: float):
    from repro.serving.router import ShedError

    TRACER.set_lane(f"tenant {load.tenant}")
    futures = []
    shed = 0
    submit_ms: List[float] = []
    t0 = time.perf_counter()
    for i, q in enumerate(load.queries):
        if load.qps > 0:
            lag = t0 + i / load.qps - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
        ts = time.perf_counter()
        try:
            futures.append(router.submit(q, tenant=load.tenant))
        except ShedError:
            shed += 1
        submit_ms.append((time.perf_counter() - ts) * 1e3)
    t_submitted = time.perf_counter()
    lat, failures = [], 0
    for f in futures:
        try:
            lat.append(f.result(timeout=timeout)["latency_ms"])
        except Exception:
            failures += 1
    wall = time.perf_counter() - t0
    sub = latency_summary(submit_ms)
    sub["max"] = float(max(submit_ms)) if submit_ms else 0.0
    report_slot[load.tenant] = TenantReport(
        tenant=load.tenant, offered=len(load.queries), completed=len(lat),
        shed=shed, failures=failures, wall_s=wall,
        offered_qps=len(load.queries) / max(t_submitted - t0, 1e-9),
        latency_ms=latency_summary(lat), submit_ms=sub)


def run_tenant_mix(router, loads: Sequence[TenantLoad],
                   timeout: float = 120.0) -> Dict[str, TenantReport]:
    """Drive several tenants' arrival processes concurrently through one
    router (one paced submitter thread per tenant, mirroring independent
    clients) and report per-tenant completion/shed/latency — the mixed-SLO
    probe behind the bench's per-tenant p50/p99 and shed-rate sections."""
    reports: Dict[str, TenantReport] = {}
    ts = [threading.Thread(target=_tenant_loop,
                           args=(router, load, reports, timeout), daemon=True)
          for load in loads]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return reports
