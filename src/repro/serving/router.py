"""Plan-cache-affinity router with tenant admission (DESIGN.md §ServingTier).

The perf lever unique to this codebase is that everything expensive is
TOPOLOGY-keyed: schedules, plan-cache entries, materialized rows and jit
programs all key off the post-CSE ``PlanGraph.topology_key()``. So the
router's affinity rule is simply *rendezvous-hash the topology over the
live replica set*: identical topologies always land on the replica whose
caches already hold them, each replica's working set becomes a topology
partition that FITS its caches, and membership changes remap only ~1/N of
topologies (the rendezvous property — no ring, no token ceremony).

Layered on top:

* **Bounded load-aware spill** — pure affinity lets one hot topology build
  an unbounded queue on its home replica while neighbors idle. When the
  affinity target's queue depth exceeds ``spill_depth``, the request may
  spill to the next replica(s) in its rendezvous ranking (``spill_width``
  of them) — bounded, deterministic alternates, so a spilled topology
  warms at most ``1 + spill_width`` replicas rather than spraying the
  whole pool.
* **Per-tenant admission** — every request carries a tenant. Quotas bound
  a tenant's in-flight requests (``max_inflight``); priority classes
  decide who blocks under backpressure: a high-priority tenant waits in
  ``submit`` (the engine's bounded-queue contract), a low-priority tenant
  gets a typed :class:`ShedError` IMMEDIATELY whenever its target replica
  is at/over ``low_priority_depth`` or its admission would block — excess
  low-priority load is shed (typed, counted) instead of everyone queueing
  behind it.
* **Hot model swap** — ``router.update_params`` fans out through the pool;
  each engine stamps admissions with a params version and serves in-flight
  requests on the params they were admitted under (see ``engine.py``), so
  the swap is bit-safe without draining.

All routing state is derived: the topology memo is a bounded LRU over
``QueryInstance.key()`` and the rendezvous rankings are memoized per
topology against the pool's ``membership_token``. Hashing uses blake2b,
not Python's salted ``hash()``, so placement is deterministic across
processes — a warm replica stays the home for its topologies across
restarts of the client.
"""
from __future__ import annotations

import dataclasses
import hashlib
import queue
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.compiler import build_plan
from repro.core.patterns import QueryInstance
from repro.obs.registry import get_registry
from repro.obs.trace import TRACER


class ShedError(RuntimeError):
    """Typed load-shed: the router refused admission WITHOUT blocking.

    ``reason`` is ``"quota"`` (tenant over its in-flight bound) or
    ``"backpressure"`` (low-priority tenant against a loaded replica).
    Clients distinguish shed from failure and may retry later; the router
    counts sheds per tenant and never lets them near ``failures``."""

    def __init__(self, tenant: str, reason: str, detail: str = ""):
        super().__init__(
            f"request shed for tenant {tenant!r}: {reason}"
            + (f" ({detail})" if detail else ""))
        self.tenant = tenant
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Admission contract for one tenant. ``max_inflight=0`` = unlimited."""

    name: str
    priority: str = "high"     # "high" blocks under load; "low" is shed
    max_inflight: int = 0

    def __post_init__(self):
        if self.priority not in ("high", "low"):
            raise ValueError(f"priority must be high|low, got {self.priority}")
        if self.max_inflight < 0:
            raise ValueError("max_inflight must be >= 0")


@dataclasses.dataclass
class RouterConfig:
    # Affinity target's queue depth above which a request may spill to the
    # next replica(s) in its rendezvous ranking.
    spill_depth: int = 8
    # How many rendezvous alternates a spilling request may consider. 0
    # disables spill (pure affinity).
    spill_width: int = 1
    # Queue depth at/above which a LOW-priority request is shed outright
    # (before even attempting a non-blocking enqueue). None = spill_depth.
    low_priority_depth: Optional[int] = None
    # Tenant used when submit() is called without one — keeps the router a
    # drop-in for single-engine call sites (loadgen's closed/open loops).
    default_tenant: str = "default"
    # Bounded memo of QueryInstance.key() -> topology_key.
    topo_memo_size: int = 4096


def query_topology_key(q: QueryInstance) -> Tuple:
    """Topology key of a single query: the post-CSE shape of its one-query
    plan, bindings excluded — the same key the schedule/plan/jit caches use
    downstream, which is exactly what makes routing by it an affinity rule
    rather than a heuristic."""
    return build_plan([q]).topology_key()


def rendezvous_rank(topo: Tuple, rids: Sequence[int]) -> List[int]:
    """Replica ids ranked by highest-random-weight for this topology.

    blake2b over ``repr((topo, rid))`` — deterministic across processes and
    runs (``topology_key`` tuples are all ints, so ``repr`` is stable).
    Removing a replica promotes each of its topologies to the next rank
    WITHOUT moving anyone else (the ~1/N remap property the tests pin)."""
    def weight(rid: int) -> bytes:
        return hashlib.blake2b(repr((topo, rid)).encode(),
                               digest_size=8).digest()

    return sorted(rids, key=lambda rid: (weight(rid), rid), reverse=True)


class _Tenant:
    """Runtime admission state + labeled metrics for one TenantSpec."""

    def __init__(self, spec: TenantSpec):
        self.spec = spec
        self.inflight = 0
        # Satellite: tenant= label through the PR 7 registry. These are NEW
        # labeled keys (serving_submitted{tenant=gold}, ...); the engines'
        # unlabeled keys are untouched, so prior snapshots still parse.
        g = get_registry().group("serving", tenant=spec.name)
        self.metrics = g
        self.submitted = g.counter("submitted")
        self.completed = g.counter("completed")
        self.failures = g.counter("failures")
        self.shed = {r: g.counter("shed", reason=r)
                     for r in ("quota", "backpressure")}
        self.latency = g.histogram("latency_ms")


class Router:
    """Affinity router over a :class:`ReplicaPool`.

    Duck-compatible with ``ServingEngine`` for the loadgen drivers:
    ``submit(query, top_k=..., timeout=...)`` returns the same future, and
    ``close``/``stats`` fan out. ``submit`` additionally takes ``tenant=``.
    """

    def __init__(self, pool, tenants: Optional[Sequence[TenantSpec]] = None,
                 cfg: Optional[RouterConfig] = None):
        self.pool = pool
        self.cfg = cfg or RouterConfig()
        if self.cfg.spill_depth < 0 or self.cfg.spill_width < 0:
            raise ValueError("spill_depth and spill_width must be >= 0")
        self._lock = threading.Lock()
        self._tenants: Dict[str, _Tenant] = {}
        for spec in tenants or ():
            if spec.name in self._tenants:
                raise ValueError(f"duplicate tenant {spec.name!r}")
            self._tenants[spec.name] = _Tenant(spec)
        # Anonymous traffic rides a high-priority unlimited default tenant
        # unless the caller configured one explicitly.
        if self.cfg.default_tenant not in self._tenants:
            self._tenants[self.cfg.default_tenant] = _Tenant(
                TenantSpec(self.cfg.default_tenant))
        # Router-level (unlabeled-by-tenant) counters.
        self._metrics = get_registry().group("router")
        self._routed = self._metrics.counter("routed")
        self._spilled = self._metrics.counter("spilled")
        self._shed_total = self._metrics.counter("shed")
        # key() -> topology LRU, and topology -> ranking memo tied to the
        # pool's membership_token (join/leave invalidates wholesale).
        self._topo_memo: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._rank_memo: Dict[Tuple, List[int]] = {}
        self._rank_token = -1
        # Token-cached replica view: pool.replicas() copies its dict (it
        # must — membership can change under it), which is too expensive to
        # do twice per submit. Benign racy refresh: the swap is atomic and
        # idempotent, and a stale view is caught by the token check on the
        # NEXT access — same staleness window the copy itself has.
        self._view: Dict[int, object] = {}
        self._view_token = -1

    # ------------------------------------------------------------- placement
    def _replicas(self) -> Dict[int, object]:
        token = self.pool.membership_token
        if token != self._view_token:
            self._view = self.pool.replicas()
            self._view_token = token
        return self._view

    def _topology(self, q: QueryInstance) -> Tuple:
        key = q.key()
        with self._lock:
            topo = self._topo_memo.get(key)
            if topo is not None:
                self._topo_memo.move_to_end(key)
                return topo
        topo = query_topology_key(q)   # plan build outside the lock
        with self._lock:
            self._topo_memo[key] = topo
            self._topo_memo.move_to_end(key)
            while len(self._topo_memo) > self.cfg.topo_memo_size:
                self._topo_memo.popitem(last=False)
        return topo

    def _ranking(self, topo: Tuple) -> List[int]:
        token = self.pool.membership_token
        with self._lock:
            if token != self._rank_token:
                self._rank_memo.clear()
                self._rank_token = token
            rank = self._rank_memo.get(topo)
            if rank is None:
                rank = rendezvous_rank(topo, sorted(self.pool.replicas()))
                if not rank:
                    raise RuntimeError("replica pool is empty")
                self._rank_memo[topo] = rank
        return rank

    def _place(self, topo: Tuple) -> Tuple[int, bool, List[int]]:
        return self._place_ranked(self._ranking(topo))

    def _place_ranked(self, rank: List[int]) -> Tuple[int, bool, List[int]]:
        """Pick ``(rid, spilled, ranking)``: the affinity target unless its
        queue is past ``spill_depth`` AND a ranked alternate is below it.
        With spill disabled placement is PURE (topology -> rank[0]), so no
        queue depth is probed at all."""
        if self.cfg.spill_width == 0:
            return rank[0], False, rank
        replicas = self._replicas()
        rank = [rid for rid in rank if rid in replicas]
        if not rank:
            raise RuntimeError("replica pool is empty")
        primary = rank[0]
        depth = replicas[primary].queue_depth()
        if depth <= self.cfg.spill_depth:
            return primary, False, rank
        for rid in rank[1:1 + self.cfg.spill_width]:
            if replicas[rid].queue_depth() <= self.cfg.spill_depth:
                return rid, True, rank
        return primary, False, rank

    # ------------------------------------------------------------- admission
    def submit(self, query: QueryInstance, top_k: Optional[int] = None,
               timeout: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        """Route + admit one request. High-priority tenants inherit the
        engine's blocking backpressure (or ``queue.Full`` with ``timeout``);
        low-priority tenants NEVER block — any admission that would wait
        raises :class:`ShedError` instead. Quota sheds are checked first and
        apply to every priority class."""
        name = tenant if tenant is not None else self.cfg.default_tenant
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r} "
                           f"(configured: {sorted(self._tenants)})")
        spec = t.spec
        # One lock acquisition covers the quota check AND both placement
        # memos: at steady state (memo hits, live token) the full routing
        # decision happens here; any miss falls back to the cold helpers.
        key = query.key()
        token = self.pool.membership_token
        rank = None
        with self._lock:
            if spec.max_inflight and t.inflight >= spec.max_inflight:
                t.shed["quota"].inc()
                self._shed_total.inc()
                raise ShedError(name, "quota",
                                f"{t.inflight}/{spec.max_inflight} in flight")
            t.inflight += 1
            if token == self._rank_token:
                topo = self._topo_memo.get(key)
                if topo is not None:
                    self._topo_memo.move_to_end(key)
                    rank = self._rank_memo.get(topo)
        try:
            if rank is None:
                rank = self._ranking(self._topology(query))
            if TRACER.enabled:
                with TRACER.span("route", pattern=query.pattern, tenant=name):
                    fut, spilled = self._admit(query, rank, top_k, timeout,
                                               name, spec)
            else:
                fut, spilled = self._admit(query, rank, top_k, timeout, name,
                                           spec)
        except ShedError:
            with self._lock:
                t.inflight -= 1
            t.shed["backpressure"].inc()
            self._shed_total.inc()
            raise
        except BaseException:
            with self._lock:
                t.inflight -= 1
            raise
        t.submitted.inc()
        self._routed.inc()
        if spilled:
            self._spilled.inc()
        t0 = time.perf_counter()

        def _done(f: Future, t=t, t0=t0):
            with self._lock:
                t.inflight -= 1
            if f.exception() is not None:
                t.failures.inc()
            else:
                t.completed.inc()
                t.latency.observe((time.perf_counter() - t0) * 1e3)

        fut.add_done_callback(_done)
        return fut

    def _admit(self, query: QueryInstance, rank: List[int], top_k, timeout,
               name: str, spec: TenantSpec) -> Tuple[Future, bool]:
        """Placement + enqueue for one already-quota-checked request."""
        rid, spilled, _rank = self._place_ranked(rank)
        rep = self._replicas()[rid]
        if spec.priority == "low":
            shallow = (self.cfg.low_priority_depth
                       if self.cfg.low_priority_depth is not None
                       else self.cfg.spill_depth)
            if rep.queue_depth() >= shallow:
                raise ShedError(name, "backpressure",
                                f"replica {rid} depth >= {shallow}")
            try:
                return rep.submit(query, top_k=top_k, timeout=0), spilled
            except queue.Full:
                raise ShedError(name, "backpressure",
                                f"replica {rid} queue full") from None
        return rep.submit(query, top_k=top_k, timeout=timeout), spilled

    def submit_many(self, queries: Sequence[QueryInstance],
                    top_k: Optional[int] = None,
                    timeout: Optional[float] = None,
                    tenant: Optional[str] = None) -> List[Future]:
        """Batched admission: one quota check + one memoized placement pass
        under a single lock acquisition, then ONE grouped engine admission
        per home replica — per-request router/engine overheads amortize
        across the batch. Results and routing are identical to a ``submit``
        loop; the differences are admission granularity: the quota check is
        all-or-nothing for the batch (shed before anything is enqueued), and
        all requests in a home-replica group share one admission timestamp
        and params version. Low-priority tenants keep the per-request path —
        their shed contract is per query."""
        name = tenant if tenant is not None else self.cfg.default_tenant
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r} "
                           f"(configured: {sorted(self._tenants)})")
        spec = t.spec
        if spec.priority == "low":
            return [self.submit(q, top_k=top_k, timeout=timeout, tenant=name)
                    for q in queries]
        n = len(queries)
        if n == 0:
            return []
        keys = [q.key() for q in queries]
        token = self.pool.membership_token
        ranks: List[Optional[List[int]]] = [None] * n
        with self._lock:
            if spec.max_inflight and t.inflight + n > spec.max_inflight:
                t.shed["quota"].inc()
                self._shed_total.inc()
                raise ShedError(
                    name, "quota",
                    f"{t.inflight}+{n} > {spec.max_inflight} in flight")
            t.inflight += n
            if token == self._rank_token:
                for i, key in enumerate(keys):
                    topo = self._topo_memo.get(key)
                    if topo is not None:
                        self._topo_memo.move_to_end(key)
                        ranks[i] = self._rank_memo.get(topo)
        t0 = time.perf_counter()

        def _done(f: Future, t=t, t0=t0):
            with self._lock:
                t.inflight -= 1
            if f.exception() is not None:
                t.failures.inc()
            else:
                t.completed.inc()
                t.latency.observe((time.perf_counter() - t0) * 1e3)

        futures: List[Optional[Future]] = [None] * n
        enqueued = 0
        try:
            groups: Dict[int, List[int]] = {}
            spilled = 0
            for i, q in enumerate(queries):
                rank = ranks[i]
                if rank is None:
                    rank = self._ranking(self._topology(q))
                rid, sp, _rank = self._place_ranked(rank)
                groups.setdefault(rid, []).append(i)
                spilled += sp
            replicas = self._replicas()
            for rid, idxs in groups.items():
                fs = replicas[rid].submit_many(
                    [queries[i] for i in idxs], top_k=top_k, timeout=timeout)
                for i, f in zip(idxs, fs):
                    futures[i] = f
                    f.add_done_callback(_done)
                enqueued += len(fs)
        except BaseException:
            # Futures already enqueued stay admitted (their callbacks own
            # their inflight slots); release only the never-enqueued rest.
            with self._lock:
                t.inflight -= n - enqueued
            raise
        t.submitted.inc(n)
        self._routed.inc(n)
        if spilled:
            self._spilled.inc(spilled)
        return futures

    # ------------------------------------------------------------- lifecycle
    def update_params(self, params) -> None:
        """Hot model swap across the pool (bit-safe, no drain)."""
        self.pool.update_params(params)

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        self.pool.close(drain=drain, timeout=timeout)

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------------------------------------------- metrics
    def tenant_inflight(self, name: str) -> int:
        with self._lock:
            return self._tenants[name].inflight

    def stats(self) -> Dict:
        pool = self.pool.stats()
        with self._lock:
            tenants = {
                name: {
                    "priority": t.spec.priority,
                    "max_inflight": t.spec.max_inflight,
                    "inflight": t.inflight,
                    "submitted": int(t.submitted),
                    "completed": int(t.completed),
                    "failures": int(t.failures),
                    "shed": {r: int(c) for r, c in t.shed.items()},
                    "latency_ms": t.latency.summary(),
                }
                for name, t in self._tenants.items()
            }
        return {
            "routed": int(self._routed),
            "spilled": int(self._spilled),
            "shed": int(self._shed_total),
            "tenants": tenants,
            "pool": pool,
        }
