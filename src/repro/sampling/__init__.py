from repro.sampling.adaptive import AdaptiveDistribution, pattern_losses_from_batch
from repro.sampling.online import OnlineSampler, SampledQuery

__all__ = [
    "OnlineSampler",
    "SampledQuery",
    "AdaptiveDistribution",
    "pattern_losses_from_batch",
]
