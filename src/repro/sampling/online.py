"""Online stochastic query sampler (App. F).

Queries are synthesized on-the-fly by BACKWARD ground-truth instantiation:
pick a (degree-weighted) answer entity, then walk the template DAG in reverse
assigning a witness entity to every node and drawing relations from actual
incoming edges — so accepted queries are non-empty by construction on the
positive part. Negation branches are grounded independently and validated by
rejection sampling against the symbolic oracle (P_accept ∝ 1[q ∈ Q_valid]).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.ops import OpType
from repro.core.patterns import TEMPLATES, QueryInstance, answer_query
from repro.data.kg import KnowledgeGraph


@dataclasses.dataclass
class SampledQuery:
    query: QueryInstance
    answers: np.ndarray  # ground-truth answer ids on the training graph


class OnlineSampler:
    """The paper's App. F sampler: O(k·|B|) per batch, zero storage."""

    def __init__(
        self,
        kg: KnowledgeGraph,
        patterns: Sequence[str] = tuple(TEMPLATES),
        seed: int = 0,
        max_rejects: int = 32,
        max_answers: int = 512,
        degree_weighted: bool = True,
    ):
        self.kg = kg
        self.patterns = list(patterns)
        self.rng = np.random.default_rng(seed)
        self.max_rejects = max_rejects
        self.max_answers = max_answers
        self._in_indptr, self._in_rels, self._in_heads = kg.incoming_by_tail
        cand = kg.entities_with_incoming
        if degree_weighted:
            w = kg.degree[cand].astype(np.float64)
            self._answer_p = w / w.sum()
        else:
            self._answer_p = None
        self._answer_cand = cand
        self.stats = {"sampled": 0, "rejected": 0}

    # ------------------------------------------------------------- grounding
    def _random_incoming(self, ent: int) -> Optional[Tuple[int, int]]:
        lo, hi = self._in_indptr[ent], self._in_indptr[ent + 1]
        if hi <= lo:
            return None
        j = int(self.rng.integers(lo, hi))
        return int(self._in_rels[j]), int(self._in_heads[j])

    def _ground(self, pattern: str) -> Optional[QueryInstance]:
        tpl = TEMPLATES[pattern]
        n = len(tpl.nodes)
        ent = np.full(n, -1, dtype=np.int64)
        rel_of_node = np.full(n, -1, dtype=np.int64)
        target = int(self.rng.choice(self._answer_cand, p=self._answer_p))
        ent[tpl.answer_node] = target
        # Reverse walk: every node's witness entity is known before its inputs.
        for i in range(n - 1, -1, -1):
            node = tpl.nodes[i]
            if ent[i] < 0:
                # Unconstrained branch (e.g. the negated side): random witness.
                ent[i] = int(self.rng.choice(self._answer_cand, p=self._answer_p))
            if node.op == OpType.PROJECT:
                step = self._random_incoming(int(ent[i]))
                if step is None:
                    return None
                rel_of_node[i], ent[node.inputs[0]] = step
            elif node.op == OpType.INTERSECT:
                for j in node.inputs:
                    # Negated inputs stay unconstrained; positive inputs share
                    # the witness so the intersection is non-empty.
                    if tpl.nodes[j].op != OpType.NEGATE:
                        ent[j] = ent[i]
            elif node.op == OpType.UNION:
                k = node.inputs[int(self.rng.integers(len(node.inputs)))]
                ent[k] = ent[i]  # one branch witnesses; others stay random
            elif node.op == OpType.NEGATE:
                pass  # input grounded independently (stays -1 → random)
        anchors = np.array(
            [ent[i] for i, nd in enumerate(tpl.nodes) if nd.op == OpType.EMBED], dtype=np.int64
        )
        rels = np.array(
            [rel_of_node[i] for i, nd in enumerate(tpl.nodes) if nd.op == OpType.PROJECT],
            dtype=np.int64,
        )
        if (anchors < 0).any() or (rels < 0).any():
            return None
        return QueryInstance(pattern, anchors, rels)

    # ------------------------------------------------------------- sampling
    def sample(self, pattern: str) -> SampledQuery:
        for _ in range(self.max_rejects):
            self.stats["sampled"] += 1
            q = self._ground(pattern)
            if q is None:
                self.stats["rejected"] += 1
                continue
            ans = answer_query(self.kg, q)
            if not ans:  # rejection sampling: require non-empty answer set
                self.stats["rejected"] += 1
                continue
            ans_arr = np.fromiter(ans, dtype=np.int64)
            if len(ans_arr) > self.max_answers:
                ans_arr = self.rng.choice(ans_arr, self.max_answers, replace=False)
            return SampledQuery(q, ans_arr)
        raise RuntimeError(f"rejection sampling failed for pattern {pattern}")

    def sample_batch(
        self, batch_size: int, dist: Optional[Dict[str, float]] = None
    ) -> List[SampledQuery]:
        names = self.patterns
        if dist is None:
            p = None
        else:
            p = np.array([dist.get(n, 0.0) for n in names], dtype=np.float64)
            p = p / p.sum()
        picks = self.rng.choice(len(names), size=batch_size, p=p)
        return [self.sample(names[i]) for i in picks]

    # --------------------------------------------------------- train tensors
    def to_training_arrays(self, batch: List[SampledQuery], n_negatives: int):
        """(queries, positives [B], negatives [B,K]) — negatives are uniform
        corruptions filtered against the (sampled) answer set."""
        pos = np.array([b.answers[self.rng.integers(len(b.answers))] for b in batch])
        neg = self.rng.integers(0, self.kg.n_entities, size=(len(batch), n_negatives))
        for i, b in enumerate(batch):
            bad = np.isin(neg[i], b.answers)
            while bad.any():  # resample collisions (rare on sparse graphs)
                neg[i, bad] = self.rng.integers(0, self.kg.n_entities, bad.sum())
                bad = np.isin(neg[i], b.answers)
        return [b.query for b in batch], pos, neg
