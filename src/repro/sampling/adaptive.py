"""Adaptive (difficulty-weighted) online sampling — §4.3 / Fig. 9.

Maintains a per-pattern exponential moving average of training loss and tilts
the sampling distribution π toward currently-hard patterns, mixed with a
uniform floor for coverage. Under the paper's steered-workload protocol
(difficulty spikes every N steps) this tracks the shifted distribution instead
of waiting for the uniform sampler to catch up."""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


class AdaptiveDistribution:
    def __init__(
        self,
        patterns: Sequence[str],
        ema: float = 0.9,
        temperature: float = 1.0,
        uniform_floor: float = 0.25,
    ):
        self.patterns = list(patterns)
        self.ema = ema
        self.temperature = temperature
        self.uniform_floor = uniform_floor
        self.difficulty: Dict[str, float] = {p: 1.0 for p in self.patterns}

    def update(self, pattern_losses: Dict[str, float]) -> None:
        for p, loss in pattern_losses.items():
            old = self.difficulty.get(p, 1.0)
            self.difficulty[p] = self.ema * old + (1.0 - self.ema) * float(loss)

    def distribution(self) -> Dict[str, float]:
        d = np.array([self.difficulty[p] for p in self.patterns], dtype=np.float64)
        z = (d - d.mean()) / (d.std() + 1e-6)
        w = np.exp(z / self.temperature)
        w = w / w.sum()
        u = np.full_like(w, 1.0 / len(w))
        w = (1.0 - self.uniform_floor) * w + self.uniform_floor * u
        return dict(zip(self.patterns, w.tolist()))


def pattern_losses_from_batch(patterns, per_query_loss) -> Dict[str, float]:
    """Aggregate per-query losses (device array) into per-pattern means."""
    per_query_loss = np.asarray(per_query_loss)
    out: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for p, l in zip(patterns, per_query_loss):
        out[p] = out.get(p, 0.0) + float(l)
        counts[p] = counts.get(p, 0) + 1
    return {p: out[p] / counts[p] for p in out}
