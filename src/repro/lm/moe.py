"""Mixture-of-Experts FFN with sort-based pool dispatch.

DESIGN.md §Arch-applicability: token→expert dispatch is the paper's
operator-pool batching applied at the layer level — experts are operator
types, tokens are ready operators, and the capacity factor plays the role of
B_max in the Max-Fillness policy (overflowing tokens are dropped, i.e. the
pool executes at its fill limit). The packing below is the same
sort-by-type → dense-batch → scatter-back mechanism as repro/core's executor.

Sharding: computed inside shard_map so the token sort stays *local* to each
data shard (a global sharded argsort would lower to a distributed sort).
Two expert-sharding modes over the ``model`` axis:
  * tp — every shard holds all experts' F/m slice; partial outputs psum'd.
  * ep — every shard holds E/m full experts; only local experts' outputs are
         accumulated, then psum'd (requires E % m == 0, e.g. jamba's 16).
Both modes do identical FLOPs/chip; they differ in weight layout, einsum
shapes and collective pattern — which one wins is a §Perf question.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
    """Version-bridging shard_map wrapper (jax.shard_map in >= 0.8)."""
    import jax as _jax

    if hasattr(_jax, "shard_map"):
        return _jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_rep)
    from jax.experimental.shard_map import shard_map as _sm  # pragma: no cover

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)


def pack_by_expert(x, expert_idx, gates, n_experts: int, capacity: int):
    """Sort-based pool packing. x [T, D]; expert_idx/gates [T, k].

    Returns (packed [E, C, D], combine metadata). Overflow beyond capacity is
    dropped (Max-Fillness at the fill limit).

    §Perf iteration 2: both directions are GATHER-based. Only tiny int32
    index/mask tensors are scattered; the [E, C, D] activations are built by
    gather + mask, and the combine reads y by gather + segment-sum over the
    token-major (T, k) layout — no [E*C, D]-sized scatter(-add) or zero-init
    passes through HBM."""
    T, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_t[order]
    starts = jnp.searchsorted(se, jnp.arange(n_experts))
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < capacity
    dest = jnp.where(keep, se * capacity + pos, n_experts * capacity)  # trash slot
    # tiny scatters: which token fills each expert slot (and whether any does)
    ec = n_experts * capacity
    gather_idx = jnp.zeros((ec + 1,), jnp.int32).at[dest].set(st.astype(jnp.int32))
    filled = jnp.zeros((ec + 1,), bool).at[dest].set(keep)
    packed = jnp.where(filled[:ec, None], x[gather_idx[:ec]], 0)
    # invert the sort so combine can walk (t, k) order directly
    dest_by_flat = jnp.zeros((T * k,), jnp.int32).at[order].set(dest.astype(jnp.int32))
    return packed.reshape(n_experts, capacity, -1), (dest_by_flat, gates, T, k)


def combine_from_experts(y, meta, T: int):
    """Inverse of pack_by_expert with gate weighting. y [E, C, D]."""
    dest_by_flat, gates, T_, k = meta
    e, c, d = y.shape
    y_flat = y.reshape(e * c, d)
    safe = jnp.minimum(dest_by_flat, e * c - 1)
    vals = jnp.where((dest_by_flat < e * c)[:, None], y_flat[safe], 0)
    vals = vals * gates.reshape(T_ * k, 1).astype(y.dtype)
    return vals.reshape(T_, k, d).sum(axis=1)


def _moe_local(x, router, w_gate, w_up, w_down, *, n_experts, top_k,
               capacity_factor, mode, model_axis: Optional[str], ep_shards: int):
    """Per-shard MoE body. x [T_local, D]; weights are the local slices."""
    T, D = x.shape
    logits = (x.astype(jnp.float32)) @ router.astype(jnp.float32)      # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    capacity = max(int(np.ceil(T * top_k / n_experts * capacity_factor)), 1)

    packed, meta = pack_by_expert(x, eidx, gates, n_experts, capacity)  # [E, C, D]
    if mode == "ep" and model_axis is not None:
        # Local shard computes only its E/m experts (full F); the other
        # experts' token rows combine to zero locally and are filled in by
        # the POST-COMBINE psum (see below).
        e_loc = n_experts // ep_shards
        shard = jax.lax.axis_index(model_axis)
        packed_loc = jax.lax.dynamic_slice_in_dim(packed, shard * e_loc, e_loc, 0)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", packed_loc, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", packed_loc, w_up
        )
        y_loc = jnp.einsum("ecf,efd->ecd", h, w_down)
        y = jnp.zeros((n_experts, capacity, D), y_loc.dtype)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_loc, shard * e_loc, 0)
    else:
        # TP-in-expert: all experts, F/m slice each; outputs are partial sums.
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", packed, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", packed, w_up
        )
        y = jnp.einsum("ecf,efd->ecd", h, w_down)
    out = combine_from_experts(y.astype(x.dtype), meta, T)   # [T_local, D]
    if model_axis is not None:
        # §Perf iteration 1: psum AFTER the combine. Both modes produce
        # outputs that are linear in the per-shard contributions, so reducing
        # the combined [T, D] instead of the dispatched [E, C, D] is exact
        # and shrinks the payload by E*C/T = top_k*capacity_factor (~2.5x)
        # ... and far more when capacity padding is loose.
        out = jax.lax.psum(out, model_axis)
    return out


def moe_ffn(x, router, w_gate, w_up, w_down, cfg, mesh=None,
            dp_axes: Tuple[str, ...] = ()) -> jnp.ndarray:
    """x [B, S, D] (or [T, D]). Weights: router [D, E]; w_* [E, D, F]/[E, F, D]."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    kw = dict(
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        mode=cfg.moe_mode,
    )
    if mesh is None:
        out = _moe_local(x2, router, w_gate, w_up, w_down, model_axis=None,
                         ep_shards=1, **kw)
        return out.reshape(shape)

    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    # Drop DP axes that do not divide the token count (e.g. batch=1 decode):
    # tokens are then replicated over those axes, which is what the incoming
    # activation sharding already is.
    while dp and x2.shape[0] % int(np.prod([mesh.shape[a] for a in dp])) != 0:
        dp = dp[:-1]
    m = mesh.shape["model"]
    if cfg.moe_mode == "ep":
        assert cfg.n_experts % m == 0, (cfg.n_experts, m)
        w_specs = (P("model", None, None), P("model", None, None), P("model", None, None))
    else:
        w_specs = (P(None, None, "model"), P(None, None, "model"), P(None, "model", None))
    fn = shard_map(
        functools.partial(_moe_local, model_axis="model", ep_shards=m, **kw),
        mesh=mesh,
        in_specs=(P(dp, None), P(None, None)) + w_specs,
        out_specs=P(dp, None),
        check_rep=False,
    )
    return fn(x2, router, w_gate, w_up, w_down).reshape(shape)
