"""Shared neural building blocks (pure JAX; no flax in this environment)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, hd]; positions [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def gelu_mlp(x: jnp.ndarray, w_up, b_up, w_down, b_down) -> jnp.ndarray:
    return jax.nn.gelu(x @ w_up + b_up) @ w_down + b_down


def init_dense(key, shape, in_axis: int = -2):
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)
