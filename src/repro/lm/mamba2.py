"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) mixer.

Training/prefill use the chunked SSD algorithm as a lax.scan over chunks:
quadratic attention-like compute *within* a chunk (MXU-friendly [Q,Q] tiles),
linear state recurrence *across* chunks (carry [B,H,P,N]). Decode is the O(1)
recurrent update. The scan formulation keeps the working set at one chunk —
the [c,h,Q,Q] full-decay tensor of the "minimal SSD" reference would be GBs
at 32k prefill.

Shapes: x [B,T,H,P]; dtA [B,T,H] (negative); Bm/Cm [B,T,G,N]; heads H map to
groups G by contiguous blocks (rep = H // G).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a [..., Q] -> L [..., Q, Q] with L[i,j] = sum_{j<k<=i} a[k], -inf above
    the diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(x, dtA, Bm, Cm, chunk: int, init_state=None, unroll: bool = False):
    """Chunked SSD. Returns (y [B,T,H,P], final_state [B,H,P,N])."""
    b, t, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    assert t % chunk == 0, (t, chunk)
    c = t // chunk

    xc = x.reshape(b, c, chunk, h, p)
    ac = dtA.reshape(b, c, chunk, h)
    bc = Bm.reshape(b, c, chunk, g, n)
    cc = Cm.reshape(b, c, chunk, g, n)

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        x_c, a_c, b_c, c_c = inp                       # [b,q,h,p] [b,q,h] [b,q,g,n]
        a_cs = jnp.cumsum(a_c, axis=1)                 # [b,q,h]
        L = jnp.exp(segsum(a_c.transpose(0, 2, 1)))    # [b,h,q,q]
        # intra-chunk (attention-like) term, grouped heads
        scores = jnp.einsum("bqgn,bsgn->bgqs", c_c, b_c)            # [b,g,q,s]
        scores = jnp.repeat(scores, rep, axis=1)                     # [b,h,q,s]
        y_diag = jnp.einsum("bhqs,bshp->bqhp", scores * L, x_c)
        # inter-chunk: contribution of incoming state
        state_decay = jnp.exp(a_cs)                                  # [b,q,h]
        c_h = jnp.repeat(c_c, rep, axis=2) if g != h else c_c        # [b,q,h,n]
        y_off = jnp.einsum("bqhn,bhpn,bqh->bqhp", c_h, state, state_decay)
        # chunk state to carry forward
        decay_states = jnp.exp(a_cs[:, -1:, :] - a_cs)               # [b,q,h]
        b_h = jnp.repeat(b_c, rep, axis=2) if g != h else b_c
        chunk_state = jnp.einsum("bqhn,bqh,bqhp->bhpn", b_h, decay_states, x_c)
        new_state = state * jnp.exp(a_cs[:, -1, :])[..., None, None] + chunk_state
        return new_state, (y_diag + y_off).astype(x.dtype)

    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        ac.transpose(1, 0, 2, 3),
        bc.transpose(1, 0, 2, 3, 4),
        cc.transpose(1, 0, 2, 3, 4),
    )
    final_state, ys = jax.lax.scan(step, init_state, xs, unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    return y, final_state


def ssd_decode_step(state, x, dtA, Bm, Cm):
    """O(1) recurrence. x [B,H,P]; dtA [B,H]; Bm/Cm [B,G,N]; state [B,H,P,N]."""
    h, g = x.shape[1], Bm.shape[1]
    rep = h // g
    b_h = jnp.repeat(Bm, rep, axis=1) if g != h else Bm              # [B,H,N]
    c_h = jnp.repeat(Cm, rep, axis=1) if g != h else Cm
    decay = jnp.exp(dtA)[..., None, None]                            # [B,H,1,1]
    new_state = state * decay + jnp.einsum("bhn,bhp->bhpn", b_h, x)
    y = jnp.einsum("bhn,bhpn->bhp", c_h, new_state)
    return y.astype(x.dtype), new_state


def causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width K. x [B,T,C]; w [K,C]; optional incoming
    state [B,K-1,C]. Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    return y + b, new_state


def mamba_mixer(h, lp, cfg, cache: Optional[dict] = None):
    """Full Mamba2 block given pre-normed input h [B,T,D] and layer params lp.
    Returns (out [B,T,D], new_cache)."""
    B_, T, D = h.shape
    din = cfg.d_inner
    g, n = 1, cfg.ssm_state
    nh, p = cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = h @ lp["in_proj"].astype(h.dtype)                    # [B,T,2din+2gn+nh]
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + g * n, 2 * din + 2 * g * n], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = cache.get("conv") if cache is not None else None
    conv_out, new_conv = causal_conv(
        conv_in, lp["conv_w"].astype(h.dtype), lp["conv_b"].astype(h.dtype), conv_state
    )
    conv_out = jax.nn.silu(conv_out)
    xin, Bm, Cm = jnp.split(conv_out, [din, din + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # [B,T,nh]
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))                 # [nh]
    dtA = dt * A                                                   # [B,T,nh]
    xh = xin.reshape(B_, T, nh, p)
    x_dt = xh * dt[..., None].astype(xh.dtype)
    Bm = Bm.reshape(B_, T, g, n)
    Cm = Cm.reshape(B_, T, g, n)

    if T == 1 and cache is not None:  # decode
        y, new_state = ssd_decode_step(
            cache["ssm"], x_dt[:, 0], dtA[:, 0], Bm[:, 0], Cm[:, 0]
        )
        y = y[:, None]
    else:
        chunk = min(cfg.ssm_chunk, T)
        init = cache.get("ssm") if cache is not None else None
        pad = (-T) % chunk
        if pad:  # zero-pad to a chunk multiple: dtA=0 (decay 1) and x=0
            # contribute nothing, so state and outputs are unaffected.
            x_p = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
            a_p = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            y, new_state = ssd_scan(x_p, a_p, b_p, c_p, chunk, init_state=init,
                                    unroll=cfg.exact_cost_mode)
            y = y[:, :T]
        else:
            y, new_state = ssd_scan(x_dt, dtA, Bm, Cm, chunk, init_state=init,
                                    unroll=cfg.exact_cost_mode)
    y = y + lp["D_skip"].astype(h.dtype)[None, None, :, None] * xh
    y = y.reshape(B_, T, din) * jax.nn.silu(z)
    # grouped RMSNorm before out-projection (mamba2's norm placement)
    from repro.lm.modules import rms_norm

    y = rms_norm(y, lp["ssm_norm"], cfg.norm_eps)
    out = y @ lp["out_proj"].astype(h.dtype)
    new_cache = {"conv": new_conv, "ssm": new_state} if cache is not None else None
    return out, new_cache
