"""Assigned input-shape cells and ShapeDtypeStruct input_specs.

Every (arch × shape) cell is fully described here; the dry-run lowers
train_step / prefill_step / decode_step from these specs without allocating
a single real buffer (the shannon/kernels pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.lm.config import LMConfig
from repro.lm.model import COMPUTE_DTYPE
from repro.lm.steps import cache_struct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: LMConfig, shape: str) -> Optional[str]:
    """None if runnable; else a human-readable skip reason."""
    if shape == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 524k decode requires sub-quadratic "
                "attention (see DESIGN.md shape/skip notes)")
    return None


def input_specs(cfg: LMConfig, shape: str) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    if cell.kind == "train":
        batch: Dict = {"labels": sds((b, s), i32)}
        if cfg.frontend == "vision":
            # anyres patch+text embeddings are precomputed by the stub frontend
            batch["embeddings"] = sds((b, s, cfg.d_model), COMPUTE_DTYPE)
        else:
            batch["tokens"] = sds((b, s), i32)
        if cfg.is_encdec:
            batch["encoder_frames"] = sds((b, cfg.encoder_seq, cfg.d_model),
                                          COMPUTE_DTYPE)
        return {"batch": batch}

    if cell.kind == "prefill":
        batch = {}
        if cfg.frontend == "vision":
            batch["embeddings"] = sds((b, s, cfg.d_model), COMPUTE_DTYPE)
        else:
            batch["tokens"] = sds((b, s), i32)
        if cfg.is_encdec:
            batch["encoder_frames"] = sds((b, cfg.encoder_seq, cfg.d_model),
                                          COMPUTE_DTYPE)
        return {"batch": batch}

    # decode: one new token against an s-long cache
    return {
        "caches": cache_struct(cfg, b, s, abstract=True),
        "tokens": sds((b, 1), i32),
        "cache_len": sds((), i32),
    }
