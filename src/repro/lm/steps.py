"""Jit-able step functions: train (fwd+bwd+Adam), prefill, decode."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.config import LMConfig
from repro.lm.model import (
    COMPUTE_DTYPE,
    abstract_params,
    block_pattern,
    chunked_ce_loss,
    forward,
    init_params,
    logits_fn,
    n_repeats,
)
from repro.training.optim import AdamConfig, adam_init, adam_update

LM_ADAM = AdamConfig(lr=1e-4, frozen=())


def _forward_kwargs(cfg: LMConfig, batch: Dict) -> Dict:
    kw = {}
    if "embeddings" in batch:
        kw["embeddings"] = batch["embeddings"]
    else:
        kw["tokens"] = batch["tokens"]
    if cfg.is_encdec:
        kw["enc_frames"] = batch["encoder_frames"]
    return kw


def make_train_step(cfg: LMConfig, mesh=None, dp_axes=(), adam: AdamConfig = LM_ADAM):
    def loss_fn(params, batch):
        hidden, _ = forward(params, cfg, mesh=mesh, dp_axes=dp_axes,
                            **_forward_kwargs(cfg, batch))
        return chunked_ce_loss(params, cfg, hidden, batch["labels"])

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adam_update(grads, opt_state, params, adam)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: LMConfig, mesh=None, dp_axes=(),
                      cache_margin: int = 0):
    """``cache_margin`` extra KV slots are reserved so subsequent decode
    steps have room (a decode write at cache_len==capacity would clamp)."""

    def prefill_step(params, batch):
        tokens = batch.get("tokens", batch.get("embeddings"))
        pad_to = tokens.shape[1] + cache_margin if cache_margin else None
        hidden, caches = forward(params, cfg, mesh=mesh, dp_axes=dp_axes,
                                 caches="init", pad_cache_to=pad_to,
                                 **_forward_kwargs(cfg, batch))
        logits = logits_fn(params, cfg, hidden[:, -1:])
        return caches, logits

    return prefill_step


def make_decode_step(cfg: LMConfig, mesh=None, dp_axes=()):
    def decode_step(params, caches, tokens, cache_len):
        hidden, new_caches = forward(params, cfg, tokens=tokens, mesh=mesh,
                                     dp_axes=dp_axes, caches=caches,
                                     cache_len=cache_len)
        return logits_fn(params, cfg, hidden), new_caches

    return decode_step


# ---------------------------------------------------------------- cache spec
def cache_struct(cfg: LMConfig, batch: int, s_cache: int, abstract: bool = True):
    """Cache pytree (ShapeDtypeStructs or zeros) matching forward()'s layout:
    {posN: {...}} with every leaf stacked [n_rep, ...]."""
    reps = n_repeats(cfg)
    hd = cfg.resolved_head_dim
    kv = cfg.n_kv_heads
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (
        lambda s, d: jnp.zeros(s, d))
    out = {}
    s_attn = min(s_cache, cfg.sliding_window) if cfg.sliding_window else s_cache
    for pi, (mixer, _) in enumerate(block_pattern(cfg)):
        if mixer == "attn":
            c = {
                "k": mk((reps, batch, s_attn, kv, hd), COMPUTE_DTYPE),
                "v": mk((reps, batch, s_attn, kv, hd), COMPUTE_DTYPE),
            }
            if cfg.is_encdec:
                c["xk"] = mk((reps, batch, cfg.encoder_seq, kv, hd), COMPUTE_DTYPE)
                c["xv"] = mk((reps, batch, cfg.encoder_seq, kv, hd), COMPUTE_DTYPE)
        else:
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            c = {
                "conv": mk((reps, batch, cfg.ssm_conv - 1, conv_dim), COMPUTE_DTYPE),
                "ssm": mk((reps, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                           cfg.ssm_state), jnp.float32),
            }
        out[f"pos{pi}"] = c
    return out
