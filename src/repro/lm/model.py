"""Composable decoder stack covering all 10 assigned architectures.

Layers are grouped into a repeating *block pattern* (length P = lcm of the
attention-interleave and MoE-interleave periods); parameters are stacked
[n_rep, ...] per pattern position and the stack is executed as ONE lax.scan
over repetitions — compile size is O(P) layer bodies regardless of depth
(qwen2-72b's 80 layers lower as a single scanned body).

Execution modes:
  * train/prefill  — full-sequence forward (prefill also returns caches)
  * decode         — one token against caches (attn KV / SWA ring / SSM state)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.attention import attention, decode_attention
from repro.lm.config import LMConfig
from repro.lm.mamba2 import mamba_mixer, ssd_decode_step
from repro.lm.modules import apply_rope, init_dense, rms_norm
from repro.lm.moe import moe_ffn

COMPUTE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- pattern
def block_pattern(cfg: LMConfig) -> List[Tuple[str, str]]:
    """[(mixer, ffn)] for one repeating block."""
    kinds = cfg.layer_kinds()
    moe_every = 1 if (cfg.is_moe and not cfg.is_hybrid) else (2 if cfg.is_moe else 0)
    period = 1
    if cfg.is_hybrid:
        period = np.lcm(cfg.attn_every, moe_every or 1)
    elif cfg.is_ssm_only:
        period = 1
    pattern = []
    for i in range(int(period)):
        mixer = kinds[i] if i < len(kinds) else kinds[-1]
        if moe_every and (i % moe_every == moe_every - 1 if moe_every > 1 else True):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        pattern.append((mixer, ffn))
    return pattern


def n_repeats(cfg: LMConfig) -> int:
    p = len(block_pattern(cfg))
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p


# ----------------------------------------------------------------------- init
def _init_attn_layer(key, cfg: LMConfig, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    prefix = "x" if cross else ""
    p = {
        f"{prefix}wq": init_dense(ks[0], (d, h * hd)),
        f"{prefix}wk": init_dense(ks[1], (d, kv * hd)),
        f"{prefix}wv": init_dense(ks[2], (d, kv * hd)),
        f"{prefix}wo": init_dense(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias and not cross:
        p[f"{prefix}bq"] = jnp.zeros((h * hd,))
        p[f"{prefix}bk"] = jnp.zeros((kv * hd,))
        p[f"{prefix}bv"] = jnp.zeros((kv * hd,))
    if cfg.qk_norm and not cross:
        p["qnorm"] = jnp.ones((hd,))
        p["knorm"] = jnp.ones((hd,))
    return p


def _init_ffn(key, cfg: LMConfig, kind: str) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    if kind == "moe":
        e = cfg.n_experts
        return {
            "router": init_dense(ks[0], (d, e)),
            "moe_gate": init_dense(ks[1], (e, d, f)),
            "moe_up": init_dense(ks[2], (e, d, f)),
            "moe_down": init_dense(ks[3], (e, f, d)),
        }
    if kind == "dense":
        if cfg.learned_pos:  # whisper-style gelu MLP with bias
            return {
                "w_up": init_dense(ks[0], (d, f)),
                "b_up": jnp.zeros((f,)),
                "w_down": init_dense(ks[1], (f, d)),
                "b_down": jnp.zeros((d,)),
            }
        return {
            "w_gate": init_dense(ks[0], (d, f)),
            "w_up": init_dense(ks[1], (d, f)),
            "w_down": init_dense(ks[2], (f, d)),
        }
    return {}


def _init_ssm_layer(key, cfg: LMConfig) -> Dict:
    d = cfg.d_model
    din, g, n, nh = cfg.d_inner, 1, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * g * n
    ks = jax.random.split(key, 3)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * din + 2 * g * n + nh)),
        "conv_w": init_dense(ks[1], (cfg.ssm_conv, conv_dim), in_axis=0),
        "conv_b": jnp.zeros((conv_dim,)),
        "dt_bias": jnp.zeros((nh,)),
        "A_log": jnp.zeros((nh,)),
        "D_skip": jnp.ones((nh,)),
        "ssm_norm": jnp.ones((din,)),
        "out_proj": init_dense(ks[2], (din, d)),
    }


def _init_layer(key, cfg: LMConfig, mixer: str, ffn: str, cross: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict = {"ln1": jnp.ones((cfg.d_model,))}
    if mixer == "attn":
        p.update(_init_attn_layer(ks[0], cfg))
    else:
        p.update(_init_ssm_layer(ks[0], cfg))
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,))
        p.update(_init_attn_layer(ks[1], cfg, cross=True))
    if ffn != "none":
        p["ln2"] = jnp.ones((cfg.d_model,))
        p.update(_init_ffn(ks[2], cfg, ffn))
    return p


def init_params(cfg: LMConfig, key: jax.Array) -> Dict:
    """Full parameter pytree (fp32 masters; compute casts to bf16)."""
    vp = cfg.padded_vocab()
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_dense(ks[0], (vp, d), in_axis=-1) * 0.02 * np.sqrt(d),
        "final_norm": jnp.ones((d,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(ks[1], (d, vp))
    if cfg.learned_pos:
        params["pos_embed"] = init_dense(ks[2], (cfg.learned_pos, d), in_axis=-1) * 0.02

    pattern = block_pattern(cfg)
    reps = n_repeats(cfg)
    blocks = {}
    for pi, (mixer, ffn) in enumerate(pattern):
        cross = cfg.is_encdec and mixer == "attn"
        lk = jax.random.fold_in(ks[3], pi)
        stacked = [
            _init_layer(jax.random.fold_in(lk, r), cfg, mixer, ffn, cross)
            for r in range(reps)
        ]
        blocks[f"pos{pi}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    params["blocks"] = blocks

    if cfg.is_encdec:
        enc_layers = [
            _init_layer(jax.random.fold_in(ks[4], i), cfg, "attn", "dense")
            for i in range(cfg.encoder_layers)
        ]
        params["enc"] = {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
            "pos_embed": init_dense(ks[5], (cfg.encoder_seq, d), in_axis=-1) * 0.02,
            "final_norm": jnp.ones((d,)),
        }
    return params


def abstract_params(cfg: LMConfig):
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# -------------------------------------------------------------------- forward
def _attn_block(x, lp, cfg: LMConfig, positions, kv_in=None,
                cache=None, cache_len=None, cross=False, causal=True,
                pad_cache_to=None):
    """Self- or cross-attention sublayer (pre-norm, residual outside).

    Returns (out, cache_updates) where cache_updates is a dict of entries to
    merge into this layer's cache (or None when cache is None)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    pre = "x" if cross else ""
    q = x @ lp[f"{pre}wq"].astype(x.dtype)
    if f"{pre}bq" in lp:
        q = q + lp[f"{pre}bq"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)

    updates = None
    if cross:
        if cache is not None and "xk" in cache:
            k, v = cache["xk"], cache["xv"]  # precomputed encoder KV
        else:
            k = (kv_in @ lp[f"{pre}wk"].astype(x.dtype)).reshape(b, -1, kv, hd)
            v = (kv_in @ lp[f"{pre}wv"].astype(x.dtype)).reshape(b, -1, kv, hd)
            if cache is not None:  # prefill: persist encoder KV
                updates = {"xk": k, "xv": v}
        out = attention(q, k, v, causal=False,
                        mode="dense_chunked" if cfg.exact_cost_mode else "auto")
        return out.reshape(b, s, h * hd) @ lp[f"{pre}wo"].astype(x.dtype), updates

    k = x @ lp["wk"].astype(x.dtype)
    v = x @ lp["wv"].astype(x.dtype)
    if "bk" in lp:
        k = k + lp["bk"].astype(x.dtype)
        v = v + lp["bv"].astype(x.dtype)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["qnorm"], cfg.norm_eps)
        k = rms_norm(k, lp["knorm"], cfg.norm_eps)
    if not cfg.learned_pos:  # RoPE archs (absolute positions; ring-safe)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None and s == 1:  # decode: write KV at ring/linear slot
        s_cache = cache["k"].shape[1]
        pos = cache_len % s_cache if cfg.sliding_window else cache_len
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, 1)
        eff = cache_len + 1
        if cfg.sliding_window:
            eff = jnp.minimum(eff, s_cache)  # ring bounds the window
        lens = jnp.broadcast_to(eff, (b,)).astype(jnp.int32)
        out = decode_attention(q, ck, cv, lens)
        return (
            out.reshape(b, s, h * hd) @ lp["wo"].astype(x.dtype),
            {"k": ck, "v": cv},
        )

    if cache is not None:  # prefill: computed KV becomes the cache
        if cfg.sliding_window and k.shape[1] > cfg.sliding_window:
            updates = {"k": k[:, -cfg.sliding_window :], "v": v[:, -cfg.sliding_window :]}
        else:
            ck, cv = k, v
            if pad_cache_to and pad_cache_to > s:  # capacity for future decodes
                pad = ((0, 0), (0, pad_cache_to - s), (0, 0), (0, 0))
                ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
            updates = {"k": ck, "v": cv}
    out = attention(q, k, v, causal=causal, window=cfg.sliding_window,
                    mode="dense_chunked" if cfg.exact_cost_mode else "auto")
    return out.reshape(b, s, h * hd) @ lp["wo"].astype(x.dtype), updates


def _ffn_block(x, lp, cfg: LMConfig, kind: str, mesh, dp_axes):
    if kind == "moe":
        return moe_ffn(x, lp["router"].astype(x.dtype),
                       lp["moe_gate"].astype(x.dtype),
                       lp["moe_up"].astype(x.dtype),
                       lp["moe_down"].astype(x.dtype), cfg, mesh, dp_axes)
    if "w_gate" in lp:
        return (jax.nn.silu(x @ lp["w_gate"].astype(x.dtype))
                * (x @ lp["w_up"].astype(x.dtype))) @ lp["w_down"].astype(x.dtype)
    return (jax.nn.gelu(x @ lp["w_up"].astype(x.dtype) + lp["b_up"].astype(x.dtype))
            @ lp["w_down"].astype(x.dtype) + lp["b_down"].astype(x.dtype))


def _layer(x, lp, cfg, mixer, ffn, positions, mesh, dp_axes, enc_out=None,
           cache=None, cache_len=None, causal=True, pad_cache_to=None):
    cache_out = dict(cache) if cache is not None else None
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if mixer == "attn":
        out, upd = _attn_block(h, lp, cfg, positions, cache=cache,
                               cache_len=cache_len, causal=causal,
                               pad_cache_to=pad_cache_to)
    else:
        out, upd = mamba_mixer(h, lp, cfg, cache=cache)
    if upd:
        cache_out.update(upd)
    x = x + out
    if mixer == "attn" and "xwq" in lp:  # whisper cross-attention sublayer
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        out, upd = _attn_block(h, lp, cfg, positions, kv_in=enc_out,
                               cache=cache, cross=True)
        if upd:
            cache_out.update(upd)
        x = x + out
    if ffn != "none":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + _ffn_block(h, lp, cfg, ffn, mesh, dp_axes)
    return x, cache_out


def encode_frames(params, cfg: LMConfig, frames: jnp.ndarray, mesh=None,
                  dp_axes=()) -> jnp.ndarray:
    """Whisper encoder over stub conv-frontend embeddings [B, Senc, D]."""
    x = (frames + params["enc"]["pos_embed"][None, : frames.shape[1]]).astype(COMPUTE_DTYPE)
    positions = jnp.arange(frames.shape[1])[None, :]

    def body(carry, lp):
        y, _ = _layer(carry, lp, cfg, "attn", "dense", positions, mesh, dp_axes,
                      causal=False)
        return y, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"]["layers"],
                        unroll=cfg.exact_cost_mode)
    return rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def forward(params, cfg: LMConfig, tokens=None, embeddings=None,
            enc_frames=None, mesh=None, dp_axes=(), caches=None,
            cache_len=None, positions=None, pad_cache_to=None):
    """Returns (hidden [B,S,D] after final norm, new_caches or None).

    ``caches``: None (train) | "init" (prefill: build caches) | pytree with
    leaves stacked [n_rep, ...] (decode: consume + produce caches)."""
    if embeddings is not None:
        x = embeddings.astype(COMPUTE_DTYPE)
        b, s = x.shape[0], x.shape[1]
    else:
        x = params["embed"][tokens].astype(COMPUTE_DTYPE)
        b, s = tokens.shape
    if positions is None:
        base = 0 if cache_len is None else cache_len
        positions = base + jnp.arange(s)[None, :]
    if cfg.learned_pos:
        x = x + params["pos_embed"][positions].astype(COMPUTE_DTYPE)

    enc_out = None
    if cfg.is_encdec and enc_frames is not None:
        enc_out = encode_frames(params, cfg, enc_frames, mesh, dp_axes)

    pattern = block_pattern(cfg)
    build = isinstance(caches, str) and caches == "init"
    has_caches = (caches is not None) and not build

    def _constrain(x):
        if not (cfg.seq_shard and mesh is not None and x.ndim == 3):
            return x
        if "model" in (dp_axes or ()):  # fsdp profile: no TP axis to seq-shard
            return x
        if x.shape[1] % mesh.shape.get("model", 1) != 0:
            return x
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        dp = dp_axes if dp_axes else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(dp, "model", None)))

    def block_body(carry, xs):
        x = carry
        bp = xs[0]
        bc = xs[1] if has_caches else None
        new_c = {}
        for pi, (mixer, ffn) in enumerate(pattern):
            if has_caches:
                c_in = bc[f"pos{pi}"]
            elif build:
                c_in = {}
            else:
                c_in = None
            x, c_out = _layer(x, bp[f"pos{pi}"], cfg, mixer, ffn, positions,
                              mesh, dp_axes, enc_out=enc_out, cache=c_in,
                              cache_len=cache_len, pad_cache_to=pad_cache_to)
            if c_out is not None:
                new_c[f"pos{pi}"] = c_out
        return _constrain(x), (new_c if (has_caches or build) else None)

    body_fn = jax.checkpoint(block_body) if (cfg.remat and caches is None) else block_body
    xs = (params["blocks"], caches) if has_caches else (params["blocks"],)
    x, new_caches = jax.lax.scan(body_fn, x, xs, unroll=cfg.exact_cost_mode)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches


def logits_fn(params, cfg: LMConfig, hidden: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = hidden @ w.astype(hidden.dtype)
    vp = cfg.padded_vocab()
    if vp != cfg.vocab_size:  # mask padded vocab columns
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def chunked_ce_loss(params, cfg: LMConfig, hidden, labels, chunk: int = 512):
    """Cross-entropy without materializing [B, S, V]: scan over S-chunks."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(COMPUTE_DTYPE)
    vmask = (jnp.arange(cfg.padded_vocab()) < cfg.vocab_size).astype(jnp.float32)

    def body(acc, i):
        hc = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = (hc @ w).astype(jnp.float32) + (vmask - 1.0) * 1e30
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(n),
                            unroll=cfg.exact_cost_mode)
    return total / (b * s)
