"""GQA attention with RoPE / qk-norm / QKV-bias / sliding-window, in three
execution modes:

  * ``blockwise``  — flash-style chunked attention (lax.scan over KV blocks
                     with online softmax). Never materializes [S, S]; this is
                     what makes prefill_32k lowering memory-sane and is the
                     jnp analogue of a Pallas flash kernel (the TPU kernel
                     itself is a §Perf item; semantics identical).
  * ``dense``      — reference path for short sequences and tests.
  * ``decode``     — one query step against a KV cache (no materialization
                     issue; softmax over the sharded S axis lowers to a
                     partial-reduce + cross-shard combine, i.e. flash-decode).

Shapes follow [B, S, H, hd]; GQA repeats KV heads by gathering.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def dense_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0) -> jnp.ndarray:
    """Reference attention. q [B,Sq,H,hd], k/v [B,Sk,KV,hd].

    GQA is computed in grouped form (no KV head repetition is materialized)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    n_rep = h // kv
    qg = q.reshape(b, sq, kv, n_rep, hd)
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32) * scale
    sk = k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrqk,bkgd->bqgrd", probs, v).reshape(b, sq, h, hd)


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_chunk: int = 1024, kv_chunk: int = 1024) -> jnp.ndarray:
    """Flash-style attention: O(S·chunk) working set via online softmax.
    Non-divisible lengths are zero-padded; padded keys are masked out and
    padded queries sliced off."""
    b, sq_orig, h, hd = q.shape
    sk_orig = k.shape[1]
    q_chunk = min(q_chunk, sq_orig)
    kv_chunk = min(kv_chunk, sk_orig)
    if sq_orig % q_chunk:
        q = jnp.pad(q, ((0, 0), (0, (-sq_orig) % q_chunk), (0, 0), (0, 0)))
    if sk_orig % kv_chunk:
        pad = ((0, 0), (0, (-sk_orig) % kv_chunk), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    sq, sk = q.shape[1], k.shape[1]
    n_rep = h // k.shape[2]
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / np.sqrt(hd)

    k = k.reshape(b, nk, kv_chunk, k.shape[2], hd)
    v = v.reshape(b, nk, kv_chunk, v.shape[2], hd)

    def q_block(qi, q_blk):
        # online softmax state: (m, l, acc)
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = _repeat_kv(k[:, ki], n_rep)        # [b, kc, h, hd]
            vb = _repeat_kv(v[:, ki], n_rep)
            logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk, kb).astype(jnp.float32) * scale
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.broadcast_to(kpos[None, :] < sk_orig, (q_chunk, kv_chunk))
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqk,bkhd->bqhd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        if causal:
            # only scan blocks that intersect the causal frontier
            n_valid = (qi + 1) * q_chunk  # kv positions needed
            nk_q = (n_valid + kv_chunk - 1) // kv_chunk
        else:
            nk_q = nk
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk_q))
        return acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]

    outs = []
    for qi in range(nq):  # unrolled over query chunks (few at 32k/1k)
        outs.append(q_block(qi, q[:, qi * q_chunk : (qi + 1) * q_chunk]))
    return jnp.concatenate(outs, axis=1)[:, :sq_orig].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0) -> jnp.ndarray:
    """One-token attention. q [B,1,H,hd]; caches [B,S,KV,hd]; cache_len [B].

    Grouped GQA form: the KV cache is read once, never repeated. When the S
    axis of the cache is sharded, the softmax reductions lower to
    partial-reduce + cross-shard combine (flash-decode)."""
    b, sq, h, hd = q.shape
    kv = k_cache.shape[2]
    n_rep = h // kv
    qg = q.reshape(b, sq, kv, n_rep, hd)
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache).astype(jnp.float32) * scale
    s = k_cache.shape[1]
    kpos = jnp.arange(s)[None, :]
    mask = kpos < cache_len[:, None]
    if window > 0:
        mask &= kpos >= (cache_len[:, None] - window)
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v_cache)
    return out.reshape(b, sq, h, hd)


def dense_chunked_attention(q, k, v, *, causal=True, window=0, q_chunk=1024):
    """Python-unrolled q-chunk loop with STATIC causal/window K-slicing.

    Same semantics as blockwise_attention but with no lax.scan, so
    compiled.cost_analysis() counts every chunk (exact-cost dry-run mode) —
    and the static frontier slicing drops the all-masked upper-triangle work."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    outs = []
    nq = (sq + q_chunk - 1) // q_chunk
    for qi in range(nq):
        lo_q = qi * q_chunk
        hi_q = min(lo_q + q_chunk, sq)
        hi = min(hi_q, sk) if causal else sk
        lo = max(0, lo_q + 1 - window) if window else 0
        lo = (lo // 128) * 128  # keep slices lane-aligned
        out = dense_attention(
            q[:, lo_q:hi_q], k[:, lo:hi], v[:, lo:hi],
            causal=causal, window=window, q_offset=lo_q - lo,
        )
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


def attention(q, k, v, *, causal=True, window=0, mode="auto", q_offset=0):
    if mode == "auto":
        mode = "blockwise" if q.shape[1] * k.shape[1] > 4_194_304 else "dense"
    if mode == "dense":
        return dense_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    if mode == "dense_chunked":
        return dense_chunked_attention(q, k, v, causal=causal, window=window)
    return blockwise_attention(q, k, v, causal=causal, window=window)
