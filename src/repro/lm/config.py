"""Config system for the assigned LM architecture zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int            # query heads; 0 for attention-free archs
    n_kv_heads: int
    d_ff: int               # dense MLP width (per expert for MoE)
    vocab_size: int
    head_dim: int = 0       # 0 -> d_model // n_heads
    # attention
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0           # 0 = full attention
    rope_theta: float = 1_000_000.0
    learned_pos: int = 0              # >0: learned positional table (whisper)
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_mode: str = "tp"              # tp = F-sharded experts | ep = expert-parallel
    # layer pattern
    attn_every: int = 1               # hybrid: layer i is attention iff i % attn_every == 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0                # N; 0 = no ssm layers
    ssm_head_dim: int = 64            # P
    ssm_expand: int = 2
    ssm_chunk: int = 256              # SSD chunk length
    ssm_conv: int = 4                 # causal conv width
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0              # 1500 frames for whisper
    # modality frontend (stub): input_specs returns precomputed embeddings
    frontend: str = "none"            # none | audio | vision
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    remat: bool = True
    # families: dense | moe | ssm | hybrid | audio | vlm
    family: str = "dense"
    # Dry-run cost-analysis mode: fully unroll every lax.scan so
    # compiled.cost_analysis() counts all iterations (XLA counts while-loop
    # bodies exactly once — verified empirically; see EXPERIMENTS.md §Dry-run).
    exact_cost_mode: bool = False
    # §Perf: Megatron-SP-style residual-stream sharding — the scan carry
    # (and therefore every remat checkpoint) is sharded over the model axis
    # on the SEQUENCE dim, cutting activation memory ~16x and letting XLA
    # decompose TP all-reduces into reduce-scatter + all-gather.
    seq_shard: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:  # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.n_heads > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.ssm_state > 0 and self.n_heads == 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid / sliding-window)."""
        return self.ssm_state > 0 or self.sliding_window > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind."""
        if self.is_ssm_only:
            return tuple("ssm" for _ in range(self.n_layers))
        if self.is_hybrid:
            return tuple(
                "attn" if i % self.attn_every == 0 else "ssm"
                for i in range(self.n_layers)
            )
        return tuple("attn" for _ in range(self.n_layers))

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for roofline 6ND."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.padded_vocab() * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            if kind == "attn":
                n += d * (self.n_heads * hd) * 2            # wq, wo
                n += d * (self.n_kv_heads * hd) * 2         # wk, wv
            else:
                din = self.d_inner
                conv_dim = din + 2 * self.ssm_state
                n += d * (2 * din + 2 * self.ssm_state + self.ssm_heads)  # in_proj
                n += din * d                                 # out_proj
                n += self.ssm_conv * conv_dim + 3 * self.ssm_heads
            if self.is_moe:
                n += d * self.n_experts                      # router
                n += self.n_experts * 3 * d * self.d_ff
            elif self.d_ff:
                n += 3 * d * self.d_ff
            n += 2 * d                                       # norms
        if self.is_encdec:
            # encoder layers: self-attn + mlp (approx; same shapes as decoder)
            per = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2 + 3 * d * self.d_ff
            n += self.encoder_layers * per
            n += self.n_layers * (d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2)  # cross-attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        moe_total = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        moe_active = self.n_layers * self.top_k * 3 * self.d_model * self.d_ff
        return full - moe_total + moe_active
