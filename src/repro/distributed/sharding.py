"""Divisibility-aware logical sharding rules.

Every parameter/cache/input dimension is mapped to mesh axes through rules
that DROP any mesh axis that does not divide the dimension (whisper's 20
heads on a 16-way axis, qwen2-0.5b's kv=2, 1500 encoder frames, ...). This is
what lets one rule table serve all 10 architectures.

Layout summary (2-D weight sharding, Megatron×FSDP):
  * TP ("model"): attention head projections, MLP/expert F dim, vocab.
  * FSDP ("data"): the other matrix dim of every large parameter, so params
    and Adam state scale 1/(data*model). Gathers are re-materialized by XLA
    per layer inside the scan (ZeRO-3-like).
  * "pod" (multi-pod): pure DP for parameters (replicated), batch sharded.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(dim: int, axis, mesh: Mesh):
    """Return axis if it divides dim, else None."""
    if axis is None:
        return None
    if dim % _axis_size(mesh, axis) == 0:
        return axis
    # try a prefix for tuple axes, e.g. ("data","model") -> "data"
    if isinstance(axis, (tuple, list)):
        for k in range(len(axis) - 1, 0, -1):
            sub = tuple(axis[:k])
            if dim % _axis_size(mesh, sub) == 0:
                return sub if len(sub) > 1 else sub[0]
    return None


# name -> spec for the trailing dims (leading stacking dims replicate).
# "F" = TP axis, "D" = FSDP axis.
_MATRIX_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "wq": ("data", "model"), "wk": ("data", "model"), "wv": ("data", "model"),
    "xwq": ("data", "model"), "xwk": ("data", "model"), "xwv": ("data", "model"),
    "wo": ("model", "data"), "xwo": ("model", "data"),
    "w_gate": ("data", "model"), "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "in_proj": ("data", "model"), "out_proj": ("model", "data"),
    "embed": ("model", "data"), "lm_head": ("data", "model"),
    "router": (None, None),
    "conv_w": (None, "model"),
    "pos_embed": (None, None),
    # NGDB tables
    "entity": ("model", None), "sem_table": ("model", None), "relation": (None, None),
    # Out-of-core semantic hot set (semantic/store.py): bounded by the row
    # budget, so replicate — the scatter staging path stays collective-free.
    "sem_cache": (None, None),
}
_MOE_RULES_TP = {
    "moe_gate": (None, "data", "model"), "moe_up": (None, "data", "model"),
    "moe_down": (None, "model", "data"),
}
_MOE_RULES_EP = {
    "moe_gate": ("model", "data", None), "moe_up": ("model", "data", None),
    "moe_down": ("model", None, "data"),
}
_VECTOR_RULES: Dict[str, Optional[str]] = {
    "bq": "model", "bk": "model", "bv": "model", "b_up": "model",
    "conv_b": "model", "A_log": "model", "dt_bias": "model", "D_skip": "model",
    "ssm_norm": "model",
}


def param_spec(name: str, shape: Tuple[int, ...], mesh: Mesh,
               moe_mode: str = "tp") -> P:
    rules = dict(_MATRIX_RULES)
    rules.update(_MOE_RULES_EP if moe_mode == "ep" else _MOE_RULES_TP)
    if name in rules:
        rule = rules[name]
        ndim = len(shape)
        spec = [None] * ndim
        for i, axis in enumerate(rule):
            di = ndim - len(rule) + i
            if di < 0:
                continue
            spec[di] = _fit(shape[di], axis, mesh)
        return P(*spec)
    if name in _VECTOR_RULES and len(shape) >= 1:
        axis = _fit(shape[-1], _VECTOR_RULES[name], mesh)
        return P(*([None] * (len(shape) - 1) + [axis]))
    return P()  # norms, scalars, small tables: replicate


def fsdp_param_spec(name: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Pure-FSDP (ZeRO-3) profile: no tensor parallelism — every large
    parameter shards its largest divisible dim over the FLATTENED
    ("data","model") axes, and the batch spreads over all devices. The right
    profile for small-to-mid dense models where TP collectives dominate
    (§Perf iteration: a 4B model on a 16-wide TP axis is collective-bound)."""
    if name in ("sem_cache", "sem_slot"):
        # Hot-set cache + indirection stay replicated in EVERY profile: the
        # plan/apply staging scatter must remain collective-free, and the
        # buffers are already bounded by the row budget (not by E).
        return P()
    if not shape or int(np.prod(shape)) < (1 << 16):
        return P()  # norms/biases: replicate
    spec = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        ax = _fit(shape[i], ("data", "model"), mesh)
        if ax is not None:
            spec[i] = ax
            return P(*spec)
    return P()


def tree_param_shardings(tree, mesh: Mesh, moe_mode: str = "tp",
                         profile: str = "2d"):
    """Pytree of NamedShardings matching ``tree`` (params or Adam state).
    profile: "2d" (TP x FSDP, default) | "fsdp" (ZeRO-3, no TP)."""

    def leaf_spec(path, leaf):
        name = None
        for k in reversed(path):
            key = getattr(k, "key", None)
            if isinstance(key, str) and key not in ("m", "v"):
                name = key
                break
        if profile == "fsdp":
            spec = fsdp_param_spec(name or "", leaf.shape, mesh)
        else:
            spec = param_spec(name or "", leaf.shape, mesh, moe_mode)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


# ------------------------------------------------------------------ batches
def dp_axes(mesh: Mesh, profile: str = "2d") -> Tuple[str, ...]:
    if profile == "fsdp":
        return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(shape: Tuple[int, ...], mesh: Mesh, profile: str = "2d") -> P:
    """THE batch leaf rule: dim 0 over the DP axes where divisible, else
    replicate. Single source of truth — ``ExecutionContext.batch_sharding``
    (what the pipeline's scheduler thread puts arrays with) and
    ``batch_shardings`` (what the fused step compiles ``in_shardings`` from)
    must agree byte-for-byte or every dispatch reshards."""
    shape = tuple(shape)
    if not shape:
        return P()
    b_axis = _fit(shape[0], dp_axes(mesh, profile), mesh)
    return P(*([b_axis] + [None] * (len(shape) - 1)))


def batch_shardings(batch_tree, mesh: Mesh, profile: str = "2d"):
    """Inputs: shard dim 0 (batch) over DP axes where divisible."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(leaf.shape, mesh, profile)),
        batch_tree)


def cache_shardings(cache_tree, mesh: Mesh):
    """Decode caches, leaves stacked [n_rep, B, ...]:
      * batch over DP axes when divisible (decode_32k),
      * else the longest remaining dim (the S axis at long_500k) over
        ("data","model") / "model",
      * attention KV additionally shards S (or heads/hd) over "model".
    """
    dp = dp_axes(mesh)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        name = getattr(path[-1], "key", "")
        spec = [None] * len(shape)
        used_model = False
        b_axis = _fit(shape[1], dp, mesh)
        spec[1] = b_axis
        if b_axis is None and len(shape) > 2:
            # batch=1 (long_500k): shard the biggest dim over everything
            big = int(np.argmax(shape[2:])) + 2
            val = _fit(shape[big], ("data", "model"), mesh)
            spec[big] = val
            used_model = val == "model" or (isinstance(val, tuple) and "model" in val)
        if not used_model:
            # k/v/xk/xv: [n_rep, B, S, kv, hd]; conv/ssm: trailing dims
            for cand in range(2, len(shape)):
                ax = _fit(shape[cand], "model", mesh)
                if ax is not None:
                    spec[cand] = ax
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)
