"""Explicit device-placement context threaded through the NGDB engine.

Before this module, placement was an implicit global: everything materialized
on ``jax.devices()[0]`` and the mesh machinery in ``sharding.py`` was only
wired into the LM zoo side. ``ExecutionContext`` makes placement a value that
flows models → executor → trainer → launch (DESIGN.md §Sharding):

* ``single_device()`` — the default everywhere; every helper degrades to a
  no-op / plain ``jnp.asarray`` so the single-device path is bit-for-bit the
  pre-context behavior (no mesh is ever constructed, no sharding attached).
* a mesh context — carries the mesh plus the *policy* for mapping names and
  shapes to ``NamedSharding``s: parameters (and Adam moments) through
  ``tree_param_shardings`` under the chosen profile (``"2d"`` TP×FSDP or
  ``"fsdp"`` ZeRO-3), batch-like arrays over the data-parallel axes via the
  same divisibility-aware ``_fit`` the rule table uses (an indivisible
  leading dim silently replicates instead of erroring), and the donation
  policy for the fused train step.

The context never forces a layout XLA must undo: ``batch_sharding`` /
``param_sharding`` are exactly the shardings the trainer passes to
``jax.jit(in_shardings=...)``, so arrays staged by the pipeline's scheduler
thread (``data/pipeline.py::prepare_work_item``) land where the step program
expects them and dispatch does zero resharding copies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    batch_shardings,
    batch_spec,
    dp_axes,
    fsdp_param_spec,
    param_spec,
    tree_param_shardings,
)


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Placement policy for one training/serving run.

    ``mesh is None`` means single-device: all helpers return ``None`` (for
    shardings) or pass values through untouched, preserving the historical
    behavior exactly. ``donate_params`` is the donation policy for the fused
    train step: donate (params, opt_state) into each dispatch so the update
    is in-place in HBM. It exists as policy (rather than a hard-coded tuple)
    because a caller that aliases ``trainer.params`` across steps — e.g. an
    eval thread scoring a snapshot — must be able to turn donation off
    without editing the trainer.
    """

    mesh: Optional[Mesh] = None
    profile: str = "2d"        # "2d" (TP x FSDP) | "fsdp" (ZeRO-3, no TP)
    moe_mode: str = "tp"
    donate_params: bool = True

    # ------------------------------------------------------------- factories
    @classmethod
    def single_device(cls) -> "ExecutionContext":
        """Today's behavior, bit-for-bit: no mesh, no shardings, plain puts."""
        return cls(mesh=None)

    @classmethod
    def from_mesh(cls, mesh: Mesh, profile: str = "2d",
                  **kw) -> "ExecutionContext":
        if profile not in ("2d", "fsdp"):
            raise ValueError(f"profile must be 2d|fsdp, got {profile!r}")
        return cls(mesh=mesh, profile=profile, **kw)

    # ------------------------------------------------------------ properties
    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    @property
    def n_devices(self) -> int:
        return self.mesh.size if self.mesh is not None else 1

    @property
    def dp_size(self) -> int:
        """Total data-parallel ways (product of the batch axes)."""
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a]
                            for a in dp_axes(self.mesh, self.profile)]))

    def describe(self) -> str:
        if self.mesh is None:
            return "single-device"
        axes = ", ".join(f"{a}={self.mesh.shape[a]}" for a in self.mesh.axis_names)
        return f"mesh({axes}) profile={self.profile}"

    # -------------------------------------------------------------- shardings
    def replicated(self) -> Optional[NamedSharding]:
        return NamedSharding(self.mesh, P()) if self.mesh is not None else None

    def param_sharding(self, name: str,
                       shape: Tuple[int, ...]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        if self.profile == "fsdp":
            spec = fsdp_param_spec(name, tuple(shape), self.mesh)
        else:
            spec = param_spec(name, tuple(shape), self.mesh, self.moe_mode)
        return NamedSharding(self.mesh, spec)

    def param_shardings(self, tree):
        """Pytree of NamedShardings for params or Adam state (or None)."""
        if self.mesh is None:
            return None
        return tree_param_shardings(tree, self.mesh, self.moe_mode, self.profile)

    def batch_sharding(self, shape: Tuple[int, ...]) -> Optional[NamedSharding]:
        """Leading (batch) dim over the DP axes where divisible, else
        replicate — ``sharding.batch_spec``, the same leaf rule the fused
        step's ``in_shardings`` are built from."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh,
                             batch_spec(shape, self.mesh, self.profile))

    def batch_shardings(self, tree):
        if self.mesh is None:
            return None
        return batch_shardings(tree, self.mesh, self.profile)

    # ------------------------------------------------------------- placement
    def put_param(self, name: str, value):
        """Materialize a parameter/table into its NamedSharding (single
        host->devices transfer); plain ``jnp.asarray`` when single-device."""
        import jax.numpy as jnp

        if self.mesh is None:
            return jnp.asarray(value)
        return jax.device_put(value, self.param_sharding(name, np.shape(value)))

    def put_batch(self, value):
        """Device-put a batch-like array, batch-sharded over the DP axes."""
        import jax.numpy as jnp

        if self.mesh is None:
            return jnp.asarray(value)
        return jax.device_put(value, self.batch_sharding(np.shape(value)))

    def put_replicated(self, value):
        import jax.numpy as jnp

        if self.mesh is None:
            return jnp.asarray(value)
        return jax.device_put(value, self.replicated())

    def constrain_batch(self, x):
        """Inside-jit ``with_sharding_constraint`` pinning the batch layout
        (e.g. the executor workspace). No-op single-device, and no-op when
        the leading dim does not divide the DP axes (constraining to
        replicated would *forbid* XLA from sharding it)."""
        if self.mesh is None:
            return x
        sh = self.batch_sharding(x.shape)
        if sh.spec[0] is None:
            return x
        return jax.lax.with_sharding_constraint(x, sh)

    def donate_argnums(self, *argnums: int) -> Tuple[int, ...]:
        return tuple(argnums) if self.donate_params else ()

    def replicated_out_kwargs(self) -> Dict:
        """``jax.jit`` kwargs pinning every output replicated. The serving
        scorer reads its ``[B, E]`` logits back to host on every micro-batch;
        without this the all-entity matmul's output inherits whatever layout
        XLA picks for the sharded entity table, and the host readback pays a
        cross-device gather per request batch instead of one collective at
        program exit. Empty (no constraint) single-device."""
        if self.mesh is None:
            return {}
        return {"out_shardings": self.replicated()}


# --------------------------------------------------------------------------
# Mesh-spec parsing (the launch surface: ``--mesh data=N[,model=M]``)
# --------------------------------------------------------------------------

_KNOWN_AXES = ("pod", "data", "model")


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """``"data=8"`` / ``"data=4,model=2"`` -> {"data": 4, "model": 2}.

    Axis names are restricted to the rule table's vocabulary so a typo fails
    here, not as a silently-replicated parameter."""
    out: Dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, eq, size = part.partition("=")
        name = name.strip()
        if not eq or name not in _KNOWN_AXES:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected comma-separated "
                f"axis=size with axes from {_KNOWN_AXES}, got {part!r}")
        try:
            n = int(size)
        except ValueError:
            raise ValueError(f"bad mesh spec {spec!r}: size {size!r} is not "
                             f"an integer") from None
        if n < 1:
            raise ValueError(f"bad mesh spec {spec!r}: {name}={n} must be >= 1")
        if name in out:
            raise ValueError(f"bad mesh spec {spec!r}: duplicate axis {name!r}")
        out[name] = n
    if "data" not in out:
        raise ValueError(f"bad mesh spec {spec!r}: a 'data' axis is required")
    out.setdefault("model", 1)  # rule table assumes both axes exist
    return out


def make_execution_context(mesh_spec: Optional[str] = None,
                           profile: str = "2d",
                           devices=None,
                           **kw) -> ExecutionContext:
    """Build an ExecutionContext from a ``--mesh`` spec (None = single
    device). Uses the first ``prod(sizes)`` visible devices, so a sweep can
    build 1/2/4/8-device contexts inside one emulated-host process."""
    if mesh_spec is None:
        return ExecutionContext.single_device()
    sizes = parse_mesh_spec(mesh_spec)
    axes = tuple(a for a in _KNOWN_AXES if a in sizes)
    shape = tuple(sizes[a] for a in axes)
    need = int(np.prod(shape))
    devices = list(jax.devices()) if devices is None else list(devices)
    if need > len(devices):
        raise ValueError(
            f"mesh {mesh_spec!r} needs {need} devices but only "
            f"{len(devices)} visible; shrink the mesh or emulate more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    mesh = Mesh(np.asarray(devices[:need]).reshape(shape), axes)
    return ExecutionContext.from_mesh(mesh, profile=profile, **kw)
