from repro.distributed.pipeline_parallel import bubble_fraction, gpipe_forward
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    dp_axes,
    param_spec,
    tree_param_shardings,
)

__all__ = [
    "param_spec",
    "tree_param_shardings",
    "batch_shardings",
    "cache_shardings",
    "dp_axes",
    "gpipe_forward",
    "bubble_fraction",
]
