from repro.distributed.context import (
    ExecutionContext,
    make_execution_context,
    parse_mesh_spec,
)
from repro.distributed.pipeline_parallel import bubble_fraction, gpipe_forward
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    dp_axes,
    param_spec,
    tree_param_shardings,
)

__all__ = [
    "ExecutionContext",
    "make_execution_context",
    "parse_mesh_spec",
    "param_spec",
    "tree_param_shardings",
    "batch_shardings",
    "cache_shardings",
    "dp_axes",
    "gpipe_forward",
    "bubble_fraction",
]
