"""Pipeline parallelism over the ``pod`` mesh axis (``--pod-mode=pp``).

GPipe-style schedule expressed in jax-native constructs: each pod holds a
contiguous stage of the layer stack; microbatch activations travel between
stages with ``jax.lax.ppermute`` inside shard_map. With S stages and M
microbatches the bubble fraction is (S-1)/(M+S-1) — at S=2 pods, M=8
microbatches it is ~12%, traded against NOT replicating the model across
pods (halves per-pod parameter + optimizer memory vs pod-DP).

This module is deliberately model-agnostic: ``stage_fn(stage_params, x)``
is any per-stage forward. The LM zoo's scanned block stack slots in directly
(stage_params = the [n_rep/S, ...] slice of the block stack).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.moe import shard_map  # version-bridging wrapper


def gpipe_forward(stage_fn: Callable, stage_params, x_microbatches,
                  mesh, axis: str = "pod"):
    """Run M microbatches through S pipeline stages.

    stage_params : pytree with leading dim S, sharded over ``axis``
                   (each pod holds only its own stage's slice).
    x_microbatches : [M, mb, ...] input microbatches (replicated over axis).
    Returns [M, mb, ...] outputs (valid on the LAST stage; replicated out
    by a final ppermute broadcast).
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    perm_fwd = [(i, (i + 1) % S) for i in range(S)]

    def local(stage_p, xs):
        # stage_p: this pod's stage slice ([1, ...] leading dim from sharding)
        stage_p = jax.tree.map(lambda a: a[0], stage_p)
        stage_id = jax.lax.axis_index(axis)
        n_ticks = M + S - 1

        def tick(carry, t):
            inflight, outputs = carry
            # stage 0 injects microbatch t (if any left); others use inflight
            mb_idx = jnp.clip(t, 0, M - 1)
            injected = xs[mb_idx]
            x_in = jnp.where(stage_id == 0, injected, inflight)
            y = stage_fn(stage_p, x_in)
            # forward the activation to the next stage
            passed = jax.lax.ppermute(y, axis, perm_fwd)
            # last stage records its result for microbatch (t - (S-1))
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            take = jnp.logical_and(stage_id == S - 1, t >= S - 1)
            outputs = jnp.where(
                take,
                jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx, 0),
                outputs,
            )
            return (passed, outputs), None

        init = (jnp.zeros_like(xs[0]), jnp.zeros_like(xs))
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(n_ticks))
        return outputs[None]  # [1, M, ...] per stage; stacked over the axis

    P = jax.sharding.PartitionSpec
    stage_spec = jax.tree.map(lambda _: P(axis), stage_params)
    stacked = shard_map(
        local, mesh,
        in_specs=(stage_spec, P()),
        out_specs=P(axis),
    )(stage_params, x_microbatches)
    return stacked[-1]  # the last stage holds the real outputs


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
