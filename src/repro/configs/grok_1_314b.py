"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].
64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072."""
from repro.lm.config import LMConfig

CONFIG = LMConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    top_k=2,
    moe_mode="tp",   # 8 experts don't divide the 16-way model axis -> F-sharded
)
