"""whisper-large-v3 [audio] — enc-dec backbone; conv frontend is a STUB per
the assignment (input_specs supplies precomputed frame embeddings)
[arXiv:2212.04356; unverified]. 32L d_model=1280 20H (kv=20, i.e. MHA)
d_ff=5120 vocab=51866; learned positions; 1500 encoder frames."""
from repro.lm.config import LMConfig

CONFIG = LMConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    learned_pos=32_768,      # decoder positional table sized to the largest
                             # applicable cell (long_500k is skipped: full attn)
    encoder_layers=32,
    encoder_seq=1500,
    frontend="audio",
)
