"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887; hf]. 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 (every other layer, per the released
config); mamba layers use d_state=16, expand=2 as in the HF release."""
from repro.lm.config import LMConfig

CONFIG = LMConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    attn_every=8,          # 1 attention : 7 mamba
    n_experts=16,
    top_k=2,
    moe_mode="ep",         # 16 experts divide the 16-way model axis exactly
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    rope_theta=1_000_000.0,
)
