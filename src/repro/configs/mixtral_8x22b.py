"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]. 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, window 4096 (per the assignment's SWA designation)."""
from repro.lm.config import LMConfig

CONFIG = LMConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    n_experts=8,
    top_k=2,
    moe_mode="tp",
    sliding_window=4096,
)
