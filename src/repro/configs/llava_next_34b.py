"""llava-next-34b [vlm] — anyres tiling frontend is a STUB per the assignment
(input_specs supplies pre-fused patch+text embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 (Yi-34B backbone)."""
from repro.lm.config import LMConfig

CONFIG = LMConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    frontend="vision",
    rope_theta=5_000_000.0,
)
