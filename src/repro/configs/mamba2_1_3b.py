"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060;
unverified]. 48L d_model=2048, attention-free, d_ff=0, vocab=50280,
ssm_state=128, head_dim=64, expand=2."""
from repro.lm.config import LMConfig

CONFIG = LMConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
)
