"""Architecture registry: one module per assigned architecture (``--arch``)."""
from __future__ import annotations

from typing import Dict

from repro.lm.config import LMConfig

from repro.configs.jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from repro.configs.qwen2_72b import CONFIG as qwen2_72b
from repro.configs.qwen3_4b import CONFIG as qwen3_4b
from repro.configs.qwen2_0_5b import CONFIG as qwen2_0_5b
from repro.configs.internlm2_20b import CONFIG as internlm2_20b
from repro.configs.whisper_large_v3 import CONFIG as whisper_large_v3
from repro.configs.llava_next_34b import CONFIG as llava_next_34b
from repro.configs.grok_1_314b import CONFIG as grok_1_314b
from repro.configs.mixtral_8x22b import CONFIG as mixtral_8x22b
from repro.configs.mamba2_1_3b import CONFIG as mamba2_1_3b

ARCHS: Dict[str, LMConfig] = {
    c.name: c
    for c in [
        jamba_v0_1_52b,
        qwen2_72b,
        qwen3_4b,
        qwen2_0_5b,
        internlm2_20b,
        whisper_large_v3,
        llava_next_34b,
        grok_1_314b,
        mixtral_8x22b,
        mamba2_1_3b,
    ]
}


def get_arch(name: str) -> LMConfig:
    return ARCHS[name]


def reduced_config(cfg: LMConfig) -> LMConfig:
    """Same-family tiny config for CPU smoke tests (per assignment: small
    layers/width, few experts, tiny vocab)."""
    import dataclasses

    pattern = max(cfg.attn_every, 1)
    if cfg.is_hybrid:
        n_layers = pattern * 1  # one full hybrid block
    elif cfg.is_moe:
        n_layers = 2
    else:
        n_layers = 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=64,
        n_heads=max(4, 0) if cfg.n_heads else 0,
        n_kv_heads=2 if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=4 if cfg.is_moe else 0,
        top_k=2 if cfg.is_moe else 2,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        encoder_layers=1 if cfg.encoder_layers else 0,
        encoder_seq=12 if cfg.encoder_seq else 0,
        learned_pos=64 if cfg.learned_pos else 0,
        sliding_window=16 if cfg.sliding_window else 0,
        remat=False,
    )
