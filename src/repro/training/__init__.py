from repro.training.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.training.eval import evaluate, filtered_ranks
from repro.training.loop import NGDBTrainer, TrainConfig
from repro.training.loss import negative_sampling_loss
from repro.training.optim import AdamConfig, adam_init, adam_update, global_norm

__all__ = [
    "NGDBTrainer",
    "TrainConfig",
    "AdamConfig",
    "adam_init",
    "adam_update",
    "global_norm",
    "negative_sampling_loss",
    "evaluate",
    "filtered_ranks",
    "CheckpointManager",
    "save_checkpoint",
    "load_checkpoint",
]
