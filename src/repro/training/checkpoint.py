"""Fault-tolerant checkpointing.

Design constraints for 1000+ node runs:
  * atomic   — write to temp, fsync, rename; a crash mid-write never corrupts
               the latest checkpoint.
  * verified — manifest with per-array SHA256; load refuses silent bitrot and
               falls back to the previous valid checkpoint.
  * elastic  — arrays are stored UNSHARDED (host numpy). Restore reshards onto
               whatever mesh is alive, so a job can come back on a different
               pod count after failures (mesh-shape-agnostic restore).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, np.asarray(leaf)))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(tree)
    name = f"ckpt_{step:010d}"
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".{name}.tmp")
    manifest = {"step": step, "time": time.time(), "metadata": metadata or {}, "arrays": {}}
    arrays = {}
    for key, arr in flat:
        arrays[key] = arr
        manifest["arrays"][key] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    final = os.path.join(directory, name)
    if os.path.exists(final):  # same step already published (e.g. final save)
        shutil.rmtree(tmp, ignore_errors=True)
        return final
    os.rename(tmp, final)  # atomic publish
    return final


def _verify(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            for key, info in manifest["arrays"].items():
                arr = z[key]
                if hashlib.sha256(arr.tobytes()).hexdigest() != info["sha256"]:
                    return False
        return True
    except Exception:
        return False


def list_checkpoints(directory: str) -> List[str]:
    if not os.path.isdir(directory):
        return []
    names = sorted(n for n in os.listdir(directory) if n.startswith("ckpt_"))
    return [os.path.join(directory, n) for n in names]


def load_checkpoint(directory: str, template=None, shardings=None):
    """Load the newest VALID checkpoint. Returns (step, tree, metadata) or
    None. ``template`` restores pytree structure; ``shardings`` (a matching
    pytree of jax.sharding.Sharding) reshards onto the current mesh."""
    for path in reversed(list_checkpoints(directory)):
        if not _verify(path):
            continue  # corrupted (e.g. node died mid-write pre-rename) — skip
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        if template is None:
            return manifest["step"], arrays, manifest["metadata"]
        flat, treedef = _flatten(template)
        leaves = [arrays[k] for k, _ in flat]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(shardings)
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return manifest["step"], tree, manifest["metadata"]
    return None


class CheckpointManager:
    """Rolling checkpoints + auto-resume, with retention policy."""

    def __init__(self, directory: str, keep: int = 3, every: int = 100):
        self.directory = directory
        self.keep = keep
        self.every = every

    def maybe_save(self, step: int, tree, metadata=None, force=False) -> Optional[str]:
        if not force and (self.every <= 0 or step % self.every != 0):
            return None
        path = save_checkpoint(self.directory, step, tree, metadata)
        self._gc()
        return path

    def _gc(self) -> None:
        ckpts = list_checkpoints(self.directory)
        for old in ckpts[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    def restore(self, template=None, shardings=None):
        return load_checkpoint(self.directory, template, shardings)
