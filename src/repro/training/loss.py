"""Vectorized objective (Eq. 6): negative-sampling log-sigmoid ranking loss.

Scores are gamma - d(q, e); positives and K negatives are scored as one dense
[B, 1+K] block (the "vectorized logit formulation") rather than per-sample
lookups. Also exposes the per-query loss vector for adaptive sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def negative_sampling_loss(model, params, q_states, pos_ids, neg_ids):
    """q_states [B, sd], pos_ids [B], neg_ids [B, K] -> (mean loss, per-query)."""
    cand = jnp.concatenate([pos_ids[:, None], neg_ids], axis=1)   # [B, 1+K]
    scores = model.score_ids(params, q_states, cand)              # one fused block
    pos = scores[:, 0]
    neg = scores[:, 1:]
    per_query = -jax.nn.log_sigmoid(pos) - jnp.mean(jax.nn.log_sigmoid(-neg), axis=1)
    return jnp.mean(per_query), per_query
