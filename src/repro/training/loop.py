"""End-to-end NGDB training loop: online sampling → operator-level scheduling
→ fused execution → vectorized loss → Adam, with adaptive sampling, prefetch
pipelining and fault-tolerant checkpointing."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import PooledExecutor, PreparedBatch, QueryLevelExecutor
from repro.core.patterns import TEMPLATES
from repro.sampling.adaptive import AdaptiveDistribution, pattern_losses_from_batch
from repro.sampling.online import OnlineSampler, SampledQuery
from repro.training.checkpoint import CheckpointManager
from repro.training.loss import negative_sampling_loss
from repro.training.optim import AdamConfig, adam_init, adam_update


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 512           # queries (Table 5)
    n_negatives: int = 64
    b_max: int = 512
    adam: AdamConfig = dataclasses.field(default_factory=AdamConfig)
    patterns: Tuple[str, ...] = tuple(TEMPLATES)
    adaptive: bool = False
    executor: str = "pooled"        # pooled | query_level
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 200
    seed: int = 0
    prefetch: int = 2               # producer/consumer queue depth (0 = sync)


class NGDBTrainer:
    def __init__(self, model, kg, cfg: TrainConfig, semantic_table=None):
        self.model = model
        self.kg = kg
        self.cfg = cfg
        if cfg.executor == "pooled":
            self.executor = PooledExecutor(model, b_max=cfg.b_max)
        else:
            self.executor = QueryLevelExecutor(model, b_max=cfg.b_max)
            self.executor.encode_fn = None  # query-level path handled eagerly
        key = jax.random.PRNGKey(cfg.seed)
        self.params = model.init_params(
            key, kg.n_entities, kg.n_relations, semantic_table=semantic_table
        )
        self.opt_state = adam_init(self.params)
        self.sampler = OnlineSampler(kg, patterns=cfg.patterns, seed=cfg.seed)
        self.adaptive = AdaptiveDistribution(cfg.patterns) if cfg.adaptive else None
        self.ckpt = (
            CheckpointManager(cfg.checkpoint_dir, every=cfg.checkpoint_every)
            if cfg.checkpoint_dir
            else None
        )
        self.step = 0
        self._train_fns: Dict[Tuple, callable] = {}
        self.history: List[Dict] = []

    # ------------------------------------------------------------------ fns
    def _train_fn(self, prepared: PreparedBatch):
        sig = prepared.signature
        fn = self._train_fns.get(sig)
        if fn is not None:
            return fn
        model, cfg = self.model, self.cfg
        encode = self.executor.encode_fn(prepared)

        def step_fn(params, opt_state, steps, ans_slots, pos, neg):
            def loss_fn(p):
                q = encode(p, steps, ans_slots)
                return negative_sampling_loss(model, p, q, pos, neg)

            (loss, per_q), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, opt_state = adam_update(grads, opt_state, params, cfg.adam)
            return params, opt_state, loss, per_q

        fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self._train_fns[sig] = fn
        return fn

    # ----------------------------------------------------------------- steps
    def train_step(self, batch: Optional[List[SampledQuery]] = None) -> Dict[str, float]:
        if batch is None:
            dist = self.adaptive.distribution() if self.adaptive else None
            batch = self.sampler.sample_batch(self.cfg.batch_size, dist)
        queries, pos, neg = self.sampler.to_training_arrays(batch, self.cfg.n_negatives)
        t0 = time.perf_counter()
        if isinstance(self.executor, PooledExecutor):
            prepared = self.executor.prepare(queries)
            pos = pos[prepared.order]
            neg = neg[prepared.order]
            fn = self._train_fn(prepared)
            steps, ans = prepared.device_args()
            self.params, self.opt_state, loss, per_q = fn(
                self.params, self.opt_state, steps, ans, jnp.asarray(pos), jnp.asarray(neg)
            )
            patterns = prepared.patterns
        else:  # query-level baseline: one fragmented pass per pattern group
            loss, per_q, patterns = self._query_level_step(queries, pos, neg)
        loss = float(loss)
        if self.adaptive:
            self.adaptive.update(pattern_losses_from_batch(patterns, per_q))
        self.step += 1
        rec = {
            "step": self.step,
            "loss": loss,
            "queries_per_sec": len(queries) / max(time.perf_counter() - t0, 1e-9),
        }
        self.history.append(rec)
        if self.ckpt:
            self.ckpt.maybe_save(
                self.step,
                {"params": self.params, "opt": self.opt_state},
                metadata={"loss": loss},
            )
        return rec

    def _qlevel_grad_fn(self, prepared):
        """Jitted per-pattern-group loss+grad — the baseline frameworks jit
        each isomorphic group too; only the BATCHING granularity differs."""
        sig = ("ql",) + prepared.signature
        fn = self._train_fns.get(sig)
        if fn is not None:
            return fn
        encode = self.executor._inner.encode_fn(prepared)
        model = self.model

        def gfn(params, steps, ans, pos, neg):
            def loss_fn(p):
                qs = encode(p, steps, ans)
                return negative_sampling_loss(model, p, qs, pos, neg)

            (loss, per_q), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            return loss, per_q, grads

        fn = jax.jit(gfn)
        self._train_fns[sig] = fn
        return fn

    def _query_level_step(self, queries, pos, neg):
        """Baseline: independent fragmented train micro-steps per pattern."""
        inner: PooledExecutor = self.executor._inner
        if not hasattr(self, "_adam_jit"):
            cfg = self.cfg.adam
            self._adam_jit = jax.jit(
                lambda g, s, p: adam_update(g, s, p, cfg), donate_argnums=(1, 2))
        groups: Dict[str, List[int]] = {}
        for i, q in enumerate(queries):
            groups.setdefault(q.pattern, []).append(i)
        total, n = 0.0, 0
        per_q_all, patterns = [], []
        grads_acc = None
        for pat, idxs in groups.items():
            sub = [queries[i] for i in idxs]
            prepared = inner.prepare(sub)
            fn = self._qlevel_grad_fn(prepared)
            steps, ans = prepared.device_args()
            loss, per_q, grads = fn(self.params, steps, ans,
                                    jnp.asarray(pos[idxs][prepared.order]),
                                    jnp.asarray(neg[idxs][prepared.order]))
            w = len(idxs)
            grads_acc = (
                grads
                if grads_acc is None
                else jax.tree.map(lambda a, b: a + b * w, grads_acc, grads)
            )
            if grads_acc is grads:
                grads_acc = jax.tree.map(lambda g: g * w, grads_acc)
            total += float(loss) * w
            n += w
            per_q_all.extend(np.asarray(per_q).tolist())
            patterns.extend([pat] * w)
        grads_acc = jax.tree.map(lambda g: g / n, grads_acc)
        self.params, self.opt_state = self._adam_jit(
            grads_acc, self.opt_state, self.params)
        return total / n, np.array(per_q_all), patterns

    # ------------------------------------------------------------------ loop
    def train(self, n_steps: int, log_every: int = 50, prefetcher=None) -> List[Dict]:
        from repro.data.pipeline import BatchPrefetcher

        own = None
        if prefetcher is None and self.cfg.prefetch > 0 and not self.adaptive:
            own = prefetcher = BatchPrefetcher(
                self.sampler, self.cfg.batch_size, depth=self.cfg.prefetch
            )
        try:
            for i in range(n_steps):
                batch = prefetcher.next() if prefetcher else None
                rec = self.train_step(batch)
                if log_every and (i + 1) % log_every == 0:
                    print(
                        f"step {rec['step']:6d} loss {rec['loss']:.4f} "
                        f"q/s {rec['queries_per_sec']:.0f}"
                    )
        finally:
            if own is not None:
                own.close()
        if self.ckpt:
            self.ckpt.maybe_save(
                self.step, {"params": self.params, "opt": self.opt_state}, force=True
            )
        return self.history

    # ---------------------------------------------------------------- resume
    def resume(self) -> bool:
        if not self.ckpt:
            return False
        restored = self.ckpt.restore(template={"params": self.params, "opt": self.opt_state})
        if restored is None:
            return False
        self.step, tree, _ = restored
        self.params, self.opt_state = tree["params"], tree["opt"]
        return True
