"""End-to-end NGDB training loop: online sampling → operator-level scheduling
→ fused execution → vectorized loss → Adam, with adaptive sampling, prefetch
pipelining and fault-tolerant checkpointing.

Two execution modes (DESIGN.md §Pipeline):

* **sync** (``pipeline=False``, the ablation baseline): each step runs
  sampling → Algorithm-1 scheduling → device step → blocking loss readback
  strictly in sequence, so the host idles during device execution and the
  device idles during host scheduling.
* **pipelined** (``pipeline=True``): background threads run the host side —
  sampling workers (or a deterministic batch pump) feeding one scheduler
  thread that samples negatives, canonicalizes and runs Algorithm-1
  scheduling for batch *k+1* while batch *k* executes on device. The main
  thread dispatches jitted step programs (XLA executes with the GIL
  released, so host stages continue underneath) and retires finished steps
  from a bounded in-flight window (``max_inflight``, i.e. double-buffered
  for the default of 2).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile_cache import CompileCache
from repro.core.executor import PooledExecutor, QueryLevelExecutor
from repro.core.plan import CompiledPlan
from repro.data.pipeline import batch_entity_ids
from repro.core.patterns import TEMPLATES
from repro.obs.registry import get_registry
from repro.obs.sink import MetricsSink
from repro.obs.trace import TRACER
from repro.sampling.adaptive import AdaptiveDistribution, pattern_losses_from_batch
from repro.sampling.online import OnlineSampler, SampledQuery
from repro.training.checkpoint import CheckpointManager
from repro.training.loss import negative_sampling_loss
from repro.training.optim import AdamConfig, adam_init, adam_update


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 512           # queries (Table 5)
    n_negatives: int = 64
    b_max: int = 512
    adam: AdamConfig = dataclasses.field(default_factory=AdamConfig)
    patterns: Tuple[str, ...] = tuple(TEMPLATES)
    adaptive: bool = False
    executor: str = "pooled"        # pooled | query_level
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 200
    seed: int = 0
    prefetch: int = 2               # producer/consumer queue depth (0 = sync)
    pipeline: bool = False          # overlap host scheduling w/ device steps
    max_inflight: int = 2           # pipelined: bounded dispatch window
    compile_cache_size: int = 128   # LRU capacity for jitted step programs
    gil_switch_interval: float = 2e-3  # pipelined: bound GIL handoff latency
    cse: bool = True                # cross-query subexpression sharing
    #                                 (False = --no-cse ablation baseline)
    materialized_rows: int = 0      # >0: attach a MaterializedSubqueryCache
    #                                 of that many rows to the pooled
    #                                 executor's eval/encode path (training
    #                                 gradients never consume cached rows)
    metrics_path: Optional[str] = None  # JSONL step-time breakdown sink
    #                                 (per-step phase durations + bubble
    #                                 fraction; None = disabled, zero cost)


def incremental_finetune(model, params, triples, *, steps: int = 4,
                         lr: float = 1e-3, n_negatives: int = 8,
                         seed: int = 0, b_max: int = 64, executor=None):
    """Incremental embedding maintenance for a live KG write (DESIGN.md
    §LiveStore): a few Adam steps of 1p link-prediction loss on exactly the
    written triples, touching the written neighborhood instead of
    retraining from scratch. Returns ``(new_params, losses)``.

    Deterministic by construction — a pure function of (params, triples,
    hyperparams, seed): negatives come from a seeded generator, the batch
    is canonicalized by the same plan compiler as training, and the jitted
    step does NOT donate its inputs — the caller's params are typically the
    serving engine's LIVE weights, concurrently read by the batcher thread,
    so they must survive this call unchanged. The background maintenance
    thread and a synchronous oracle rerun therefore produce bitwise-
    identical params, which ``benchmarks/live.py`` gates."""
    triples = np.asarray(triples, dtype=np.int64).reshape(-1, 3)
    if len(triples) == 0:
        return params, []
    from repro.core.patterns import QueryInstance

    executor = executor or PooledExecutor(model, b_max=b_max)
    queries = [QueryInstance("1p", np.array([h]), np.array([r]))
               for h, r, _ in triples]
    pos = np.ascontiguousarray(triples[:, 2])
    rng = np.random.default_rng(seed)
    n_ent = model.n_entities
    neg = rng.integers(0, n_ent, size=(len(pos), n_negatives))
    clash = neg == pos[:, None]
    while clash.any():
        neg[clash] = rng.integers(0, n_ent, size=int(clash.sum()))
        clash = neg == pos[:, None]
    prepared = executor.prepare(queries)
    pos = pos[prepared.order]
    neg = neg[prepared.order]
    step_arrays, ans = prepared.device_args()
    encode = executor.encode_fn(prepared)
    adam_cfg = AdamConfig(lr=lr)
    frozen_names = set(model.frozen_param_names())

    def step_fn(params, opt_state, steps_in, ans_slots, pos_in, neg_in):
        trainable = {k: v for k, v in params.items()
                     if k not in frozen_names}
        frozen = {k: v for k, v in params.items() if k in frozen_names}

        def loss_fn(t):
            p = {**t, **frozen}
            q = encode(p, steps_in, ans_slots)
            return negative_sampling_loss(model, p, q, pos_in, neg_in)

        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
        grads = {**grads,
                 **{k: jnp.zeros((1,), jnp.float32) for k in frozen}}
        params, opt_state = adam_update(grads, opt_state, params, adam_cfg)
        return params, opt_state, loss

    fn = jax.jit(step_fn)
    opt_state = adam_init(params, adam_cfg)
    losses: List[float] = []
    pos_j, neg_j = jnp.asarray(pos), jnp.asarray(neg)
    for _ in range(steps):
        params, opt_state, loss = fn(params, opt_state, step_arrays, ans,
                                     pos_j, neg_j)
        losses.append(float(loss))
    return params, losses


class NGDBTrainer:
    def __init__(self, model, kg, cfg: TrainConfig, semantic_table=None,
                 semantic_cache=None, ctx=None):
        from repro.distributed.context import ExecutionContext

        self.model = model
        self.kg = kg
        self.cfg = cfg
        # Placement policy (DESIGN.md §Sharding): params/Adam state live in
        # their NamedShardings, batches shard over the data axes, and the
        # fused step compiles with explicit in/out shardings. The default
        # single-device context makes every placement hook a no-op.
        self.ctx = ctx or ExecutionContext.single_device()
        # Materialized subquery rows are an inference-side cache: the fused
        # train step never reads them (a constant row would detach the
        # gradient), but executor.encode() on the eval path does, and they
        # must be invalidated on every param update / KG write (bumps below).
        self.mat_cache = None
        if cfg.materialized_rows > 0 and cfg.executor == "pooled":
            from repro.core.matcache import MaterializedSubqueryCache

            self.mat_cache = MaterializedSubqueryCache(cfg.materialized_rows)
            self.mat_cache.watch_kg(kg)
        if cfg.executor == "pooled":
            self.executor = PooledExecutor(model, b_max=cfg.b_max,
                                           cache_size=cfg.compile_cache_size,
                                           ctx=self.ctx, cse=cfg.cse,
                                           mat_cache=self.mat_cache)
        else:
            self.executor = QueryLevelExecutor(model, b_max=cfg.b_max,
                                               ctx=self.ctx)
        # Out-of-core semantic mode (semantic/store.py): the params carry a
        # bounded device hot set + indirection instead of the full H_sem;
        # every batch's rows are staged (plan/apply_to) before dispatch.
        self.sem_cache = semantic_cache
        key = jax.random.PRNGKey(cfg.seed)
        self.params = model.init_params(
            key, kg.n_entities, kg.n_relations, semantic_table=semantic_table,
            semantic_cache=semantic_cache, ctx=self.ctx,
        )
        self.opt_state = adam_init(self.params, cfg.adam, ctx=self.ctx)
        # Shardings the fused step is compiled against (None single-device).
        self._param_sh = self.ctx.param_shardings(self.params)
        self._opt_sh = self.ctx.param_shardings(self.opt_state)
        self.sampler = OnlineSampler(kg, patterns=cfg.patterns, seed=cfg.seed)
        self.adaptive = AdaptiveDistribution(cfg.patterns) if cfg.adaptive else None
        self.ckpt = (
            CheckpointManager(cfg.checkpoint_dir, every=cfg.checkpoint_every)
            if cfg.checkpoint_dir
            else None
        )
        self.step = 0
        self._train_fns = CompileCache(cfg.compile_cache_size, name="train_step")
        self.history: List[Dict] = []
        # Step-time telemetry (DESIGN.md §Observability): cumulative
        # main-thread phase seconds + the per-step JSONL sink. The sink is a
        # no-op object when metrics_path is None, so instrumented paths need
        # no gating.
        self._obs = get_registry().group("trainer")
        self._steps_done = self._obs.counter("steps")
        self._phase_s = {
            name: self._obs.counter("phase_seconds", phase=name)
            for name in ("pipeline_wait", "sem_apply", "compile", "dispatch",
                         "retire")}
        self._inflight_gauge = self._obs.gauge("inflight")
        self.metrics_sink = MetricsSink(cfg.metrics_path)

    # ------------------------------------------------------------------ fns
    def _split_frozen(self, params):
        """(trainable, frozen) views of the params dict. Frozen buffers —
        H_sem in either layout, including the int32 cache indirection which
        could not be differentiated at all — are closed over by the loss, so
        XLA never materializes gradients for them (at d_l=1024 a sem_table
        cotangent would double the largest buffer in the step)."""
        frozen_names = set(self.model.frozen_param_names())
        trainable = {k: v for k, v in params.items() if k not in frozen_names}
        frozen = {k: v for k, v in params.items() if k in frozen_names}
        return trainable, frozen

    def _train_fn(self, prepared: CompiledPlan, example=None):
        """Jitted fused step for ``prepared``'s signature. ``example`` is the
        (steps, ans, pos, neg) the step will be called with — under a mesh
        context their SHAPES pick the batch in_shardings, so the program is
        compiled against exactly the layout the pipeline stages arrays into
        (signature-keyed cache: same signature ⇒ same bucketed shapes ⇒ same
        shardings, so the example never fragments the cache).

        The loss consumes the plan's per-query answer map (``ans_slots``):
        with CSE, queries sharing their full tree alias the same workspace
        row, the encode-final gather fans that row out per query, and
        reverse-mode AD sums the per-query cotangents into the shared node —
        gradients through shared subexpressions need no special handling."""
        sig = prepared.signature
        fn = self._train_fns.get(sig)
        if fn is not None:
            return fn
        model, cfg = self.model, self.cfg
        encode = self.executor.encode_fn(prepared)

        def step_fn(params, opt_state, steps, ans_slots, pos, neg):
            trainable, frozen = self._split_frozen(params)

            def loss_fn(t):
                p = {**t, **frozen}
                q = encode(p, steps, ans_slots)
                return negative_sampling_loss(model, p, q, pos, neg)

            (loss, per_q), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
            # Token gradients for frozen leaves keep the pytree aligned with
            # params/opt_state; adam_update skips them by name.
            grads = {**grads, **{k: jnp.zeros((1,), jnp.float32) for k in frozen}}
            params, opt_state = adam_update(grads, opt_state, params, cfg.adam)
            return params, opt_state, loss, per_q

        jit_kwargs = {}
        if self.ctx.is_sharded and example is not None:
            steps, ans, pos, neg = example
            rep = self.ctx.replicated()
            jit_kwargs = dict(
                # params + Adam state per tree_param_shardings; batch arrays
                # over the data axes; loss and per-query aux replicated (both
                # are read back on the host every retire).
                in_shardings=(self._param_sh, self._opt_sh,
                              self.ctx.batch_shardings(steps),
                              self.ctx.batch_sharding(np.shape(ans)),
                              self.ctx.batch_sharding(np.shape(pos)),
                              self.ctx.batch_sharding(np.shape(neg))),
                out_shardings=(self._param_sh, self._opt_sh, rep, rep),
            )
        fn = jax.jit(step_fn, donate_argnums=self.ctx.donate_argnums(0, 1),
                     **jit_kwargs)
        self._train_fns.put(sig, fn)
        return fn

    def compile_cache_stats(self) -> Dict[str, Dict[str, float]]:
        """Counters for every signature-keyed cache in the engine."""
        out = {"train_step": self._train_fns.stats()}
        out.update(self.executor.cache_stats())
        if self.sem_cache is not None:
            out["sem_cache"] = self.sem_cache.stats()
        return out

    # ----------------------------------------------------------------- steps
    def train_step(self, batch: Optional[List[SampledQuery]] = None) -> Dict[str, float]:
        if batch is None:
            dist = self.adaptive.distribution() if self.adaptive else None
            with TRACER.span("sample", n=self.cfg.batch_size):
                batch = self.sampler.sample_batch(self.cfg.batch_size, dist)
        queries, pos, neg = self.sampler.to_training_arrays(batch, self.cfg.n_negatives)
        phases: Dict[str, float] = {}
        if self.sem_cache is not None:
            # Sync mode stages on the critical path (the pipelined loop does
            # this on the scheduler thread instead — zero mid-step reads).
            tp = time.perf_counter()
            with TRACER.span("sem_prefetch"):
                stage = self.sem_cache.plan(batch_entity_ids(queries, pos, neg))
            if stage is not None:
                self.params = self.sem_cache.apply_to(self.params, stage)
            phases["sem_prefetch_s"] = time.perf_counter() - tp
        t0 = time.perf_counter()
        if isinstance(self.executor, PooledExecutor):
            with TRACER.span("schedule", n=len(queries)):
                prepared = self.executor.prepare(queries)
            phases["schedule_s"] = time.perf_counter() - t0
            pos = pos[prepared.order]
            neg = neg[prepared.order]
            steps, ans = prepared.device_args()
            # A signature absent from the cache means THIS dispatch pays the
            # jit trace+compile — label the span accordingly.
            cold = prepared.signature not in self._train_fns
            fn = self._train_fn(prepared, example=(steps, ans, pos, neg))
            td = time.perf_counter()
            # pos/neg go in as host numpy: the jit places them per its
            # in_shardings (one transfer straight into the compiled layout);
            # a jnp.asarray here would commit to device 0 first and force a
            # second reshard transfer at dispatch under a mesh ctx.
            with TRACER.span("compile" if cold else "dispatch"):
                self.params, self.opt_state, loss, per_q = fn(
                    self.params, self.opt_state, steps, ans, pos, neg
                )
            phases["compile_s" if cold else "dispatch_s"] = (
                time.perf_counter() - td)
            self._phase_s["compile" if cold else "dispatch"].inc(
                phases["compile_s" if cold else "dispatch_s"])
            patterns = prepared.patterns
        else:  # query-level baseline: one fragmented pass per pattern group
            loss, per_q, patterns = self._query_level_step(queries, pos, neg)
        if self.mat_cache is not None:
            # params handle just advanced — rows encoded under the old
            # params must never be served (or inserted: version pinning in
            # insert() drops in-flight encodes started before this bump).
            self.mat_cache.bump_version("param_update")
        tr = time.perf_counter()
        with TRACER.span("retire"):
            loss = float(loss)
        phases["retire_s"] = time.perf_counter() - tr
        self._phase_s["retire"].inc(phases["retire_s"])
        self._steps_done.inc()
        if self.adaptive:
            self.adaptive.update(pattern_losses_from_batch(patterns, per_q))
        self.step += 1
        rec = {
            "step": self.step,
            "loss": loss,
            "queries_per_sec": len(queries) / max(time.perf_counter() - t0, 1e-9),
        }
        self.history.append(rec)
        if self.metrics_sink.enabled:
            # Separate record, not extra keys on rec: history is compared
            # across runs by tests/benchmarks and must not change shape.
            self.metrics_sink.write({"kind": "step", "mode": "sync", **rec,
                                     **phases})
        if self.ckpt:
            self.ckpt.maybe_save(
                self.step,
                {"params": self.params, "opt": self.opt_state},
                metadata={"loss": loss},
            )
        return rec

    def _qlevel_grad_fn(self, prepared):
        """Jitted per-pattern-group loss+grad — the baseline frameworks jit
        each isomorphic group too; only the BATCHING granularity differs."""
        sig = ("ql",) + prepared.signature
        fn = self._train_fns.get(sig)
        if fn is not None:
            return fn
        encode = self.executor.encode_fn(prepared)
        model = self.model

        def gfn(params, steps, ans, pos, neg):
            trainable, frozen = self._split_frozen(params)

            def loss_fn(t):
                p = {**t, **frozen}
                qs = encode(p, steps, ans)
                return negative_sampling_loss(model, p, qs, pos, neg)

            (loss, per_q), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
            grads = {**grads, **{k: jnp.zeros((1,), jnp.float32) for k in frozen}}
            return loss, per_q, grads

        fn = jax.jit(gfn)
        self._train_fns.put(sig, fn)
        return fn

    def _query_level_step(self, queries, pos, neg):
        """Baseline: independent fragmented train micro-steps per pattern."""
        if not hasattr(self, "_adam_jit"):
            cfg = self.cfg.adam
            self._adam_jit = jax.jit(
                lambda g, s, p: adam_update(g, s, p, cfg), donate_argnums=(1, 2))
        groups: Dict[str, List[int]] = {}
        for i, q in enumerate(queries):
            groups.setdefault(q.pattern, []).append(i)
        total, n = 0.0, 0
        per_q_all, patterns = [], []
        grads_acc = None
        for pat, idxs in groups.items():
            sub = [queries[i] for i in idxs]
            prepared = self.executor.prepare(sub)
            fn = self._qlevel_grad_fn(prepared)
            steps, ans = prepared.device_args()
            loss, per_q, grads = fn(self.params, steps, ans,
                                    jnp.asarray(pos[idxs][prepared.order]),
                                    jnp.asarray(neg[idxs][prepared.order]))
            w = len(idxs)
            grads_acc = (
                grads
                if grads_acc is None
                else jax.tree.map(lambda a, b: a + b * w, grads_acc, grads)
            )
            if grads_acc is grads:
                grads_acc = jax.tree.map(lambda g: g * w, grads_acc)
            total += float(loss) * w
            n += w
            per_q_all.extend(np.asarray(per_q).tolist())
            patterns.extend([pat] * w)
        grads_acc = jax.tree.map(lambda g: g / n, grads_acc)
        self.params, self.opt_state = self._adam_jit(
            grads_acc, self.opt_state, self.params)
        return total / n, np.array(per_q_all), patterns

    # ------------------------------------------------------------------ loop
    def train(self, n_steps: int, log_every: int = 50, prefetcher=None,
              batches=None) -> List[Dict]:
        """Run ``n_steps``. ``batches`` pins the workload — a fixed batch
        list (cycled) or a zero-arg callable yielding batches (e.g. a seeded
        sampler stream) — so benchmarks/tests can feed sync and pipelined
        modes the SAME batches; otherwise batches come from the online
        sampler."""
        if self.cfg.pipeline and isinstance(self.executor, PooledExecutor):
            return self._train_pipelined(n_steps, log_every, batches=batches)

        TRACER.set_lane("main dispatch")
        from repro.data.pipeline import BatchPrefetcher

        own = None
        if (prefetcher is None and batches is None and self.cfg.prefetch > 0
                and not self.adaptive):
            own = prefetcher = BatchPrefetcher(
                self.sampler, self.cfg.batch_size, depth=self.cfg.prefetch
            )
        try:
            for i in range(n_steps):
                if callable(batches):
                    batch = batches()
                elif batches is not None:
                    batch = batches[i % len(batches)]
                else:
                    batch = prefetcher.next() if prefetcher else None
                rec = self.train_step(batch)
                if log_every and (i + 1) % log_every == 0:
                    print(
                        f"step {rec['step']:6d} loss {rec['loss']:.4f} "
                        f"q/s {rec['queries_per_sec']:.0f}"
                    )
        finally:
            if own is not None:
                own.close()
        if self.ckpt:
            self.ckpt.maybe_save(
                self.step, {"params": self.params, "opt": self.opt_state}, force=True
            )
        return self.history

    # ------------------------------------------------------------- pipelined
    def _retire(self, pending, t_last: float, log_every: int) -> float:
        """Block on one in-flight step's loss, fold its metrics into history.

        ``pending`` carries a snapshot of the (params, opt_state) produced BY
        the retired step when that step lands on a checkpoint boundary, so
        the checkpoint is labeled with the step whose parameters it actually
        contains — ``self.params`` may already belong to a later dispatched
        step, and the retired step's own outputs are donated into the next
        dispatch (hence the explicit copy at dispatch time)."""
        loss, per_q, patterns, n_queries, snap, phases = pending
        tr = time.perf_counter()
        with TRACER.span("retire"):
            loss = float(loss)  # sync point: waits for that device step only
        now = time.perf_counter()
        phases["retire_s"] = now - tr
        self._phase_s["retire"].inc(phases["retire_s"])
        if self.adaptive:
            self.adaptive.update(pattern_losses_from_batch(patterns, per_q))
        self.step += 1
        self._steps_done.inc()
        rec = {
            "step": self.step,
            "loss": loss,
            "queries_per_sec": n_queries / max(now - t_last, 1e-9),
        }
        self.history.append(rec)
        if self.metrics_sink.enabled:
            # Bubble fraction: main-thread time spent WAITING for the
            # prefetcher (pf.next) over this step's wall time — the share of
            # the loop the pipeline failed to hide host work in. Retire
            # (device sync) is reported separately: a big retire_s means the
            # DEVICE is the bottleneck, which is the pipeline working.
            wall = max(now - t_last, 1e-9)
            self.metrics_sink.write({
                "kind": "step", "mode": "pipelined", **rec, **phases,
                "bubble_frac": min(phases.get("wait_s", 0.0) / wall, 1.0),
                "wall_s": wall,
            })
        if log_every and self.step % log_every == 0:
            print(f"step {rec['step']:6d} loss {rec['loss']:.4f} "
                  f"q/s {rec['queries_per_sec']:.0f}")
        if self.ckpt and snap is not None:
            params, opt_state = snap
            self.ckpt.maybe_save(
                self.step,
                {"params": params, "opt": opt_state},
                metadata={"loss": loss},
            )
        return now

    def _train_pipelined(self, n_steps: int, log_every: int,
                         batches=None) -> List[Dict]:
        """Dataflow mode (DESIGN.md §Pipeline).

        Host stages run on background threads (sampling workers — or a batch
        pump for a deterministic source — feeding one scheduler thread that
        builds fully device-ready work items). The main thread dispatches
        the jitted step program (XLA executes with the GIL released, so the
        host stages keep running underneath) and retires finished steps from
        a bounded in-flight window (``max_inflight``, default 2 = double
        buffered): a step's loss is only read back once it leaves the
        window, so metric readback never stalls dispatch."""
        from repro.data.pipeline import PreparedBatchPrefetcher

        batch_fn = None
        if callable(batches):
            batch_fn = batches
        elif batches is not None:
            it = itertools.cycle(batches)
            batch_fn = lambda: next(it)  # noqa: E731 — single pump thread
        elif self.adaptive:
            # Adaptive needs the latest distribution at sample time; sample in
            # the pump thread with a (≤ max_inflight steps) stale π.
            batch_fn = lambda: self.sampler.sample_batch(  # noqa: E731
                self.cfg.batch_size, self.adaptive.distribution())
        pf = PreparedBatchPrefetcher(
            self.sampler, self.executor, self.cfg.batch_size,
            self.cfg.n_negatives, depth=max(self.cfg.prefetch, 1),
            batch_fn=batch_fn, sem_cache=self.sem_cache, ctx=self.ctx,
            mat_cache=self.mat_cache,
        )
        # The main thread re-acquires the GIL every time a jit call returns
        # from (GIL-free) XLA execution; the default 5 ms switch interval
        # makes each re-acquisition wait on whichever host stage holds the
        # GIL. Tightening it while pipeline threads are live keeps dispatch
        # latency bounded; restored on exit.
        import sys as _sys

        old_switch = _sys.getswitchinterval()
        if self.cfg.gil_switch_interval:
            _sys.setswitchinterval(self.cfg.gil_switch_interval)
        inflight: deque = deque()
        t_last = time.perf_counter()
        TRACER.set_lane("main dispatch")
        try:
            for _ in range(n_steps):
                tw = time.perf_counter()
                # This wait IS the pipeline bubble: the prefetcher had no
                # ready item, so the main thread idles instead of dispatching.
                with TRACER.span("pipeline_wait"):
                    item = pf.next()
                wait_s = time.perf_counter() - tw
                item.phases["wait_s"] = wait_s
                self._phase_s["pipeline_wait"].inc(wait_s)
                if item.sem_stage is not None:
                    # The scheduler thread already did the store read +
                    # device put (overlapped with step k); this is just the
                    # donated scatter, enqueued after step k's program — the
                    # in-order device stream makes eviction of step k's rows
                    # safe even while k is still executing.
                    ta = time.perf_counter()
                    with TRACER.span("sem_apply"):
                        self.params = self.sem_cache.apply_to(self.params,
                                                              item.sem_stage)
                    item.phases["sem_apply_s"] = time.perf_counter() - ta
                    self._phase_s["sem_apply"].inc(item.phases["sem_apply_s"])
                cold = item.prepared.signature not in self._train_fns
                fn = self._train_fn(item.prepared,
                                    example=(item.steps, item.ans,
                                             item.pos, item.neg))
                td = time.perf_counter()
                with TRACER.span("compile" if cold else "dispatch"):
                    self.params, self.opt_state, loss, per_q = fn(
                        self.params, self.opt_state, item.steps, item.ans,
                        item.pos, item.neg,
                    )
                key = "compile" if cold else "dispatch"
                item.phases[key + "_s"] = time.perf_counter() - td
                self._phase_s[key].inc(item.phases[key + "_s"])
                if self.mat_cache is not None:
                    # Dispatch replaced the params handle; scheduler-thread
                    # probes pinned to the old version stop matching and any
                    # in-flight insert pinned to it is dropped.
                    self.mat_cache.bump_version("param_update")
                # Snapshot on checkpoint boundaries BEFORE the next dispatch
                # donates these buffers (jnp.copy enqueues ahead of donation).
                step_no = self.step + len(inflight) + 1
                snap = None
                if (self.ckpt and self.ckpt.every > 0
                        and step_no % self.ckpt.every == 0):
                    snap = jax.tree.map(jnp.copy,
                                        (self.params, self.opt_state))
                inflight.append((loss, per_q, item.patterns, item.n_queries,
                                 snap, item.phases))
                self._inflight_gauge.set(len(inflight))
                while len(inflight) >= max(self.cfg.max_inflight, 1):
                    t_last = self._retire(inflight.popleft(), t_last, log_every)
                    self._inflight_gauge.set(len(inflight))
            while inflight:
                t_last = self._retire(inflight.popleft(), t_last, log_every)
                self._inflight_gauge.set(len(inflight))
        finally:
            _sys.setswitchinterval(old_switch)
            pf.close()
            if self.sem_cache is not None:
                # Drained queue items may hold planned-but-unapplied stages;
                # drop residency metadata so future plans restage from disk.
                self.sem_cache.reconcile()
        if self.ckpt:
            self.ckpt.maybe_save(
                self.step, {"params": self.params, "opt": self.opt_state}, force=True
            )
        return self.history

    # ---------------------------------------------------------------- resume
    def resume(self) -> bool:
        if not self.ckpt:
            return False
        # Checkpoints store arrays UNSHARDED (host numpy); passing the
        # context's shardings reshards them onto whatever mesh THIS run has —
        # save on 8 devices, restore on 4 (mesh-shape-agnostic restore).
        shardings = None
        if self.ctx.is_sharded:
            shardings = {"params": self._param_sh, "opt": self._opt_sh}
        restored = self.ckpt.restore(
            template={"params": self.params, "opt": self.opt_state},
            shardings=shardings)
        if restored is None:
            return False
        self.step, tree, _ = restored
        self.params, self.opt_state = tree["params"], tree["opt"]
        if self.sem_cache is not None:
            # Restored cache buffers don't match whatever residency metadata
            # accumulated before resume; declare everything absent so the
            # next plan restages from the store into the restored buffers.
            self.sem_cache.reset()
        return True
