"""Filtered ranking metrics (MRR / Hits@k) for predictive query answering."""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import PooledExecutor
from repro.core.patterns import QueryInstance, answer_query
from repro.data.kg import KnowledgeGraph


def filtered_ranks(scores: np.ndarray, answers: np.ndarray) -> np.ndarray:
    """Rank of each answer with other answers filtered out. scores [E]."""
    order = np.argsort(-scores, kind="stable")
    rank_of = np.empty_like(order)
    rank_of[order] = np.arange(len(order)) + 1
    ans_ranks = rank_of[answers]
    # filter: subtract the number of *other* answers ranked above each answer
    sorted_ranks = np.sort(ans_ranks)
    filtered = sorted_ranks - np.arange(len(sorted_ranks))
    return filtered


def evaluate(
    model,
    params,
    executor: PooledExecutor,
    eval_kg: KnowledgeGraph,
    queries: Sequence[QueryInstance],
    train_kg: KnowledgeGraph = None,
    batch_size: int = 64,
    score_all_fn=None,
) -> Dict[str, float]:
    """Filtered MRR / Hits over the *full* graph answers. If ``train_kg`` is
    given, metrics are also split into easy (observed) vs hard (predictive)
    answers — the paper's A_obs vs A_miss distinction.

    ``score_all_fn`` overrides the dense all-entity scorer — the semantic-
    store path passes ``lambda p, q: model.score_all_chunked(p, q,
    store.read_rows)`` so evaluation streams H_sem from disk instead of
    requiring a full-resident table."""
    score_all = score_all_fn or jax.jit(model.score_all)
    mrr, h1, h3, h10, n = 0.0, 0.0, 0.0, 0.0, 0
    hard_mrr, hard_n = 0.0, 0
    per_pattern: Dict[str, List[float]] = {}
    for lo in range(0, len(queries), batch_size):
        chunk = list(queries[lo : lo + batch_size])
        states = executor.encode(params, chunk)
        scores = np.asarray(score_all(params, states))
        for i, q in enumerate(chunk):
            full_ans = np.fromiter(answer_query(eval_kg, q), dtype=np.int64)
            if len(full_ans) == 0:
                continue
            ranks = filtered_ranks(scores[i], full_ans)
            rr = 1.0 / ranks
            mrr += rr.sum()
            h1 += (ranks <= 1).sum()
            h3 += (ranks <= 3).sum()
            h10 += (ranks <= 10).sum()
            n += len(ranks)
            per_pattern.setdefault(q.pattern, []).append(float(rr.mean()))
            if train_kg is not None:
                easy = answer_query(train_kg, q)
                hard = np.array([a for a in full_ans if a not in easy], dtype=np.int64)
                if len(hard):
                    hr = filtered_ranks(scores[i], full_ans)
                    mask = np.isin(np.sort(full_ans), hard)
                    hard_mrr += (1.0 / hr[mask]).sum()
                    hard_n += len(hard)
    out = {
        "mrr": mrr / max(n, 1),
        "hits@1": h1 / max(n, 1),
        "hits@3": h3 / max(n, 1),
        "hits@10": h10 / max(n, 1),
        "n": float(n),
    }
    if train_kg is not None and hard_n:
        out["hard_mrr"] = hard_mrr / hard_n
    for p, vals in per_pattern.items():
        out[f"mrr/{p}"] = float(np.mean(vals))
    return out
