"""Adam optimizer in pure JAX (no optax in this environment), with frozen-
parameter masking (the GPU-resident H_sem buffer must receive no gradients)
and global-norm clipping."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-4           # Table 5
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 0.0     # 0 = off
    # H_sem in either layout: full-resident table, or hot-set cache buffer +
    # its int32 entity->slot indirection (semantic/store.py::SemanticCache).
    frozen: Tuple[str, ...] = ("sem_table", "sem_cache", "sem_slot")


def _is_frozen(path: Tuple, frozen: Tuple[str, ...]) -> bool:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    return any(str(n) in frozen for n in names)


def adam_init(params, cfg: AdamConfig = AdamConfig(), ctx=None):
    """Frozen buffers (e.g. the H_sem table) get token-sized moment slots:
    they receive no updates, so real m/v would be pure HBM waste (§Perf
    iteration N2 — 2x the H_sem bytes on every device).

    ``ctx`` (an ``ExecutionContext``) places the moments per
    ``tree_param_shardings`` — the same rule table as the params they mirror,
    so under FSDP the Adam state scales 1/N with the tables. ``zeros_like``
    of a sharded param already inherits its sharding; the explicit put makes
    the layout an invariant rather than an inference."""

    def zeros(path, p):
        if _is_frozen(path, cfg.frozen):
            return jnp.zeros((1,), p.dtype)
        return jnp.zeros_like(p)

    state = {
        "m": jax.tree_util.tree_map_with_path(zeros, params),
        "v": jax.tree_util.tree_map_with_path(zeros, params),
        "step": jnp.zeros((), dtype=jnp.int32),
    }
    if ctx is not None and ctx.is_sharded:
        state = jax.device_put(state, ctx.param_shardings(state))
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in leaves))


def adam_update(grads, state, params, cfg: AdamConfig = AdamConfig()):
    step = state["step"] + 1
    if cfg.clip_norm > 0:
        g_norm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / (g_norm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        if _is_frozen(path, cfg.frozen):
            return p, m, v
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1t
        vhat = v / b2t
        new_p = p - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return new_p, m, v

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    gs = jax.tree.leaves(grads)
    ms = jax.tree.leaves(state["m"])
    vs = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat, gs, ms, vs):
        a, b, c = upd(path, p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "step": step,
        },
    )
