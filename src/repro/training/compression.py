"""Int8 gradient compression with error feedback for slow (inter-pod) links.

Quantize → all-reduce(int32) → dequantize, with a persistent error-feedback
accumulator so compression noise is re-injected next step instead of lost
(convergence-neutral in expectation). Intended for the ``pod`` mesh axis,
whose ICI/DCN links are the collective bottleneck at multi-pod scale."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jnp.ndarray, axis_name: str, error: jnp.ndarray):
    """Inside shard_map: returns (mean-reduced grad, new error feedback).

    The int8 payload is 4x smaller than fp32 on the wire; scales are reduced
    separately (scalar). Error feedback keeps the quantization residual local.
    """
    g = grad + error
    q, scale = quantize_int8(g)
    local = dequantize_int8(q, scale)
    new_error = g - local
    # Reduce the quantized values at int32 precision, then rescale by the
    # max scale across the axis (conservative; avoids per-peer scale exchange).
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    max_scale = jax.lax.pmax(scale, axis_name)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return summed.astype(jnp.float32) * max_scale / n, new_error


def compressed_tree_psum(grads, axis_name: str, errors):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    outs, new_errs = [], []
    for g, e in zip(flat_g, flat_e):
        o, ne = compressed_psum(g, axis_name, e)
        outs.append(o)
        new_errs.append(ne)
    return (
        jax.tree_util.tree_unflatten(treedef, outs),
        jax.tree_util.tree_unflatten(treedef, new_errs),
    )
