"""Pallas TPU kernel: cardinality-class attention intersection (Eq. 8/9).

One equivalence class C_k (all intersections with the same input cardinality
k) executes as one VMEM-resident fusion: 2-layer MLP attention logits →
softmax over the k inputs → weighted combine. The whole chain — two small
matmuls, softmax, reduce — runs on one [bn, k, d] tile without HBM
round-trips, which is exactly where the paper's 13.1× per-operator win comes
from (fragmented per-query launches → dense class-wide fusion).

k is a *static* kernel parameter (one compiled kernel per equivalence class,
mirroring Eq. 8); d and the MLP hidden dim are padded to 128 lanes by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _intersect_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)                      # [bn, k, d]
    w1 = w1_ref[...].astype(jnp.float32)                    # [d, hd]
    b1 = b1_ref[...].astype(jnp.float32)                    # [1, hd]
    w2 = w2_ref[...].astype(jnp.float32)                    # [hd, 1... padded 128]
    b2 = b2_ref[...].astype(jnp.float32)                    # [1, pad]
    bn, kk, d = x.shape
    h = jnp.maximum(
        jax.lax.dot_general(
            x.reshape(bn * kk, d), w1, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b1,
        0.0,
    )                                                        # [bn*k, hd]
    logits = (
        jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b2
    )[:, :1].reshape(bn, kk)                                 # [bn, k]
    att = jax.nn.softmax(logits, axis=1)
    o_ref[...] = jnp.einsum("nk,nkd->nd", att, x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def intersect_pallas(
    x: jnp.ndarray,   # [n, k, d]
    w1: jnp.ndarray,  # [d, hd]
    b1: jnp.ndarray,  # [hd]
    w2: jnp.ndarray,  # [hd, pad] (col 0 = real logit weights)
    b2: jnp.ndarray,  # [pad]
    *,
    bn: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    n, k, d = x.shape
    hd = w1.shape[1]
    pad = w2.shape[1]
    # Explicit errors (not asserts — those vanish under `python -O`) naming
    # the offending dim and the multiple it must satisfy.
    if n % bn != 0:
        raise ValueError(
            f"intersect: pool rows n={n} must be a multiple of the row tile "
            f"bn={bn} (the ops.intersect wrapper pads for you)")
    if w1.shape[0] != d:
        raise ValueError(
            f"intersect: attention MLP input dim {w1.shape[0]} != state "
            f"dim d={d}")
    if w2.shape[0] != hd:
        raise ValueError(
            f"intersect: logit head input dim {w2.shape[0]} != hidden dim "
            f"hd={hd}")
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_intersect_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((d, hd), lambda i: (0, 0)),
            pl.BlockSpec((1, hd), lambda i: (0, 0)),
            pl.BlockSpec((hd, pad), lambda i: (0, 0)),
            pl.BlockSpec((1, pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
    )(x, w1, b1.reshape(1, hd), w2, b2.reshape(1, pad))
