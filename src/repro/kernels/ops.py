"""Jitted public wrappers for the Pallas kernels: shape padding to hardware
tiles, dtype handling, and interpret-mode fallback on CPU hosts.

On a CPU host (this container) the kernels run with interpret=True, which
executes the kernel body in Python — bit-accurate semantics, no TPU needed.
On TPU the same call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.gather_fuse import gather_fuse_pallas
from repro.kernels.intersect import intersect_pallas
from repro.kernels.scoring import scoring_pallas

_LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def scoring(q, e, gamma: float = 0.0, mode: str = "dot",
            bm: int = 128, bn: int = 256, bk: int = 128,
            interpret: bool | None = None):
    """Padded/unpadded entry to the scoring kernel. q [B,d], e [N,d]."""
    if interpret is None:
        interpret = not _on_tpu()
    B, d = q.shape
    N = e.shape[0]
    bm_ = min(bm, max(8, 1 << int(np.ceil(np.log2(max(B, 1))))))
    bn_ = min(bn, max(_LANE, 1 << int(np.ceil(np.log2(max(N, 1))))))
    qp = _pad_to(_pad_to(q, 0, bm_), 1, bk)
    ep = _pad_to(_pad_to(e, 0, bn_), 1, bk)
    out = scoring_pallas(qp, ep, gamma=gamma, mode=mode, bm=bm_, bn=bn_, bk=bk,
                         interpret=interpret)
    return out[:B, :N]


def intersect(x, w1, b1, w2, b2, bn: int = 256, interpret: bool | None = None):
    """x [n,k,d], MLP (w1 [d,hd], b1, w2 [hd,1], b2 [1]) -> [n,d]."""
    if interpret is None:
        interpret = not _on_tpu()
    n, k, d = x.shape
    bn_ = min(bn, max(8, 1 << int(np.ceil(np.log2(max(n, 1))))))
    xp = _pad_to(x, 0, bn_)
    # Pad the logit head to a full lane so the tile is hardware-aligned.
    w2p = _pad_to(w2, 1, _LANE)
    b2p = _pad_to(b2, 0, _LANE)
    out = intersect_pallas(xp, w1, b1, w2p, b2p, bn=bn_, interpret=interpret)
    return out[:n]


def gather_fuse(ids, h_str, h_sem, wp, bp, wf, bf, sem_ids=None,
                interpret: bool | None = None):
    """ids [n] -> fused entity vectors [n, d] (Eq. 11+12).

    ``sem_ids`` indexes ``h_sem`` independently of ``ids`` — pass the cache
    slots (``params["sem_slot"][ids]``) with the hot-set ``sem_cache`` buffer
    for the out-of-core layout (DESIGN.md §SemanticStore); defaults to
    ``ids`` for the full-resident table."""
    if interpret is None:
        interpret = not _on_tpu()
    return gather_fuse_pallas(ids, h_str, h_sem, wp, bp, wf, bf, sem_ids,
                              interpret=interpret)


def gather_fuse_params(params, ids, interpret: bool | None = None):
    """Drive the kernel straight from a model params dict, resolving the
    semantic layout the same way ``models/base.py::semantic_rows`` does."""
    if "sem_slot" in params:
        h_sem = params["sem_cache"]
        sem_ids = params["sem_slot"][ids]
    else:
        h_sem = params["sem_table"]
        sem_ids = None
    return gather_fuse(ids, params["entity"], h_sem, params["sem_proj_w"],
                       params["sem_proj_b"], params["fuse_w"],
                       params["fuse_b"], sem_ids=sem_ids, interpret=interpret)


# Re-exported oracles (tests + fallback paths).
scoring_ref = ref.scoring_ref
intersect_ref = ref.intersect_ref
gather_fuse_ref = ref.gather_fuse_ref
