"""Jitted public wrappers for the Pallas kernels: shape padding to hardware
tiles, dtype handling, and interpret-mode fallback on CPU hosts.

On a CPU host (this container) the kernels run with interpret=True, which
executes the kernel body in Python — bit-accurate semantics, no TPU needed.
On TPU the same call sites compile to Mosaic.

Tile configs resolve through the process autotuner (DESIGN.md §Autotuner):
pass ``bm``/``bn``/``rows`` explicitly to pin a config (the tuner's sweep
does), or leave them ``None`` and the tuned config for the call's shape
bucket is used — falling back to the hand-picked ``autotune.DEFAULTS`` when
nothing is tuned, which reproduces the pre-autotuner behavior bit for bit.
Row padding goes through the ONE shared rule ``autotune.row_block`` — the
same rule the compiler's kernel-aware ``bucket_size`` applies — so the
wrapper and the scheduler can never disagree about a padded size and force
an avoidable retrace.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune as at
from repro.kernels import ref
from repro.kernels.autotune import LANE as _LANE
from repro.kernels.gather_fuse import gather_fuse_pallas
from repro.kernels.intersect import intersect_pallas
from repro.kernels.scoring import scoring_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def scoring(q, e, gamma: float = 0.0, mode: str = "dot",
            bm: int | None = None, bn: int | None = None,
            bk: int | None = None, interpret: bool | None = None):
    """Padded/unpadded entry to the scoring kernel. q [B,d], e [N,d]."""
    if interpret is None:
        interpret = not _on_tpu()
    B, d = q.shape
    N = e.shape[0]
    if bm is None or bn is None or bk is None:
        cfg = at.get_tuner().config_for(
            "scoring", at.scoring_bucket(B, N, d), str(q.dtype), interpret)
        bm = cfg["bm"] if bm is None else bm
        bn = cfg["bn"] if bn is None else bn
        bk = cfg["bk"] if bk is None else bk
    bm_, Bp = at.row_block(B, bm, 8)
    bn_, Np = at.row_block(N, bn, _LANE)
    qp = _pad_to(_pad_to(q, 0, bm_), 1, bk)
    ep = _pad_to(_pad_to(e, 0, bn_), 1, bk)
    out = scoring_pallas(qp, ep, gamma=gamma, mode=mode, bm=bm_, bn=bn_, bk=bk,
                         interpret=interpret)
    return out[:B, :N]


def intersect(x, w1, b1, w2, b2, bn: int | None = None,
              interpret: bool | None = None):
    """x [n,k,d], MLP (w1 [d,hd], b1, w2 [hd,1], b2 [1]) -> [n,d]."""
    if interpret is None:
        interpret = not _on_tpu()
    n, k, d = x.shape
    if bn is None:
        cfg = at.get_tuner().config_for(
            "intersect", at.intersect_bucket(n, k, d, w1.shape[1]),
            str(x.dtype), interpret)
        bn = cfg["bn"]
    bn_, _np = at.row_block(n, bn, 8)
    xp = _pad_to(x, 0, bn_)
    # Pad the logit head to a full lane so the tile is hardware-aligned.
    w2p = _pad_to(w2, 1, _LANE)
    b2p = _pad_to(b2, 0, _LANE)
    out = intersect_pallas(xp, w1, b1, w2p, b2p, bn=bn_, interpret=interpret)
    return out[:n]


def gather_fuse(ids, h_str, h_sem, wp, bp, wf, bf, sem_ids=None,
                rows: int | None = None, interpret: bool | None = None):
    """ids [n] -> fused entity vectors [n, d] (Eq. 11+12).

    ``sem_ids`` indexes ``h_sem`` independently of ``ids`` — pass the cache
    slots (``params["sem_slot"][ids]``) with the hot-set ``sem_cache`` buffer
    for the out-of-core layout (DESIGN.md §SemanticStore); defaults to
    ``ids`` for the full-resident table. ``rows`` selects the launch
    geometry (1 = scalar-prefetch row DMAs, >1 = blocked); ids are padded
    here (repeating row 0) to the row-block multiple and the pad rows are
    sliced off."""
    if interpret is None:
        interpret = not _on_tpu()
    n = ids.shape[0]
    d = h_str.shape[1]
    if rows is None:
        cfg = at.get_tuner().config_for(
            "gather_fuse",
            at.gather_fuse_bucket(n, d, h_sem.shape[1], wp.shape[1]),
            str(h_str.dtype), interpret)
        rows = cfg["rows"]
    rows_, np_ = at.row_block(n, rows, 1)
    ids_p = _pad_to(ids, 0, rows_)  # pad ids are 0 — valid rows, sliced off
    sem_p = None if sem_ids is None else _pad_to(sem_ids, 0, rows_)
    out = gather_fuse_pallas(ids_p, h_str, h_sem, wp, bp, wf, bf, sem_p,
                             rows=rows_, interpret=interpret)
    return out[:n]


def gather_fuse_params(params, ids, rows: int | None = None,
                       interpret: bool | None = None):
    """Drive the kernel straight from a model params dict, resolving the
    semantic layout the same way ``models/base.py::semantic_rows`` does."""
    if "sem_slot" in params:
        h_sem = params["sem_cache"]
        sem_ids = params["sem_slot"][ids]
    else:
        h_sem = params["sem_table"]
        sem_ids = None
    return gather_fuse(ids, params["entity"], h_sem, params["sem_proj_w"],
                       params["sem_proj_b"], params["fuse_w"],
                       params["fuse_b"], sem_ids=sem_ids, rows=rows,
                       interpret=interpret)


# Re-exported oracles (tests + fallback paths).
scoring_ref = ref.scoring_ref
intersect_ref = ref.intersect_ref
gather_fuse_ref = ref.gather_fuse_ref
