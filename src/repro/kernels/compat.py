"""Version bridges for the ``jax.experimental.pallas.tpu`` API.

Newer JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(mirroring the moe.py shard_map bridge); resolve whichever this JAX has so
the kernels import on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
