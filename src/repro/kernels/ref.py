"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match to float tolerance across the
shape/dtype sweeps in tests/test_kernels_*.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scoring_ref(q: jnp.ndarray, e: jnp.ndarray, gamma: float = 0.0,
                mode: str = "dot") -> jnp.ndarray:
    """Vectorized logits (Eq. 6). q [B, d], e [N, d] -> [B, N].

    mode=dot : gamma + q @ e.T      (inner-product geometries)
    mode=l1  : gamma - sum |q - e|  (translational geometries)
    """
    if mode == "dot":
        return gamma + q @ e.T
    if mode == "l1":
        return gamma - jnp.sum(jnp.abs(q[:, None, :] - e[None, :, :]), axis=-1)
    raise ValueError(mode)


def scoring_loss_ref(q, e_pos, e_neg, gamma: float, mode: str = "dot"):
    """Fused negative-sampling loss over pos [B,d] and neg [B,K,d]."""
    if mode == "dot":
        s_pos = gamma + jnp.sum(q * e_pos, axis=-1)
        s_neg = gamma + jnp.einsum("bd,bkd->bk", q, e_neg)
    else:
        s_pos = gamma - jnp.sum(jnp.abs(q - e_pos), axis=-1)
        s_neg = gamma - jnp.sum(jnp.abs(q[:, None, :] - e_neg), axis=-1)
    per = -jax.nn.log_sigmoid(s_pos) - jnp.mean(jax.nn.log_sigmoid(-s_neg), axis=-1)
    return per


def intersect_ref(x: jnp.ndarray, w1, b1, w2, b2) -> jnp.ndarray:
    """Cardinality-class attention intersection (Eq. 8/9).

    x [n, k, d]; attention logits from a 2-layer MLP; softmax over k;
    weighted combine. Matches BetaE/Q2B-style intersection."""
    h = jax.nn.relu(x @ w1 + b1)           # [n, k, hd]
    logits = h @ w2 + b2                   # [n, k, 1]
    att = jax.nn.softmax(logits, axis=1)
    return jnp.sum(att * x, axis=1)


def gather_fuse_ref(ids, h_str, h_sem, wp, bp, wf, bf) -> jnp.ndarray:
    """GPU-resident semantic integration (Eq. 11 + 12).

    ids [n]; h_str [E, d]; h_sem [E, dl]; project h_sem -> dp, concat, affine,
    sigmoid*2-1. One fused memory pass per row."""
    h = h_str[ids]
    z = h_sem[ids] @ wp + bp
    x = jnp.concatenate([h, z], axis=-1)
    return jax.nn.sigmoid(x @ wf + bf) * 2.0 - 1.0
