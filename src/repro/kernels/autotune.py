"""Kernel autotuner with a persisted tuning cache (DESIGN.md §Autotuner).

The Pallas kernels ship with hand-picked tile shapes (``scoring`` bm=128/
bn=256/bk=128, ``intersect`` bn=256, row-at-a-time ``gather_fuse``) — tuned
for exactly one shape regime. This module searches tile/block configurations
per **(op, shape-bucket, dtype, backend, interpret-mode)** and persists the
winner so the tuning cost is paid once per machine:

* **Shape buckets** — pool-rows dimensions are bucketed to the next power of
  two (the same ladder the scheduler's ``bucket_size`` pads to), feature
  dims are kept exact. One tuned config covers every pool that lands in the
  bucket, so the config set — like the jit signature set — stays closed.
* **Bit-identity verification** — every candidate's output is compared
  ``np.array_equal`` against the default-tile path (and float-checked
  against the ``kernels/ref.py`` oracle) on deterministic inputs BEFORE it
  is timed; a candidate that changes a single bit is rejected. Tile choice
  may only move work, never numerics.
* **Timed sweep** — median-of-iters wall time through the PUBLIC ``ops``
  wrappers (what actually runs), default config always among the
  candidates, so the tuned config is never slower than the default on the
  machine that tuned it (modulo timer noise; ``benchmarks/autotune.py``
  gates this with paired trials).
* **Persisted cache** — crash-safe JSON (tmp + fsync + ``os.replace``, the
  ``SemanticStore`` idiom). A corrupt/partial/foreign-version file is
  REJECTED and retuned, never crashed on. ``REPRO_AUTOTUNE_CACHE`` names
  the default cache file for the process-wide tuner.

``PoolTilePolicy`` is the bridge to the compiler: it maps a scheduler pool
``(op, cardinality, rows)`` to the tuned row tile, and ``bucket_size`` pads
the pool to the smallest multiple of that tile instead of the bare power of
two — less pad waste AND kernel-aligned launches, with the policy's key
mixed into every schedule/plan cache key so the signature universe stays
closed (zero steady-state retraces).

Activity is published through the PR-7 ``MetricsRegistry`` (group
``autotune``): sweeps run, candidates timed, tuned-config lookups served vs
defaulted, rejected candidates, cache-file loads/saves.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.registry import get_registry

__all__ = [
    "LANE", "DEFAULTS", "KernelTuner", "PoolTilePolicy", "get_tuner",
    "set_tuner", "pow2ceil", "ceil_to", "rows_bucket", "row_block",
    "scoring_bucket", "intersect_bucket", "gather_fuse_bucket",
    "pool_tile_policy", "tune_for_model", "ENV_CACHE",
]

#: TPU lane width / MXU edge — the hardware alignment every feature-dim pad
#: in ``ops.py`` targets. Single-sourced here so the kernel wrappers and the
#: tuner's search spaces can never disagree about it.
LANE = 128

#: Hand-picked tiles the kernels shipped with — served whenever no tuned
#: entry exists, so an empty tuner is bit-and-trace-identical to the
#: pre-autotuner engine.
DEFAULTS: Dict[str, Dict[str, int]] = {
    "scoring": {"bm": 128, "bn": 256, "bk": 128},
    "intersect": {"bn": 256},
    "gather_fuse": {"rows": 1},
}

ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1


# --------------------------------------------------------------- shape math
def pow2ceil(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def ceil_to(n: int, m: int) -> int:
    """Smallest multiple of m >= n."""
    return -(-int(n) // int(m)) * int(m)


def rows_bucket(n: int, floor: int = 8) -> int:
    """Pow2 bucket for a rows-like dimension, floored at the minimum block."""
    return max(int(floor), pow2ceil(n))


def row_block(n: int, tile: int, floor: int = 8) -> Tuple[int, int]:
    """The ONE row-padding rule shared by the kernel wrappers and the
    compiler's kernel-aware ``bucket_size``: clamp the tuned ``tile`` to the
    pow2 bucket of ``n`` (a tile can never exceed the padded rows), then pad
    ``n`` to the smallest multiple of the clamped block. Returns
    ``(block, padded_n)`` with ``padded_n % block == 0``."""
    b = min(int(tile), rows_bucket(n, floor))
    return b, ceil_to(max(int(n), 1), b)


def scoring_bucket(B: int, N: int, d: int) -> Tuple[int, int, int]:
    return (rows_bucket(B), rows_bucket(N, LANE), int(d))


def intersect_bucket(n: int, k: int, d: int, hd: int) -> Tuple[int, ...]:
    return (rows_bucket(n), int(k), int(d), int(hd))


def gather_fuse_bucket(n: int, d: int, dl: int, dp: int) -> Tuple[int, ...]:
    return (rows_bucket(n, 1), int(d), int(dl), int(dp))


def _backend() -> str:
    import jax

    return jax.default_backend()


def cache_key(op: str, bucket: Sequence[int], dtype: str,
              interpret: bool) -> str:
    """Flat string key: op + shape bucket + dtype + backend + interpret mode
    (interpret-mode timings on a CPU host must never be mistaken for Mosaic
    timings on a TPU — they tune different cost models)."""
    shp = "x".join(str(int(v)) for v in bucket)
    mode = "interpret" if interpret else "compiled"
    return f"{op}|{shp}|{dtype}|{_backend()}|{mode}"


# ----------------------------------------------------------- search spaces
def scoring_candidates(bucket) -> List[Dict[str, int]]:
    Bb, Nb, _d = bucket
    out = [dict(DEFAULTS["scoring"])]
    # bk stays at one lane: splitting the k-loop differently reassociates the
    # fp32 accumulator and would fail the bit-identity gate by construction.
    for bm in (32, 64, 128, 256):
        for bn in (128, 256, 512):
            if bm <= rows_bucket(Bb) and bn <= rows_bucket(Nb, LANE):
                c = {"bm": bm, "bn": bn, "bk": 128}
                if c not in out:
                    out.append(c)
    return out


def intersect_candidates(bucket) -> List[Dict[str, int]]:
    nb = bucket[0]
    out = [dict(DEFAULTS["intersect"])]
    for bn in (8, 16, 32, 64, 128, 256, 512):
        if bn <= nb:
            c = {"bn": bn}
            if c not in out:
                out.append(c)
    return out


def gather_fuse_candidates(bucket) -> List[Dict[str, int]]:
    nb = bucket[0]
    out = [dict(DEFAULTS["gather_fuse"])]
    for rows in (2, 4, 8, 16, 32, 64):
        if rows <= nb:
            c = {"rows": rows}
            if c not in out:
                out.append(c)
    return out


_CANDIDATES: Dict[str, Callable] = {
    "scoring": scoring_candidates,
    "intersect": intersect_candidates,
    "gather_fuse": gather_fuse_candidates,
}


# ------------------------------------------------------------------- tuner
@dataclasses.dataclass
class SweepResult:
    key: str
    config: Dict[str, int]
    us: float
    default_us: float
    n_candidates: int
    n_rejected: int


class KernelTuner:
    """Per-process tile tuner + the persisted on-disk tuning cache.

    Lookups (``config_for``) are a dict probe — safe on every hot path; the
    expensive sweep only runs when ``tune()`` / ``tune_for_model()`` is
    invoked explicitly (the bench, ``--autotune``, or a test). With no tuned
    entries the tuner serves ``DEFAULTS`` and the engine behaves exactly as
    before this subsystem existed."""

    def __init__(self, path: Optional[str] = None, iters: int = 3,
                 warmup: int = 1, max_candidates: int = 12,
                 margin: float = 0.10):
        if iters < 1 or warmup < 0 or max_candidates < 1:
            raise ValueError(
                f"iters >= 1, warmup >= 0, max_candidates >= 1 required; got "
                f"iters={iters} warmup={warmup} max_candidates={max_candidates}")
        if not 0.0 <= margin < 1.0:
            raise ValueError(f"margin must be in [0, 1); got {margin}")
        self.path = path
        self.iters = iters
        self.warmup = warmup
        self.max_candidates = max_candidates
        self.margin = margin
        self._entries: Dict[str, Dict] = {}
        self._lock = threading.RLock()
        self.load_error: Optional[str] = None
        m = get_registry().group("autotune")
        self._metrics = m
        self.sweeps = m.counter("sweeps")
        self.candidates_timed = m.counter("candidates_timed")
        self.lookup_hits = m.counter("lookup_hits")      # tuned config served
        self.lookup_misses = m.counter("lookup_misses")  # DEFAULTS served
        self.verify_rejects = m.counter("verify_rejects")
        self.loads = m.counter("loads")
        self.load_rejects = m.counter("load_rejects")
        self.saves = m.counter("saves")
        self.entries_gauge = m.gauge("entries")
        if path:
            self.load()

    # ------------------------------------------------------------- lookups
    def lookup(self, op: str, bucket, dtype: str = "float32",
               interpret: bool = True) -> Optional[Dict[str, int]]:
        with self._lock:
            e = self._entries.get(cache_key(op, bucket, dtype, interpret))
        return dict(e["config"]) if e else None

    def config_for(self, op: str, bucket, dtype: str = "float32",
                   interpret: bool = True) -> Dict[str, int]:
        """Tuned config for the bucket, or the hand-picked default."""
        c = self.lookup(op, bucket, dtype, interpret)
        if c is not None:
            self.lookup_hits += 1
            return c
        self.lookup_misses += 1
        return dict(DEFAULTS[op])

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __bool__(self) -> bool:
        # An empty tuner is still a tuner — never let ``len == 0`` make
        # ``tuner or get_tuner()``-style code swap in the global one.
        return True

    def entries(self) -> Dict[str, Dict]:
        with self._lock:
            return {k: dict(v) for k, v in self._entries.items()}

    def stats(self) -> Dict:
        with self._lock:
            n = len(self._entries)
        return {
            "name": "autotune",
            "path": self.path,
            "entries": n,
            "sweeps": int(self.sweeps),
            "candidates_timed": int(self.candidates_timed),
            "lookup_hits": int(self.lookup_hits),
            "lookup_misses": int(self.lookup_misses),
            "verify_rejects": int(self.verify_rejects),
            "loads": int(self.loads),
            "load_rejects": int(self.load_rejects),
            "saves": int(self.saves),
            "load_error": self.load_error,
        }

    def reset_counters(self) -> None:
        self._metrics.reset()

    # ------------------------------------------------------------ sweeping
    def tune(self, op: str, bucket, dtype: str = "float32",
             interpret: bool = True, force: bool = False) -> Dict[str, int]:
        """Ensure a tuned entry for the bucket (sweep once, then cached —
        in memory and, with a ``path``, on disk)."""
        if op not in _CANDIDATES:
            raise ValueError(f"unknown op {op!r}; tunable: {sorted(_CANDIDATES)}")
        key = cache_key(op, bucket, dtype, interpret)
        with self._lock:
            if not force and key in self._entries:
                return dict(self._entries[key]["config"])
        res = self._sweep(op, tuple(int(v) for v in bucket), dtype, interpret)
        with self._lock:
            self._entries[key] = {
                "op": op, "bucket": list(bucket), "dtype": dtype,
                "config": dict(res.config), "us": res.us,
                "default_us": res.default_us,
                "n_candidates": res.n_candidates,
                "n_rejected": res.n_rejected,
            }
            self.entries_gauge.set(len(self._entries))
        if self.path:
            self.save()
        return dict(res.config)

    def _sweep(self, op, bucket, dtype, interpret) -> SweepResult:
        self.sweeps += 1
        run, args = _make_runner(op, bucket, dtype, interpret)
        cands = _CANDIDATES[op](bucket)[: self.max_candidates]
        ref_out = np.asarray(run(cands[0], *args))  # default tiles = oracle
        best_cfg, best_us, default_us, rejected = dict(cands[0]), None, None, 0
        for cfg in cands:
            out = np.asarray(run(cfg, *args))
            if not np.array_equal(out, ref_out):
                # Tile choice may only move work, never numerics.
                self.verify_rejects += 1
                rejected += 1
                continue
            us = _time_us(lambda: run(cfg, *args), self.iters, self.warmup)
            self.candidates_timed += 1
            if default_us is None:
                # The default runs first; it is the incumbent to beat.
                default_us = us
                best_cfg, best_us = dict(cfg), us
            elif us < best_us and us < default_us * (1.0 - self.margin):
                # A challenger must beat the default by ``margin`` (not just
                # by a timer tick) — ties and noise-level wins stay with the
                # default, so "tuned never slower" is robust to host jitter.
                best_cfg, best_us = dict(cfg), us
        return SweepResult(
            key=cache_key(op, bucket, dtype, interpret), config=best_cfg,
            us=float(best_us), default_us=float(default_us),
            n_candidates=len(cands), n_rejected=rejected)

    # --------------------------------------------------------- persistence
    def save(self) -> None:
        """Crash-safe publish: tmp + fsync + atomic rename (the
        ``SemanticStore`` idiom) — a reader never sees partial bytes."""
        if not self.path:
            return
        with self._lock:
            payload = {"version": CACHE_VERSION, "entries": dict(self._entries)}
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
            self.saves += 1

    def load(self) -> int:
        """Load the persisted cache; a corrupt, partial, or foreign-version
        file is rejected whole (``load_error`` records why) and the tuner
        simply retunes — it must never crash the engine."""
        self.load_error = None
        if not self.path or not os.path.exists(self.path):
            return 0
        try:
            with open(self.path) as f:
                payload = json.load(f)
            if not isinstance(payload, dict):
                raise ValueError("cache root is not an object")
            if payload.get("version") != CACHE_VERSION:
                raise ValueError(
                    f"cache version {payload.get('version')!r} != "
                    f"{CACHE_VERSION}")
            raw = payload.get("entries")
            if not isinstance(raw, dict):
                raise ValueError("cache has no entries object")
            good: Dict[str, Dict] = {}
            for k, e in raw.items():
                if (isinstance(k, str) and isinstance(e, dict)
                        and isinstance(e.get("config"), dict)
                        and e.get("op") in DEFAULTS
                        and set(e["config"]) == set(DEFAULTS[e["op"]])
                        and all(isinstance(v, int) and v >= 1
                                for v in e["config"].values())):
                    good[k] = e
                else:
                    raise ValueError(f"malformed entry {k!r}")
        except (OSError, ValueError, json.JSONDecodeError) as err:
            self.load_error = f"{type(err).__name__}: {err}"
            self.load_rejects += 1
            return 0
        with self._lock:
            self._entries.update(good)
            self.entries_gauge.set(len(self._entries))
        self.loads += 1
        return len(good)


def _time_us(fn: Callable, iters: int, warmup: int) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    # Min, not mean/median: host timing noise is strictly additive, so the
    # fastest observation is the least-contaminated estimate.
    return min(ts) * 1e6


def _make_runner(op: str, bucket, dtype: str, interpret: bool):
    """Deterministic inputs at the bucket shape + a runner that drives the
    PUBLIC ``ops`` wrapper with an explicit candidate config — the sweep
    times exactly the code path production takes."""
    import jax.numpy as jnp

    from repro.kernels import ops  # function-level: ops imports this module

    rng = np.random.default_rng(0)
    dt = jnp.dtype(dtype)
    if op == "scoring":
        B, N, d = bucket
        q = jnp.asarray(rng.normal(size=(B, d)), dt)
        e = jnp.asarray(rng.normal(size=(N, d)), dt)

        def run(cfg, q, e):
            return ops.scoring(q, e, gamma=1.0, mode="dot", bm=cfg["bm"],
                               bn=cfg["bn"], bk=cfg["bk"], interpret=interpret)

        return run, (q, e)
    if op == "intersect":
        n, k, d, hd = bucket
        x = jnp.asarray(rng.normal(size=(n, k, d)), dt)
        w1 = jnp.asarray(rng.normal(size=(d, hd)) * 0.2, jnp.float32)
        b1 = jnp.asarray(rng.normal(size=(hd,)) * 0.1, jnp.float32)
        w2 = jnp.asarray(rng.normal(size=(hd, 1)) * 0.2, jnp.float32)
        b2 = jnp.zeros((1,), jnp.float32)

        def run(cfg, *a):
            return ops.intersect(*a, bn=cfg["bn"], interpret=interpret)

        return run, (x, w1, b1, w2, b2)
    if op == "gather_fuse":
        n, d, dl, dp = bucket
        E = max(n, 64)
        ids = jnp.asarray(rng.integers(0, E, n), jnp.int32)
        h_str = jnp.asarray(rng.normal(size=(E, d)), jnp.float32)
        h_sem = jnp.asarray(rng.normal(size=(E, dl)), jnp.float32)
        wp = jnp.asarray(rng.normal(size=(dl, dp)) * 0.2, jnp.float32)
        bp = jnp.asarray(rng.normal(size=(dp,)) * 0.1, jnp.float32)
        wf = jnp.asarray(rng.normal(size=(d + dp, d)) * 0.2, jnp.float32)
        bf = jnp.zeros((d,), jnp.float32)

        def run(cfg, *a):
            return ops.gather_fuse(*a, rows=cfg["rows"], interpret=interpret)

        return run, (ids, h_str, h_sem, wp, bp, wf, bf)
    raise ValueError(op)  # pragma: no cover


# ---------------------------------------------------------- process tuner
_GLOBAL: Optional[KernelTuner] = None
_GLOBAL_LOCK = threading.Lock()


def get_tuner() -> KernelTuner:
    """Process-wide tuner. Created lazily; picks up ``REPRO_AUTOTUNE_CACHE``
    as its persisted cache path when set (the ``run.sh`` launcher sets it)."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = KernelTuner(path=os.environ.get(ENV_CACHE) or None)
        return _GLOBAL


def set_tuner(tuner: Optional[KernelTuner]) -> Optional[KernelTuner]:
    """Install (or with ``None`` reset) the process-wide tuner; returns the
    previous one so tests can restore it."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        prev, _GLOBAL = _GLOBAL, tuner
        return prev


# ------------------------------------------------------- compiler bridge
class PoolTilePolicy:
    """Maps a scheduler pool ``(op, cardinality, rows)`` to the tuned row
    tile its padded size must be a multiple of (``scheduler.bucket_size``
    consumes it). ``key()`` enters every schedule/plan cache key, so two
    executors holding different tunings can never share a schedule — the
    signature universe stays closed per policy."""

    def __init__(self, tiles: Dict[Tuple[int, int, int], int]):
        for (op, card, bucket), t in tiles.items():
            if t < 1 or (t & (t - 1)):
                raise ValueError(
                    f"tile for pool (op={op}, card={card}, bucket={bucket}) "
                    f"must be a power of two >= 1, got {t}")
        self._tiles = dict(tiles)
        self._key = tuple(sorted(self._tiles.items()))

    def tile(self, op: int, card: int, n: int) -> int:
        if not self._tiles:
            return 1
        return self._tiles.get((int(op), int(card), rows_bucket(n, 1)), 1)

    def key(self) -> Tuple:
        return self._key

    def __bool__(self) -> bool:
        return bool(self._tiles)

    def __repr__(self) -> str:
        return f"PoolTilePolicy({len(self._tiles)} tiles)"


def pool_tile_policy(model, tuner: Optional[KernelTuner] = None,
                     b_max: int = 512) -> Optional[PoolTilePolicy]:
    """Build the kernel-aware padding policy for ``model`` from whatever the
    tuner has learned. Tiles come from tuned entries whose feature dims
    match the model (intersect/union pools gate on ``state_dim``; embed
    pools on the fused-entity ``cfg.dim``); with no matching entries the
    result is ``None`` and the compiler keeps bare pow2 padding — the
    pre-autotuner engine, bit for bit."""
    from repro.core.ops import OpType

    tuner = get_tuner() if tuner is None else tuner
    tiles: Dict[Tuple[int, int, int], int] = {}
    try:
        sd = int(model.state_dim)
    except Exception:
        sd = -1
    dim = int(getattr(model.cfg, "dim", -1))
    for e in tuner.entries().values():
        bucket = e.get("bucket") or []
        cfg = e["config"]
        if e["op"] == "intersect" and len(bucket) == 4 and bucket[2] == sd:
            nb, k = int(bucket[0]), int(bucket[1])
            if nb <= rows_bucket(b_max, 1):
                t = int(cfg["bn"])
                for op in (OpType.INTERSECT, OpType.UNION):
                    tiles[(int(op), k, nb)] = t
        elif e["op"] == "gather_fuse" and len(bucket) == 4 and bucket[1] == dim:
            nb = int(bucket[0])
            if nb <= rows_bucket(b_max, 1):
                tiles[(int(OpType.EMBED), 0, nb)] = int(cfg["rows"])
    return PoolTilePolicy(tiles) if tiles else None


def tune_for_model(model, tuner: Optional[KernelTuner] = None,
                   b_max: int = 512, batch: int = 128,
                   n_entities: int = 4096, cards: Sequence[int] = (2, 3),
                   interpret: Optional[bool] = None) -> int:
    """Bounded sweep over the buckets one model/shape regime actually hits:
    scoring at (batch x entities x dim), intersect at the pool buckets the
    scheduler can form (up to ``b_max``) per cardinality class, gather_fuse
    at the embed working set. Returns the number of sweeps run (0 when the
    persisted cache already covers everything)."""
    import jax

    tuner = get_tuner() if tuner is None else tuner
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    before = int(tuner.sweeps)
    dim = int(model.cfg.dim)
    sd = int(model.state_dim)
    tuner.tune("scoring", scoring_bucket(batch, n_entities, dim),
               interpret=interpret)
    hd = None
    # Intersect MLP width from the model's own attention params when it
    # exposes one (BetaE: att_w0 [2d, h]); fall back to hidden_mult * dim.
    try:
        probe = model.init_geometry(jax.random.PRNGKey(0), 8, 4)
        for name in ("att_w0", "int_w0"):
            if name in probe:
                hd = int(probe[name].shape[1])
                break
    except Exception:
        pass
    if hd is None:
        hd = int(getattr(model.cfg, "hidden_mult", 2) * dim)
    # Full pow2 ladder up to the largest pool the scheduler can form, so the
    # tile policy has an answer for EVERY pool bucket (a bucket without an
    # entry falls back to pow2 padding — correct, just not kernel-aware).
    top = rows_bucket(min(4 * batch, b_max))
    pool_buckets = []
    nb = 8
    while nb <= top:
        pool_buckets.append(nb)
        nb *= 2
    for k in cards:
        for nb in pool_buckets:
            tuner.tune("intersect", intersect_bucket(nb, k, sd, hd),
                       interpret=interpret)
    if model.cfg.semantic_dim > 0:
        dl = int(model.cfg.semantic_dim)
        dp = int(model.cfg.semantic_proj_dim)
        tuner.tune("gather_fuse",
                   gather_fuse_bucket(min(4 * batch, b_max), dim, dl, dp),
                   interpret=interpret)
    return int(tuner.sweeps) - before
