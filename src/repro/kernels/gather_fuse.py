"""Pallas TPU kernel: GPU(HBM)-resident semantic integration (Eq. 11 + 12).

e_fused = sigmoid(W_f [h_str[ids] ⊕ (h_sem[ids] W_p + b_p)] + b_f) * 2 - 1

The tables stay in HBM (pltpu.ANY); each grid step DMAs exactly the rows it
needs into VMEM using scalar-prefetched indices (PrefetchScalarGridSpec) —
the TPU analogue of the paper's "high-speed tensor indexing" gather: the
semantic manifold is never densified or round-tripped, and the projection +
concat + affine + activation all happen in VMEM right after the row DMA.

Rows are processed in blocks of ``rows`` per grid step; callers pad ids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_fuse_kernel(ids_ref, hstr_ref, hsem_ref, wp_ref, bp_ref, wf_ref, bf_ref, o_ref,
                        *, rows: int):
    h = hstr_ref[...].astype(jnp.float32)                    # [rows, d]
    z = hsem_ref[...].astype(jnp.float32)                    # [rows, dl]
    zp = (
        jax.lax.dot_general(z, wp_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + bp_ref[...].astype(jnp.float32)
    )                                                        # [rows, dp]
    x = jnp.concatenate([h, zp], axis=-1)                    # [rows, d+dp]
    y = (
        jax.lax.dot_general(x, wf_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + bf_ref[...].astype(jnp.float32)
    )
    o_ref[...] = (jax.nn.sigmoid(y) * 2.0 - 1.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def gather_fuse_pallas(
    ids: jnp.ndarray,    # [n] int32 — row indices into both tables
    h_str: jnp.ndarray,  # [E, d]
    h_sem: jnp.ndarray,  # [E, dl]  (the frozen H_sem buffer)
    wp: jnp.ndarray,     # [dl, dp]
    bp: jnp.ndarray,     # [dp]
    wf: jnp.ndarray,     # [d+dp, d]
    bf: jnp.ndarray,     # [d]
    *,
    rows: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    n = ids.shape[0]
    E, d = h_str.shape
    _, dl = h_sem.shape
    dp = wp.shape[1]
    assert n % rows == 0, (n, rows)
    # Block index i selects rows [ids[i*rows + r] for r in range(rows)]; with
    # a row-blocked table BlockSpec the index_map returns the *row block* to
    # DMA. We gather row-by-row (block height 1) and let the grid supply the
    # row position — the standard Pallas scalar-prefetch gather pattern.
    grid = (n,)

    def tbl_map(i, ids_ref):
        return (ids_ref[i], 0)

    out = pl.pallas_call(
        functools.partial(_gather_fuse_kernel, rows=1),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), tbl_map),
                pl.BlockSpec((1, dl), tbl_map),
                pl.BlockSpec((dl, dp), lambda i, ids_ref: (0, 0)),
                pl.BlockSpec((1, dp), lambda i, ids_ref: (0, 0)),
                pl.BlockSpec((d + dp, d), lambda i, ids_ref: (0, 0)),
                pl.BlockSpec((1, d), lambda i, ids_ref: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), h_str.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), h_str, h_sem, wp, bp.reshape(1, dp), wf, bf.reshape(1, d))
    return out
