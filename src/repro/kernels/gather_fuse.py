"""Pallas TPU kernel: GPU(HBM)-resident semantic integration (Eq. 11 + 12).

e_fused = sigmoid(W_f [h_str[ids] ⊕ (h_sem[sem_ids] W_p + b_p)] + b_f) * 2 - 1

The tables stay in HBM (pltpu.ANY); each grid step DMAs exactly the rows it
needs into VMEM using scalar-prefetched indices (PrefetchScalarGridSpec) —
the TPU analogue of the paper's "high-speed tensor indexing" gather: the
semantic manifold is never densified or round-tripped, and the projection +
concat + affine + activation all happen in VMEM right after the row DMA.

Two scalar-prefetch index streams because the semantic table may be the
out-of-core HOT-SET CACHE (DESIGN.md §SemanticStore): there ``h_sem`` is the
bounded ``sem_cache`` buffer and ``sem_ids`` are cache SLOTS
(``sem_slot[ids]``), distinct from the structural entity ids. In the
full-resident layout both streams carry the same entity ids.

``rows`` selects the launch geometry (the autotuner's knob — DESIGN.md
§Autotuner):

* ``rows=1`` — the scalar-prefetch gather above: grid ``(n,)``, height-1
  row DMAs addressed by the prefetched index streams. Minimal VMEM
  footprint, one grid step per output row.
* ``rows>1`` — blocked: the row gathers run as XLA takes (arbitrary-row
  multi-height DMA is not expressible as a single BlockSpec index_map),
  then ONE fuse kernel processes ``rows`` gathered rows per grid step —
  ``n/rows`` launches amortize the per-step overhead that dominates small
  fused dims.

Both paths run the same ``_fuse_block`` body on [rows, ·] f32 tiles, so the
per-row arithmetic — and therefore the output bits — is identical; the
autotuner verifies exactly that before timing a candidate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fuse_block(h, z, wp_ref, bp_ref, wf_ref, bf_ref, o_ref):
    """Shared Eq. 11+12 body: h [rows, d] structural, z [rows, dl] semantic
    (already gathered into VMEM) -> o_ref [rows, d]."""
    zp = (
        jax.lax.dot_general(z, wp_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + bp_ref[...].astype(jnp.float32)
    )                                                        # [rows, dp]
    x = jnp.concatenate([h, zp], axis=-1)                    # [rows, d+dp]
    y = (
        jax.lax.dot_general(x, wf_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + bf_ref[...].astype(jnp.float32)
    )
    o_ref[...] = (jax.nn.sigmoid(y) * 2.0 - 1.0).astype(o_ref.dtype)


def _gather_fuse_kernel(ids_ref, sem_ids_ref, hstr_ref, hsem_ref, wp_ref,
                        bp_ref, wf_ref, bf_ref, o_ref):
    _fuse_block(hstr_ref[...].astype(jnp.float32),
                hsem_ref[...].astype(jnp.float32),
                wp_ref, bp_ref, wf_ref, bf_ref, o_ref)


def _fuse_only_kernel(hstr_ref, hsem_ref, wp_ref, bp_ref, wf_ref, bf_ref,
                      o_ref):
    _fuse_block(hstr_ref[...].astype(jnp.float32),
                hsem_ref[...].astype(jnp.float32),
                wp_ref, bp_ref, wf_ref, bf_ref, o_ref)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def gather_fuse_pallas(
    ids: jnp.ndarray,      # [n] int32 — row indices into h_str
    h_str: jnp.ndarray,    # [E, d]
    h_sem: jnp.ndarray,    # [E, dl] full H_sem, or [budget, dl] hot-set cache
    wp: jnp.ndarray,       # [dl, dp]
    bp: jnp.ndarray,       # [dp]
    wf: jnp.ndarray,       # [d+dp, d]
    bf: jnp.ndarray,       # [d]
    sem_ids: jnp.ndarray = None,  # [n] int32 rows into h_sem (cache slots);
    #                               None = same as ``ids`` (full-resident)
    *,
    rows: int = 1,
    interpret: bool = False,
) -> jnp.ndarray:
    n = ids.shape[0]
    E, d = h_str.shape
    _, dl = h_sem.shape
    dp = wp.shape[1]
    if rows < 1:
        raise ValueError(f"rows must be >= 1, got rows={rows}")
    if n % rows != 0:
        raise ValueError(
            f"gather_fuse: ids length n={n} must be a multiple of the row "
            f"block rows={rows} (the ops.gather_fuse wrapper pads for you)")
    if wf.shape[0] != d + dp:
        raise ValueError(
            f"gather_fuse: fuse weight rows {wf.shape[0]} != d+dp = "
            f"{d}+{dp} = {d + dp}")
    if sem_ids is None:
        sem_ids = ids
    if sem_ids.shape != ids.shape:
        raise ValueError(
            f"gather_fuse: sem_ids shape {sem_ids.shape} != ids shape "
            f"{ids.shape}")

    if rows > 1:
        # Blocked path: gather XLA-side (dynamic rows), fuse in [rows, ·]
        # tiles — grid (n/rows,). Same _fuse_block arithmetic per row.
        hs = h_str[ids]                                     # [n, d]
        zs = h_sem[sem_ids]                                 # [n, dl]
        return pl.pallas_call(
            _fuse_only_kernel,
            grid=(n // rows,),
            in_specs=[
                pl.BlockSpec((rows, d), lambda i: (i, 0)),
                pl.BlockSpec((rows, dl), lambda i: (i, 0)),
                pl.BlockSpec((dl, dp), lambda i: (0, 0)),
                pl.BlockSpec((1, dp), lambda i: (0, 0)),
                pl.BlockSpec((d + dp, d), lambda i: (0, 0)),
                pl.BlockSpec((1, d), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n, d), h_str.dtype),
            interpret=interpret,
        )(hs, zs, wp, bp.reshape(1, dp), wf, bf.reshape(1, d))

    # rows == 1: scalar-prefetch gather. Block index i selects row ids[i];
    # with a row-blocked table BlockSpec the index_map returns the *row
    # block* to DMA. We gather row-by-row (block height 1) and let the grid
    # supply the row position — the standard Pallas scalar-prefetch gather
    # pattern. The two scalar-prefetch streams feed the two tables
    # independently.
    grid = (n,)

    def str_map(i, ids_ref, sem_ids_ref):
        return (ids_ref[i], 0)

    def sem_map(i, ids_ref, sem_ids_ref):
        return (sem_ids_ref[i], 0)

    def rep_map(i, ids_ref, sem_ids_ref):
        return (0, 0)

    out = pl.pallas_call(
        _gather_fuse_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), str_map),
                pl.BlockSpec((1, dl), sem_map),
                pl.BlockSpec((dl, dp), rep_map),
                pl.BlockSpec((1, dp), rep_map),
                pl.BlockSpec((d + dp, d), rep_map),
                pl.BlockSpec((1, d), rep_map),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, ids_ref, sem_ids_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), h_str.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), sem_ids.astype(jnp.int32),
      h_str, h_sem, wp, bp.reshape(1, dp), wf, bf.reshape(1, d))
    return out
