"""Pallas TPU kernel: vectorized scoring logits (Eq. 6), S = gamma ± <Q, E>.

The paper casts the objective as one dense Q·Eᵀ block so the "linear algebra
libraries optimize data reuse via shared memory"; the TPU-native version is an
MXU-blocked matmul with an fp32 VMEM accumulator. Tiles are (bm, bn) output
blocks with a k-loop over the latent dim; every tile dimension is a multiple
of the 128-lane register/MXU width (callers pad via ops.py).

mode="dot" uses the MXU (jnp.dot); mode="l1" computes the translational
distance on the VPU with the same blocking (GQE-style geometries).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _scoring_kernel(q_ref, e_ref, o_ref, acc_ref, *, nk: int, gamma: float, mode: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)          # [bm, bk] VMEM tile
    e = e_ref[...].astype(jnp.float32)          # [bn, bk] VMEM tile
    if mode == "dot":
        acc_ref[...] += jnp.dot(q, e.T, preferred_element_type=jnp.float32)
    else:  # l1: -(sum_d |q - e|) accumulated blockwise over d
        acc_ref[...] += -jnp.sum(
            jnp.abs(q[:, None, :] - e[None, :, :]), axis=-1
        )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = (gamma + acc_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("gamma", "mode", "bm", "bn", "bk", "interpret")
)
def scoring_pallas(
    q: jnp.ndarray,
    e: jnp.ndarray,
    *,
    gamma: float = 0.0,
    mode: str = "dot",
    bm: int = 128,
    bn: int = 256,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q [B, d], e [N, d] -> [B, N]. B % bm == N % bn == d % bk == 0."""
    B, d = q.shape
    N, d2 = e.shape
    # Explicit errors (not asserts — those vanish under `python -O`) naming
    # the offending dim and the multiple it must satisfy.
    if d != d2:
        raise ValueError(
            f"scoring: q feature dim d={d} != e feature dim d={d2}")
    if B % bm != 0:
        raise ValueError(
            f"scoring: q rows B={B} must be a multiple of the row tile "
            f"bm={bm} (the ops.scoring wrapper pads for you)")
    if N % bn != 0:
        raise ValueError(
            f"scoring: e rows N={N} must be a multiple of the column tile "
            f"bn={bn} (the ops.scoring wrapper pads for you)")
    if d % bk != 0:
        raise ValueError(
            f"scoring: feature dim d={d} must be a multiple of the k tile "
            f"bk={bk} (the ops.scoring wrapper pads for you)")
    nk = d // bk
    grid = (B // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_scoring_kernel, nk=nk, gamma=gamma, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(q, e)
