"""JSONL metrics sink: one JSON object per line, thread-safe, append-order.

The trainer writes one record per retired step (phase durations, bubble
fraction, queue depths); launches write a final registry snapshot record.
Readers (``repro.obs.report``, the obs benchmark, CI smoke) stream lines —
no trailing-comma / partial-file hazards on crash, by construction.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["MetricsSink", "read_jsonl"]


class MetricsSink:
    """Thread-safe line-buffered JSONL writer. ``None`` path = disabled sink
    (every ``write`` is a cheap no-op), so call sites need no gating."""

    def __init__(self, path: Optional[str]):
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "w") if path else None
        self.records = 0

    @property
    def enabled(self) -> bool:
        return self._f is not None

    def write(self, record: Dict[str, Any]) -> None:
        if self._f is None:
            return
        line = json.dumps(record)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()
            self.records += 1

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
