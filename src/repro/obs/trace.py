"""Span tracer emitting Chrome-trace-event / Perfetto JSON.

One process-wide :data:`TRACER`, disabled by default. When disabled,
``span()`` returns a shared null context manager — the steady-state cost of
an instrumented call site is one attribute read and one identity return,
which is what lets the instrumentation live permanently in the hot paths
(pipeline scheduler, serving batcher) instead of behind copy-pasted
``if profiling:`` forks.

Event model (the subset of the trace-event format Perfetto's JSON importer
accepts):

* ``ph:"X"`` complete events — a named span with ``ts``/``dur`` (µs since
  tracer start), on the emitting thread's lane.
* ``ph:"M"`` metadata — ``thread_name`` per lane, emitted by
  :meth:`SpanTracer.set_lane` from each instrumented thread ("main
  dispatch", "pipeline scheduler", "sampling worker 0", "serving batcher").
* ``ph:"b"``/``ph:"e"`` async events — cross-thread request spans keyed by
  ``id``; the serving engine opens one per request at submit and closes it
  at completion, so coalesced duplicates keep distinct request spans while
  sharing one batch/compute span.
* ``ph:"i"`` instants and ``ph:"C"`` counters — flush triggers, queue depth.

Spans optionally bridge into ``jax.profiler.TraceAnnotation`` so the same
names line up against device activity when a JAX profile is captured
alongside.

Thread safety: events go into a plain list via ``list.append`` (GIL-atomic);
lane registration takes a lock (rare). ``max_events`` caps memory — on
overflow the tracer drops further events and flags ``truncated`` in the
written file rather than growing without bound.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["SpanTracer", "TRACER", "validate_trace"]

_NULL = contextlib.nullcontext()


class _Span:
    """Context manager recording one ph:"X" event on the current lane."""

    __slots__ = ("tracer", "name", "args", "t0", "_jax_ann")

    def __init__(self, tracer: "SpanTracer", name: str, args: Optional[dict]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self._jax_ann = None

    def __enter__(self):
        tr = self.tracer
        if tr.jax_annotations:
            ann = _trace_annotation(self.name)
            if ann is not None:
                ann.__enter__()
                self._jax_ann = ann
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self._jax_ann is not None:
            self._jax_ann.__exit__(*exc)
        tr = self.tracer
        ev = {"name": self.name, "ph": "X", "pid": tr.pid,
              "tid": tr.lane_tid(),
              "ts": (self.t0 - tr.epoch) * 1e6,
              "dur": (t1 - self.t0) * 1e6, "cat": "repro"}
        if self.args:
            ev["args"] = self.args
        tr._emit(ev)
        return False


def _trace_annotation(name: str):
    try:
        import jax

        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return None


class SpanTracer:
    """Process-wide span recorder. ``enable()`` before the run, ``write()``
    after; everything between is near-free when disabled."""

    def __init__(self, max_events: int = 2_000_000):
        self.enabled = False
        self.jax_annotations = False
        self.pid = 1
        self.epoch = time.perf_counter()
        self.max_events = max_events
        self.truncated = False
        self._events: List[dict] = []
        self._lock = threading.Lock()
        self._lanes: Dict[int, int] = {}  # thread ident -> tid
        self._lane_names: Dict[int, str] = {}  # thread ident -> lane name
        self._next_tid = itertools.count(1)
        self._next_async = itertools.count(1)

    # ------------------------------------------------------------ lifecycle
    def enable(self, *, jax_annotations: bool = True,
               max_events: Optional[int] = None) -> None:
        self.epoch = time.perf_counter()
        self.truncated = False
        self._events = []
        if max_events is not None:
            self.max_events = max_events
        self.jax_annotations = jax_annotations
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # ----------------------------------------------------------------- lanes
    def set_lane(self, name: str) -> None:
        """Name the calling thread's lane (ph:"M" thread_name). Works even
        while the tracer is disabled — long-lived threads (the serving
        batcher, the pipeline scheduler) register once at thread start and
        keep their name across later ``enable()`` calls; the latest name per
        thread wins."""
        ident = threading.get_ident()
        with self._lock:
            tid = self._lanes.get(ident)
            if tid is None:
                tid = next(self._next_tid)
                self._lanes[ident] = tid
            self._lane_names[ident] = name

    def lane_tid(self) -> int:
        ident = threading.get_ident()
        tid = self._lanes.get(ident)
        if tid is None:
            with self._lock:
                tid = self._lanes.get(ident)
                if tid is None:
                    tid = next(self._next_tid)
                    self._lanes[ident] = tid
        return tid

    # ---------------------------------------------------------------- events
    def _emit(self, ev: dict) -> None:
        if len(self._events) >= self.max_events:
            self.truncated = True
            return
        self._events.append(ev)  # GIL-atomic

    def span(self, name: str, **args):
        """Context manager for a named span on the calling thread's lane.
        Returns a shared null context when tracing is off (the fast path)."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t", "pid": self.pid,
              "tid": self.lane_tid(),
              "ts": (time.perf_counter() - self.epoch) * 1e6, "cat": "repro"}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, **values) -> None:
        """ph:"C" counter track (queue depth, batch occupancy over time)."""
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "C", "pid": self.pid,
                    "tid": self.lane_tid(),
                    "ts": (time.perf_counter() - self.epoch) * 1e6,
                    "cat": "repro", "args": values})

    # Async (ph b/e) spans: cross-thread, keyed by id. Used for per-request
    # serving spans — begin on the client thread at submit, end on whichever
    # thread completes the future.
    def next_id(self) -> int:
        return next(self._next_async)

    def async_begin(self, name: str, span_id: int, **args) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "b", "id": span_id, "pid": self.pid,
              "tid": self.lane_tid(),
              "ts": (time.perf_counter() - self.epoch) * 1e6, "cat": "request"}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_end(self, name: str, span_id: int, **args) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "e", "id": span_id, "pid": self.pid,
              "tid": self.lane_tid(),
              "ts": (time.perf_counter() - self.epoch) * 1e6, "cat": "request"}
        if args:
            ev["args"] = args
        self._emit(ev)

    # ---------------------------------------------------------------- output
    def events(self) -> List[dict]:
        with self._lock:
            meta = [{"name": "thread_name", "ph": "M", "pid": self.pid,
                     "tid": tid, "args": {"name": self._lane_names[ident]}}
                    for ident, tid in sorted(self._lanes.items(),
                                             key=lambda kv: kv[1])
                    if ident in self._lane_names]
        return meta + self._events

    def to_json(self) -> Dict[str, Any]:
        obj: Dict[str, Any] = {"traceEvents": self.events(),
                               "displayTimeUnit": "ms"}
        if self.truncated:
            obj["otherData"] = {"truncated": True}
        return obj

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


#: The process-wide tracer all instrumented call sites share.
TRACER = SpanTracer()


_REQUIRED = {"X": ("name", "ph", "ts", "dur", "pid", "tid"),
             "M": ("name", "ph", "pid", "tid", "args"),
             "i": ("name", "ph", "ts", "pid", "tid"),
             "C": ("name", "ph", "ts", "pid", "tid", "args"),
             "b": ("name", "ph", "ts", "id", "pid", "tid"),
             "e": ("name", "ph", "ts", "id", "pid", "tid")}


def validate_trace(obj: Any) -> Dict[str, Any]:
    """Validate a trace object against the trace-event rules Perfetto's JSON
    importer enforces; raise ``ValueError`` on violation, else return a
    summary (``lanes``, ``names``, per-phase ``counts``, async balance).

    Checks: top-level ``traceEvents`` list; every event has the required
    keys for its phase with numeric ``ts``/``dur`` (``dur >= 0``);
    ``thread_name`` metadata carries ``args.name``; ``b``/``e`` events
    balance per (cat, id) with begin-before-end; JSON-serializability.
    """
    if isinstance(obj, str):
        obj = json.loads(obj)
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        raise ValueError("trace must be an object with a traceEvents list")
    events = obj["traceEvents"]
    json.dumps(events)  # everything must serialize
    lanes: Dict[int, str] = {}
    names = set()
    counts: Dict[str, int] = {}
    open_async: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        ph = ev.get("ph")
        req = _REQUIRED.get(ph)
        if req is None:
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        for k in req:
            if k not in ev:
                raise ValueError(f"event {i} (ph={ph}): missing key {k!r}")
        counts[ph] = counts.get(ph, 0) + 1
        if ph == "M":
            if ev["name"] == "thread_name":
                tname = ev.get("args", {}).get("name")
                if not isinstance(tname, str) or not tname:
                    raise ValueError(f"event {i}: thread_name without a name")
                lanes[ev["tid"]] = tname
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)):
            raise ValueError(f"event {i}: non-numeric ts {ts!r}")
        if ph == "X":
            dur = ev["dur"]
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
        names.add(ev["name"])
        if ph == "b":
            key = (ev.get("cat"), ev["id"], ev["name"])
            open_async[key] = open_async.get(key, 0) + 1
        elif ph == "e":
            key = (ev.get("cat"), ev["id"], ev["name"])
            n = open_async.get(key, 0)
            if n <= 0:
                raise ValueError(f"event {i}: async end without begin ({key})")
            open_async[key] = n - 1
    dangling = {k: v for k, v in open_async.items() if v}
    if dangling:
        raise ValueError(f"unbalanced async spans: {dangling}")
    return {"n_events": len(events), "lanes": sorted(lanes.values()),
            "names": sorted(names), "counts": counts}
