"""Process-wide metrics registry (DESIGN.md §Observability).

Every subsystem in the repo grew its own ad-hoc counters — ``CompileCache``
hit/miss ints, ``SemanticCache`` staging totals, ``ServingEngine``'s latency
deque — each with its own ``stats()`` dict and its own ``reset_counters()``
path. This module is the single substrate underneath all of them:

* **Metric primitives** — ``Counter`` (monotonic-ish, int-like so existing
  ``self.hits += 1`` call sites keep working verbatim), ``Gauge`` (last-set
  value: queue depth, batch occupancy), ``Histogram`` (bounded observation
  window + lifetime count/sum: request latency). All carry a name and a
  label tuple (``cache="schedule"``), so many instances of one component
  aggregate cleanly in a snapshot.
* **Lock-free fast path** — ``Counter.inc``/``Gauge.set``/``Histogram.
  observe`` take no registry lock: a counter bump is one attribute add
  (call sites that need exactness already hold their component's lock, as
  before this refactor), a histogram observe is a GIL-atomic deque append.
  The registry lock is touched only at metric CREATION and snapshot time.
* **Snapshot / delta / reset** — ``snapshot()`` aggregates every live
  metric by ``name{labels}`` key (counters/gauges sum across instances;
  histograms contribute ``_count``/``_sum`` and window percentiles);
  ``delta(before)`` subtracts the summable keys; ``reset()`` zeroes EVERY
  counter and histogram in the process and then runs registered reset
  hooks — the one path that fixes the historical counter-reset drift,
  where ``ServingEngine.reset_counters`` and the trainer each reset a
  different subset of the same underlying caches.
* **Weak registration** — the registry holds weakrefs. Components own
  their metrics (via a ``MetricGroup``); when a trainer or engine is
  garbage-collected its metrics silently leave the snapshot, so the
  process-wide registry never accumulates dead tests' counters.

The existing ``stats()`` dict methods are unchanged in keys and meaning —
they are now thin views reading these metrics (``int(self.hits)``).
"""
from __future__ import annotations

import threading
import weakref
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricGroup", "MetricsRegistry",
           "get_registry"]


def _val(x):
    return x._v if isinstance(x, (Counter, Gauge)) else x


class Counter:
    """An int-like accumulator. ``c += 1`` (via ``__iadd__``) and ``c.inc()``
    both bump it in place, so converting ``self.hits = 0`` call sites needs
    no change beyond the declaration; comparisons/arithmetic against plain
    numbers keep existing assertions (``cache.hits == 3``) working."""

    kind = "counter"
    __slots__ = ("name", "labels", "_v", "__weakref__")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._v = 0

    # fast path — no locks (see module docstring)
    def inc(self, n=1) -> None:
        self._v += n

    def __iadd__(self, n):
        self._v += n
        return self

    def __isub__(self, n):
        self._v -= n
        return self

    @property
    def value(self):
        return self._v

    def read(self):
        return self._v

    def reset(self) -> None:
        self._v = 0

    # int-like views so existing call sites/assertions stay verbatim
    def __int__(self):
        return int(self._v)

    def __index__(self):
        return int(self._v)

    def __float__(self):
        return float(self._v)

    def __bool__(self):
        return bool(self._v)

    def __eq__(self, other):
        return self._v == _val(other)

    def __lt__(self, other):
        return self._v < _val(other)

    def __le__(self, other):
        return self._v <= _val(other)

    def __gt__(self, other):
        return self._v > _val(other)

    def __ge__(self, other):
        return self._v >= _val(other)

    def __add__(self, other):
        return self._v + _val(other)

    def __radd__(self, other):
        return _val(other) + self._v

    def __sub__(self, other):
        return self._v - _val(other)

    def __rsub__(self, other):
        return _val(other) - self._v

    def __truediv__(self, other):
        return self._v / _val(other)

    def __rtruediv__(self, other):
        return _val(other) / self._v

    def __mul__(self, other):
        return self._v * _val(other)

    __rmul__ = __mul__
    __hash__ = None  # mutable: never a dict key

    def __repr__(self):
        return f"Counter({metric_key(self)}={self._v})"


class Gauge(Counter):
    """Current-state value (queue depth, occupancy). ``reset()`` is a no-op:
    zeroing a gauge would fabricate a state the system is not in — the
    registry-wide reset zeroes history (counters, histograms), not state."""

    kind = "gauge"
    __slots__ = ()

    def set(self, v) -> None:
        self._v = v

    def reset(self) -> None:
        pass

    def __repr__(self):
        return f"Gauge({metric_key(self)}={self._v})"


class Histogram:
    """Bounded observation window + lifetime count/sum.

    The window (``maxlen``-deque, GIL-atomic append) serves percentiles; the
    lifetime count/sum serve rates and means over the whole run. ``window``
    is surfaced in summaries as ``window_n`` so a p99 over 100 samples is
    never mistaken for a p99 over the run."""

    kind = "histogram"
    __slots__ = ("name", "labels", "window", "_win", "_count", "_sum",
                 "__weakref__")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = (),
                 window: int = 8192):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.name = name
        self.labels = labels
        self.window = window
        self._win: deque = deque(maxlen=window)
        self._count = 0
        self._sum = 0.0

    def observe(self, v) -> None:
        self._win.append(v)
        self._count += 1
        self._sum += v

    def __len__(self):
        return len(self._win)

    @property
    def count(self):
        return self._count

    @property
    def sum(self):
        return self._sum

    def window_values(self) -> List[float]:
        return list(self._win)

    def summary(self) -> Dict[str, float]:
        import numpy as np

        win = np.asarray(self._win, dtype=np.float64)
        out = {"count": int(self._count), "sum": float(self._sum),
               "mean": float(self._sum / self._count) if self._count else 0.0,
               "window_n": int(len(win)), "window": int(self.window)}
        if len(win):
            p50, p95, p99 = np.percentile(win, [50, 95, 99])
            out.update(p50=float(p50), p95=float(p95), p99=float(p99),
                       max=float(win.max()))
        else:
            out.update(p50=0.0, p95=0.0, p99=0.0, max=0.0)
        return out

    def reset(self) -> None:
        self._win.clear()
        self._count = 0
        self._sum = 0.0

    def __repr__(self):
        return f"Histogram({metric_key(self)} n={self._count})"


def metric_key(m) -> str:
    """Stable flat key: ``name`` or ``name{k=v,...}`` (sorted labels)."""
    if not m.labels:
        return m.name
    inner = ",".join(f"{k}={v}" for k, v in sorted(m.labels))
    return f"{m.name}{{{inner}}}"


class MetricGroup:
    """One component's metrics: a shared name prefix + label set.

    The component holds the group (strong refs); the registry holds only
    weakrefs. ``reset()`` zeroes just this group — the building block every
    component-level ``reset_counters()`` is now implemented with, so there
    is exactly one reset mechanism in the codebase."""

    def __init__(self, registry: "MetricsRegistry", prefix: str, **labels):
        self._registry = registry
        self.prefix = prefix
        self.labels = tuple(sorted((k, str(v)) for k, v in labels.items()))
        self._metrics: List = []

    def _add(self, m):
        self._metrics.append(m)
        self._registry.register(m)
        return m

    def counter(self, name: str, **labels) -> Counter:
        lb = self.labels + tuple(sorted((k, str(v)) for k, v in labels.items()))
        return self._add(Counter(f"{self.prefix}_{name}", lb))

    def gauge(self, name: str, **labels) -> Gauge:
        lb = self.labels + tuple(sorted((k, str(v)) for k, v in labels.items()))
        return self._add(Gauge(f"{self.prefix}_{name}", lb))

    def histogram(self, name: str, window: int = 8192, **labels) -> Histogram:
        lb = self.labels + tuple(sorted((k, str(v)) for k, v in labels.items()))
        return self._add(Histogram(f"{self.prefix}_{name}", lb, window=window))

    def reset(self, only: Optional[Iterable] = None) -> None:
        """Zero this group's counters/histograms (gauges keep state). With
        ``only``, reset just those metric objects — for components whose
        public ``reset_counters`` deliberately preserves a subset (e.g. the
        serving engine keeps submitted/completed across warmup resets)."""
        targets = self._metrics if only is None else list(only)
        for m in targets:
            m.reset()

    def metrics(self) -> List:
        return list(self._metrics)


class MetricsRegistry:
    """Weak collection of every live metric in the process + reset hooks."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: List[weakref.ref] = []
        self._hooks: List = []  # weakref.ref / weakref.WeakMethod

    # --------------------------------------------------------- registration
    def group(self, prefix: str, **labels) -> MetricGroup:
        return MetricGroup(self, prefix, **labels)

    def register(self, metric) -> None:
        with self._lock:
            self._metrics.append(weakref.ref(metric))

    def on_reset(self, fn) -> None:
        """Register a callback run after every registry-wide ``reset()`` —
        components use this to re-baseline derived deltas (e.g. the serving
        engine's scorer-trace baseline). Held weakly: a dead component's
        hook disappears with it."""
        ref = (weakref.WeakMethod(fn) if hasattr(fn, "__self__")
               else weakref.ref(fn))
        with self._lock:
            self._hooks.append(ref)

    def metrics(self) -> List:
        """Live metrics (dead weakrefs pruned as a side effect)."""
        with self._lock:
            live, refs = [], []
            for r in self._metrics:
                m = r()
                if m is not None:
                    live.append(m)
                    refs.append(r)
            self._metrics = refs
        return live

    # ------------------------------------------------------------ snapshots
    def snapshot(self) -> Dict[str, float]:
        """Flat ``{key: number}`` view of every live metric, aggregated by
        key: counters and gauges SUM across same-key instances (two engines'
        ``serving_batches`` add up to the process total); histograms emit
        ``_count``/``_sum`` (summed) plus window percentiles (merged)."""
        import numpy as np

        out: Dict[str, float] = {}
        windows: Dict[str, list] = {}
        for m in self.metrics():
            key = metric_key(m)
            if m.kind == "histogram":
                out[key + "_count"] = out.get(key + "_count", 0) + m.count
                out[key + "_sum"] = out.get(key + "_sum", 0.0) + m.sum
                windows.setdefault(key, []).extend(m.window_values())
            else:
                out[key] = out.get(key, 0) + m.read()
        for key, win in windows.items():
            if win:
                arr = np.asarray(win, dtype=np.float64)
                p50, p95, p99 = np.percentile(arr, [50, 95, 99])
                out.update({key + "_p50": float(p50), key + "_p95": float(p95),
                            key + "_p99": float(p99)})
            out[key + "_window_n"] = len(win)
        return out

    def delta(self, before: Dict[str, float]) -> Dict[str, float]:
        """Current snapshot minus ``before`` for subtractable keys (counters,
        gauges, histogram counts/sums); point-in-time keys (percentiles,
        window sizes) pass through as-is."""
        now = self.snapshot()
        out = {}
        for k, v in now.items():
            if k.endswith(("_p50", "_p95", "_p99", "_window_n")):
                out[k] = v
            else:
                out[k] = v - before.get(k, 0)
        return out

    # ---------------------------------------------------------------- reset
    def reset(self) -> None:
        """Zero EVERY counter and histogram in the process, then run reset
        hooks. This is the registry-level reset the satellite demands: no
        component-specific path can leave a sibling's counters drifted,
        because there are no component-specific paths — only groups of
        metrics this loop reaches."""
        for m in self.metrics():
            m.reset()
        with self._lock:
            hooks, refs = [], []
            for r in self._hooks:
                fn = r()
                if fn is not None:
                    hooks.append(fn)
                    refs.append(r)
            self._hooks = refs
        for fn in hooks:
            fn()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every component registers into."""
    return _REGISTRY
