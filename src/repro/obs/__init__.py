"""Unified telemetry: metrics registry, span tracer, JSONL sink.

See DESIGN.md §Observability. Import surface:

    from repro.obs import get_registry, TRACER, MetricsSink
"""
from repro.obs.registry import (Counter, Gauge, Histogram, MetricGroup,
                                MetricsRegistry, get_registry)
from repro.obs.sink import MetricsSink, read_jsonl
from repro.obs.trace import TRACER, SpanTracer, validate_trace

__all__ = ["Counter", "Gauge", "Histogram", "MetricGroup", "MetricsRegistry",
           "get_registry", "MetricsSink", "read_jsonl", "TRACER",
           "SpanTracer", "validate_trace"]
