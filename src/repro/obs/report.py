"""Summarize a trace + metrics pair from an instrumented run.

  PYTHONPATH=src python -m repro.obs.report --trace /tmp/train.trace.json \
      --metrics /tmp/train.metrics.jsonl

Reads the Chrome-trace-event JSON written by ``--trace`` and the JSONL
written by ``--metrics`` (``launch/train.py`` / ``launch/serve.py``) and
prints:

* **top spans** — total/mean duration and count per span name, per lane,
  from the ph:"X" events (where the step time actually goes);
* **step-time breakdown** — mean per-phase seconds and the pipeline bubble
  fraction over the run's "step" records (pipelined runs only; the bubble is
  the fraction of each step's wall time the dispatcher spent blocked on the
  scheduler — 0 means perfect overlap);
* **cache hit tables** — every ``*_hits``/``*_misses`` pair in the final
  registry snapshot record, one row per cache instance.

All three sections degrade gracefully: pass only one of --trace/--metrics
and the other sections are skipped.
"""
from __future__ import annotations

import argparse
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional

from repro.obs.sink import read_jsonl
from repro.obs.trace import validate_trace

__all__ = ["summarize_trace", "summarize_metrics", "cache_tables", "main"]


def summarize_trace(obj, top: int = 8) -> str:
    """Top spans by total duration, grouped per lane."""
    summary = validate_trace(obj)
    lanes: Dict[int, str] = {}
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            lanes[ev["tid"]] = ev["args"]["name"]
    agg: Dict[str, Dict[str, List[float]]] = defaultdict(lambda: defaultdict(list))
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "X":
            lane = lanes.get(ev["tid"], f"tid {ev['tid']}")
            agg[lane][ev["name"]].append(ev["dur"])
    lines = [f"trace: {summary['n_events']} events, "
             f"{len(summary['lanes'])} lanes "
             f"({', '.join(summary['lanes'])})"]
    for lane in sorted(agg):
        lines.append(f"  lane [{lane}]")
        rows = sorted(agg[lane].items(),
                      key=lambda kv: -sum(kv[1]))[:top]
        for name, durs in rows:
            tot = sum(durs)
            lines.append(f"    {name:<14} {len(durs):>6}x  "
                         f"total {tot/1e3:>9.1f} ms  "
                         f"mean {tot/len(durs)/1e3:>7.3f} ms")
    return "\n".join(lines)


def summarize_metrics(records: List[dict]) -> str:
    """Mean phase seconds + bubble fraction over the run's step records."""
    steps = [r for r in records if r.get("kind") == "step"]
    if not steps:
        return "metrics: no step records (snapshot-only file)"
    phase_keys = sorted({k for r in steps for k in r
                         if k.endswith("_s") and k != "wall_s"})
    lines = [f"metrics: {len(steps)} step records "
             f"(mode {steps[0].get('mode', '?')})"]
    wall = sum(r.get("wall_s", 0.0) for r in steps)
    for k in phase_keys:
        tot = sum(r.get(k, 0.0) for r in steps)
        share = f"  ({tot / wall:.1%} of wall)" if wall else ""
        lines.append(f"  {k[:-2]:<14} total {tot:>8.3f} s  "
                     f"mean {tot / len(steps) * 1e3:>8.2f} ms/step{share}")
    bubbles = [r["bubble_frac"] for r in steps if "bubble_frac" in r]
    if bubbles:
        lines.append(f"  pipeline bubble: mean {sum(bubbles)/len(bubbles):.1%}"
                     f", max {max(bubbles):.1%} "
                     f"(overlap {1 - sum(bubbles)/len(bubbles):.1%})")
    return "\n".join(lines)


_HIT_RE = re.compile(r"^(?P<base>[a-z0-9_]+)_hits(?P<labels>\{.*\})?$")


def cache_tables(snapshot: Dict[str, float]) -> str:
    """One row per ``*_hits``/``*_misses`` pair in a registry snapshot."""
    rows = []
    for key, hits in sorted(snapshot.items()):
        m = _HIT_RE.match(key)
        if not m:
            continue
        miss_key = f"{m['base']}_misses{m['labels'] or ''}"
        misses = snapshot.get(miss_key)
        if misses is None:
            continue
        total = hits + misses
        rate = hits / total if total else 0.0
        label = f"{m['base']}{m['labels'] or ''}"
        rows.append(f"  {label:<40} hits {int(hits):>8}  "
                    f"misses {int(misses):>7}  rate {rate:>6.1%}")
    if not rows:
        return "caches: no hit/miss pairs in snapshot"
    return "\n".join(["caches:"] + rows)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.obs.report")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="trace-event JSON written by --trace")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="JSONL written by --metrics")
    ap.add_argument("--top", type=int, default=8,
                    help="span names per lane in the trace table")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("pass --trace and/or --metrics")
    if args.trace:
        with open(args.trace) as f:
            print(summarize_trace(json.load(f), top=args.top))
    if args.metrics:
        records = read_jsonl(args.metrics)
        print(summarize_metrics(records))
        snaps = [r for r in records if r.get("kind") == "snapshot"]
        if snaps:
            print(cache_tables(snaps[-1]["metrics"]))


if __name__ == "__main__":
    main()
