"""NGDB-Zoo on JAX: operator-level batched training for Neural Graph
Databases, with decoupled semantic integration, an online query sampler,
Pallas TPU kernels for the scoring/intersection/gather hot-spots, and a
multi-pod distribution layer hosting the 10 assigned LM architectures."""

__version__ = "1.0.0"

from repro.core import (  # noqa: F401
    OpType,
    PooledExecutor,
    QueryInstance,
    QueryLevelExecutor,
    answer_query,
    build_batched_dag,
    schedule,
)
from repro.data import KnowledgeGraph, generate_synthetic_kg, load_dataset  # noqa: F401
from repro.models import ModelConfig, make_model, model_names  # noqa: F401
from repro.sampling import AdaptiveDistribution, OnlineSampler  # noqa: F401
from repro.semantic import StubPTE, precompute_semantic_table  # noqa: F401
from repro.training import NGDBTrainer, TrainConfig, evaluate  # noqa: F401
