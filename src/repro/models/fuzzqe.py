"""FuzzQE (Chen et al., 2022): fuzzy-logic query embeddings. States live in
[0,1]^d; intersection/union/negation are product t-norm / probabilistic sum /
complement — exactly the closed fuzzy-logic operators."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, QueryEncoder, mlp_apply, mlp_params, register_model

_EPS = 1e-6


@register_model("fuzzqe")
class FuzzQE(QueryEncoder):
    @property
    def state_dim(self) -> int:
        return self.cfg.dim

    def init_geometry(self, key, n_entities, n_relations):
        d, h = self.cfg.dim, self.cfg.dim * self.cfg.hidden_mult
        k1, k2 = jax.random.split(key)
        p = {"relation": jax.random.normal(k1, (n_relations, d)) * (1.0 / jnp.sqrt(d))}
        p.update(mlp_params(k2, (2 * d, h, d), "proj"))
        return p

    def entity_state(self, params, ent_vec):
        return jax.nn.sigmoid(ent_vec * 3.0)

    def _logit(self, x):
        x = jnp.clip(x, _EPS, 1.0 - _EPS)
        return jnp.log(x) - jnp.log1p(-x)

    def project(self, params, x, rel_ids):
        r = params["relation"][rel_ids]
        y = mlp_apply(params, "proj", jnp.concatenate([self._logit(x), r], axis=-1), 2)
        return jax.nn.sigmoid(y)

    def intersect(self, params, X):
        # Product t-norm, numerically as exp(sum log).
        return jnp.exp(jnp.sum(jnp.log(jnp.clip(X, _EPS, 1.0)), axis=1))

    def union(self, params, X):
        # Probabilistic sum: 1 - prod(1 - x).
        return 1.0 - jnp.exp(jnp.sum(jnp.log(jnp.clip(1.0 - X, _EPS, 1.0)), axis=1))

    def negate(self, params, x):
        return 1.0 - x

    def distance(self, params, q, ent_vec):
        e = self.entity_state(params, ent_vec)
        sim = jnp.sum(q * e, axis=-1) / (
            jnp.linalg.norm(q, axis=-1) * jnp.linalg.norm(e, axis=-1) + _EPS
        )
        return (1.0 - sim) * jnp.sqrt(self.cfg.dim)
