"""Query2Particles (Bai et al., 2022): multi-particle query states with
attention-based particle selection for the set operators."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, QueryEncoder, glorot, mlp_apply, mlp_params, register_model


@register_model("q2p")
class Q2P(QueryEncoder):
    @property
    def np_(self) -> int:
        return self.cfg.n_particles

    @property
    def state_dim(self) -> int:
        return self.np_ * self.cfg.dim

    def init_geometry(self, key, n_entities, n_relations):
        d, h = self.cfg.dim, self.cfg.dim * self.cfg.hidden_mult
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        p = {
            "relation": jax.random.normal(k1, (n_relations, d)) * (1.0 / jnp.sqrt(d)),
            "particle_offsets": jax.random.normal(k2, (self.np_, d)) * 0.1,
            "int_queries": jax.random.normal(k5, (self.np_, d)) * (1.0 / jnp.sqrt(d)),
            "uni_queries": jax.random.normal(k6, (self.np_, d)) * (1.0 / jnp.sqrt(d)),
        }
        p.update(mlp_params(k3, (2 * d, h, d), "proj"))
        p.update(mlp_params(k4, (d, h, d), "neg"))
        return p

    def _particles(self, s):
        return s.reshape(s.shape[:-1] + (self.np_, self.cfg.dim))

    def _flat(self, P):
        return P.reshape(P.shape[:-2] + (self.state_dim,))

    def entity_state(self, params, ent_vec):
        P = ent_vec[..., None, :] + params["particle_offsets"]
        return self._flat(P)

    def project(self, params, x, rel_ids):
        P = self._particles(x)                                   # [n, p, d]
        r = params["relation"][rel_ids][..., None, :]
        Y = mlp_apply(params, "proj", jnp.concatenate([P, jnp.broadcast_to(r, P.shape)], -1), 2)
        return self._flat(P + Y)                                 # residual move

    def _select(self, params, X, queries):
        # X: [n, k, sd] -> all particles [n, k*p, d]; attend with np learned
        # queries to re-select a fixed-size particle set.
        n, k, _ = X.shape
        allP = self._particles(X).reshape(n, k * self.np_, self.cfg.dim)
        logits = jnp.einsum("pd,nmd->npm", queries, allP) / jnp.sqrt(self.cfg.dim)
        att = jax.nn.softmax(logits, axis=-1)
        return self._flat(jnp.einsum("npm,nmd->npd", att, allP))

    def intersect(self, params, X):
        return self._select(params, X, params["int_queries"])

    def union(self, params, X):
        return self._select(params, X, params["uni_queries"])

    def negate(self, params, x):
        P = self._particles(x)
        return self._flat(mlp_apply(params, "neg", P, 2))

    def distance(self, params, q, ent_vec):
        P = self._particles(q)                                    # [.., p, d]
        sims = jnp.einsum("...pd,...d->...p", P, ent_vec)
        return -jnp.max(sims, axis=-1) / jnp.sqrt(self.cfg.dim)
