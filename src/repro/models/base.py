"""Unified operator interface for query-encoder backbones.

Every model exposes the five pooled operators over a FLAT state vector
[n, state_dim] so the executor is model-agnostic — the pooled kernels are
exactly the Kernel_{tau}(X_batch; theta_tau) of Eq. 5.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    dim: int = 400                 # latent dimension (Table 5)
    gamma: float = 12.0            # margin (Table 5)
    n_particles: int = 2           # Q2P
    hidden_mult: int = 2           # operator MLP width multiplier
    semantic_dim: int = 0          # d_l of the PTE manifold; 0 = structural-only
    semantic_proj_dim: int = 64    # F: R^{d_l} -> R^{proj} before concat (Eq. 12)
    dtype: str = "float32"
    # Pad entity-table rows to a multiple of this so the tables divide the
    # mesh's model axis (§Perf: unpadded ogbl-wikikg2 has 2,500,604 entities —
    # indivisible by 16 — and the sharding rules silently replicate 14GB of
    # tables onto every device). Padded rows are masked out of score_all.
    entity_pad: int = 1
    # Route the hot-spot ops through the Pallas TPU kernels (repro/kernels):
    # the Eq. 6 scoring matmul (models that expose ``pallas_score_mode``) and
    # the cardinality-class attention intersection (BetaE). On CPU hosts the
    # kernels run in interpret mode — bit-equivalent, Python-speed.
    use_pallas: bool = False


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype) * scale


def mlp_params(key, sizes, prefix):
    ks = jax.random.split(key, len(sizes) - 1)
    p = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        p[f"{prefix}_w{i}"] = glorot(ks[i], (a, b))
        p[f"{prefix}_b{i}"] = jnp.zeros((b,))
    return p


def mlp_apply(p, prefix, x, n_layers, act=jax.nn.relu, final_act=None):
    for i in range(n_layers):
        x = x @ p[f"{prefix}_w{i}"] + p[f"{prefix}_b{i}"]
        if i < n_layers - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


class QueryEncoder:
    """Base class. Subclasses implement the geometry; the fused-entity path
    (structural ⊕ semantic, Eq. 12) is shared here."""

    name: str = "base"
    # "dot" | "l1" when the geometry's distance is expressible by the Pallas
    # scoring kernel (score = gamma ± <q, e>); None = jnp path only.
    pallas_score_mode = None

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # --- geometry interface -------------------------------------------------
    @property
    def state_dim(self) -> int:
        raise NotImplementedError

    def init_geometry(self, key, n_entities: int, n_relations: int) -> Dict:
        raise NotImplementedError

    def entity_state(self, params, ent_vec: jnp.ndarray) -> jnp.ndarray:
        """Lift a fused entity vector [n, dim] into operator state [n, sd]."""
        raise NotImplementedError

    def project(self, params, x, rel_ids) -> jnp.ndarray:
        raise NotImplementedError

    def intersect(self, params, X) -> jnp.ndarray:  # [n, k, sd] -> [n, sd]
        raise NotImplementedError

    def union(self, params, X) -> jnp.ndarray:
        raise NotImplementedError

    def negate(self, params, x) -> jnp.ndarray:
        raise NotImplementedError

    def distance(self, params, q, ent_vec) -> jnp.ndarray:
        """d(q, e): q [.., sd] vs fused entity vec [.., dim] -> [..]."""
        raise NotImplementedError

    # --- shared fused-entity path (Eq. 11 + 12) ------------------------------
    def padded_entities(self, n_entities: int) -> int:
        m = self.cfg.entity_pad
        return ((n_entities + m - 1) // m) * m

    def init_params(self, key, n_entities: int, n_relations: int,
                    semantic_table: Optional[jnp.ndarray] = None,
                    semantic_cache=None) -> Dict:
        """Semantic mode is decided by which buffer is supplied:

        * ``semantic_table`` — full-resident frozen ``sem_table`` (small
          graphs / ablation baseline);
        * ``semantic_cache`` — a ``semantic.store.SemanticCache``: the params
          carry the bounded ``sem_cache`` hot-set buffer plus the
          ``sem_slot`` entity-id -> cache-slot indirection instead of the
          full table. Gathers must be preceded by ``cache.plan``/``apply_to``
          (the pipeline does this for training batches).
        """
        k1, k2, k3 = jax.random.split(key, 3)
        d = self.cfg.dim
        self.n_entities = n_entities  # real count; tables may be padded
        rows = self.padded_entities(n_entities)
        p = {"entity": jax.random.normal(k1, (rows, d)) * (1.0 / np.sqrt(d))}
        p.update(self.init_geometry(k2, n_entities, n_relations))
        if self.cfg.semantic_dim > 0:
            if semantic_cache is not None:
                assert semantic_cache.dim == self.cfg.semantic_dim, (
                    semantic_cache.dim, self.cfg.semantic_dim)
                assert semantic_cache.n_rows >= n_entities
                p["sem_cache"] = semantic_cache.buffer   # [budget, d_l] hot set
                p["sem_slot"] = semantic_cache.slot_map  # [E] id -> slot
            else:
                assert semantic_table is not None and semantic_table.shape[1] == self.cfg.semantic_dim
                st = jnp.asarray(semantic_table)
                if st.shape[0] < rows:
                    st = jnp.pad(st, ((0, rows - st.shape[0]), (0, 0)))
                p["sem_table"] = st  # frozen H_sem buffer
            dp = self.cfg.semantic_proj_dim
            p["sem_proj_w"] = glorot(k3, (self.cfg.semantic_dim, dp))
            p["sem_proj_b"] = jnp.zeros((dp,))
            kf = jax.random.fold_in(k3, 1)
            p["fuse_w"] = glorot(kf, (d + dp, d))
            p["fuse_b"] = jnp.zeros((d,))
        return p

    def frozen_param_names(self):
        """Params excluded from gradients AND from real optimizer moments:
        the H_sem buffer in either layout (full-resident table, or hot-set
        cache + its int32 indirection — the latter could not be
        differentiated at all)."""
        return ("sem_table", "sem_cache", "sem_slot")

    def semantic_rows(self, params, ent_ids) -> jnp.ndarray:
        """Gather(H_sem, I) — Eq. 11, in whichever layout the params carry:
        the full-resident ``sem_table`` or the device cache via the
        ``sem_slot`` indirection (ids must have been staged by the cache)."""
        if "sem_slot" in params:
            return params["sem_cache"][params["sem_slot"][ent_ids]]
        return params["sem_table"][ent_ids]

    def fuse_semantic(self, params, h, z) -> jnp.ndarray:
        """Eq. 12 on already-gathered rows: h [.., d] structural, z [.., d_l]
        semantic -> fused [.., d]. Shared by the train-time gather path and
        the chunked/streaming scorers, so their numerics are identical."""
        z = z @ params["sem_proj_w"] + params["sem_proj_b"]   # F: d_l -> dp
        x = jnp.concatenate([h, z], axis=-1)
        return jax.nn.sigmoid(x @ params["fuse_w"] + params["fuse_b"]) * 2.0 - 1.0

    def fused_entity_vec(self, params, ent_ids) -> jnp.ndarray:
        """x_i = sigma(W_p [h_str ⊕ F(h_sem)] + b_p) — Eq. 12. Pure gathers +
        one small matmul; the PTE itself never appears in the train loop."""
        h = params["entity"][ent_ids]
        if self.cfg.semantic_dim == 0:
            return h
        return self.fuse_semantic(params, h, self.semantic_rows(params, ent_ids))

    def embed(self, params, ent_ids) -> jnp.ndarray:
        return self.entity_state(params, self.fused_entity_vec(params, ent_ids))

    # --- scoring -------------------------------------------------------------
    def score_ids(self, params, q, ent_ids) -> jnp.ndarray:
        """gamma - d(q, e) for given candidate ids. q [B, sd], ids [B, M]."""
        ev = self.fused_entity_vec(params, ent_ids)           # [B, M, dim]
        return self.cfg.gamma - self.distance(params, q[:, None, :], ev)

    def score_all(self, params, q) -> jnp.ndarray:
        """Logits against EVERY entity (vectorized logit formulation, Eq. 6).
        Padded table rows are masked to -inf."""
        if "sem_slot" in params:
            raise RuntimeError(
                "score_all needs every entity's semantic row, but these "
                "params carry the bounded hot-set cache; use "
                "score_all_chunked(params, q, store.read_rows) to stream "
                "over the on-disk store instead")
        rows = params["entity"].shape[0]
        ids = jnp.arange(rows)
        ev = self.fused_entity_vec(params, ids)               # [E, dim]
        if self.cfg.use_pallas and self.pallas_score_mode:
            from repro.kernels import ops as kops

            scores = kops.scoring(q, ev, gamma=self.cfg.gamma,
                                  mode=self.pallas_score_mode)
        else:
            scores = self.cfg.gamma - self.distance(
                params, q[:, None, :], ev[None, :, :])
        n_real = getattr(self, "n_entities", rows)
        if n_real != rows:
            scores = jnp.where(ids[None, :] < n_real, scores, -1e30)
        return scores

    def score_all_chunked(self, params, q, sem_rows_fn,
                          chunk: int = 4096) -> np.ndarray:
        """Out-of-core twin of ``score_all`` for the semantic-store path:
        streams entity chunks (structural slice + ``sem_rows_fn(ids)`` rows
        read from the store), fuses and scores each on device, and assembles
        host scores — the full ``[E, d_l]`` table never exists anywhere.
        Returns np [B, n_real]; ``sem_rows_fn`` is e.g.
        ``SemanticStore.read_rows``. Works for resident params too (pass
        ``lambda ids: np.asarray(params["sem_table"])[ids]``)."""
        rows = params["entity"].shape[0]
        n_real = getattr(self, "n_entities", rows)
        outs = []
        for lo in range(0, n_real, chunk):
            hi = min(lo + chunk, n_real)
            h = params["entity"][lo:hi]
            if self.cfg.semantic_dim > 0:
                z = jnp.asarray(sem_rows_fn(np.arange(lo, hi)))
                ev = self.fuse_semantic(params, h, z)
            else:
                ev = h
            outs.append(np.asarray(
                self.cfg.gamma - self.distance(params, q[:, None, :], ev[None, :, :])))
        return np.concatenate(outs, axis=1)


_REGISTRY: Dict[str, Callable[[ModelConfig], QueryEncoder]] = {}


def register_model(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def _load_builtin():
    import repro.models.betae  # noqa: F401
    import repro.models.complex_e  # noqa: F401
    import repro.models.fuzzqe  # noqa: F401
    import repro.models.gqe  # noqa: F401
    import repro.models.q2b  # noqa: F401
    import repro.models.q2p  # noqa: F401


def make_model(name: str, cfg: Optional[ModelConfig] = None) -> QueryEncoder:
    _load_builtin()
    return _REGISTRY[name](cfg or ModelConfig())


def model_names():
    _load_builtin()
    return sorted(_REGISTRY)
