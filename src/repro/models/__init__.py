from repro.models.base import ModelConfig, QueryEncoder, make_model, model_names

__all__ = ["ModelConfig", "QueryEncoder", "make_model", "model_names"]
