"""GQE (Hamilton et al., 2018): translational projection + DeepSets intersection."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, QueryEncoder, glorot, mlp_apply, mlp_params, register_model


@register_model("gqe")
class GQE(QueryEncoder):
    pallas_score_mode = "l1"  # score = gamma - |q - e|_1 == scoring kernel l1

    @property
    def state_dim(self) -> int:
        return self.cfg.dim

    def init_geometry(self, key, n_entities, n_relations):
        d, h = self.cfg.dim, self.cfg.dim * self.cfg.hidden_mult
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"relation": jax.random.normal(k1, (n_relations, d)) * (1.0 / jnp.sqrt(d))}
        p.update(mlp_params(k2, (d, h, d), "int"))          # DeepSets phi
        p["int_out_w"] = glorot(k3, (d, d))                 # DeepSets rho
        p.update(mlp_params(k4, (d, h, d), "neg"))          # negation extension
        return p

    def entity_state(self, params, ent_vec):
        return ent_vec

    def project(self, params, x, rel_ids):
        return x + params["relation"][rel_ids]

    def intersect(self, params, X):
        h = mlp_apply(params, "int", X, 2)                  # [n, k, d]
        return jnp.mean(h, axis=1) @ params["int_out_w"]

    def union(self, params, X):
        # Smooth elementwise max — a permutation-invariant union surrogate.
        return jax.nn.logsumexp(X * 4.0, axis=1) / 4.0

    def negate(self, params, x):
        return mlp_apply(params, "neg", x, 2)

    def distance(self, params, q, ent_vec):
        return jnp.sum(jnp.abs(q - ent_vec), axis=-1)
