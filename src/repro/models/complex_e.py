"""ComplEx (Trouillon et al., 2016) as a QueryEncoder — used by the Table 2
single-hop (KG completion) runtime benchmark, matching the paper's choice of
ComplEx/d=100 on Freebase. Projection is the complex Hadamard rotation; the
set operators are simple elementwise surrogates (ComplEx is a 1p model; the
surrogates just keep every pattern runnable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, QueryEncoder, register_model


@register_model("complex")
class ComplExE(QueryEncoder):
    pallas_score_mode = "dot"  # Re<q, conj(e)> == plain dot in this layout

    @property
    def state_dim(self) -> int:
        return self.cfg.dim  # dim/2 real + dim/2 imaginary

    def init_geometry(self, key, n_entities, n_relations):
        return {
            "relation": jax.random.normal(key, (n_relations, self.cfg.dim))
            * (1.0 / jnp.sqrt(self.cfg.dim))
        }

    def _split(self, s):
        d = self.cfg.dim // 2
        return s[..., :d], s[..., d:]

    def entity_state(self, params, ent_vec):
        return ent_vec

    def project(self, params, x, rel_ids):
        xr, xi = self._split(x)
        rr, ri = self._split(params["relation"][rel_ids])
        return jnp.concatenate([xr * rr - xi * ri, xr * ri + xi * rr], axis=-1)

    def intersect(self, params, X):
        return jnp.min(X, axis=1)

    def union(self, params, X):
        return jnp.max(X, axis=1)

    def negate(self, params, x):
        return -x

    def distance(self, params, q, ent_vec):
        qr, qi = self._split(q)
        er, ei = self._split(ent_vec)
        score = jnp.sum(qr * er + qi * ei, axis=-1)  # Re<q, conj(e)>
        return -score
