"""BetaE (Ren & Leskovec, 2020): Beta-distribution embeddings with closed-form
negation (reciprocal parameters) and attention-weighted intersection."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import betaln, digamma

from repro.models.base import ModelConfig, QueryEncoder, mlp_apply, mlp_params, register_model

_EPS = 0.05
_MAXP = 40.0


def _clip(p):
    return jnp.clip(p, _EPS, _MAXP)


@register_model("betae")
class BetaE(QueryEncoder):
    @property
    def state_dim(self) -> int:
        return 2 * self.cfg.dim

    def init_geometry(self, key, n_entities, n_relations):
        d, h = self.cfg.dim, self.cfg.dim * self.cfg.hidden_mult
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"relation": jax.random.normal(k1, (n_relations, d)) * (1.0 / jnp.sqrt(d))}
        p.update(mlp_params(k2, (3 * d, h, 2 * d), "proj"))   # Psi_theta projection MLP
        p.update(mlp_params(k3, (2 * d, h, 1), "att"))        # intersection attention
        p.update(mlp_params(k4, (2 * d, h, 1), "uatt"))       # union mixture attention
        return p

    def _split(self, s):
        d = self.cfg.dim
        return s[..., :d], s[..., d:]

    def entity_state(self, params, ent_vec):
        # Sufficient statistics from the joint embedding (Eq. 3): the fused
        # vector parameterizes (alpha, beta) via a smooth positive map.
        a = _clip(jax.nn.softplus(ent_vec * 2.0) + _EPS)
        b = _clip(jax.nn.softplus(-ent_vec * 2.0) + _EPS)
        return jnp.concatenate([a, b], axis=-1)

    def project(self, params, x, rel_ids):
        r = params["relation"][rel_ids]
        y = mlp_apply(params, "proj", jnp.concatenate([x, r], axis=-1), 2)
        return _clip(jax.nn.softplus(y) + _EPS)

    def _attn_combine(self, params, X, prefix):
        if self.cfg.use_pallas:
            # cardinality-class fused kernel (one VMEM pass per class, Eq. 8/9)
            from repro.kernels import ops as kops

            return _clip(kops.intersect(
                X, params[f"{prefix}_w0"], params[f"{prefix}_b0"],
                params[f"{prefix}_w1"], params[f"{prefix}_b1"]))
        w = jax.nn.softmax(mlp_apply(params, prefix, X, 2), axis=1)  # [n, k, 1]
        return _clip(jnp.sum(w * X, axis=1))

    def intersect(self, params, X):
        return self._attn_combine(params, X, "att")

    def union(self, params, X):
        # Mixture surrogate (native BetaE rewrites unions to DNF).
        return self._attn_combine(params, X, "uatt")

    def negate(self, params, x):
        return _clip(1.0 / jnp.maximum(x, _EPS))

    def distance(self, params, q, ent_vec):
        ae, be = self._split(self.entity_state(params, ent_vec))
        aq, bq = self._split(q)
        aq, bq = _clip(aq), _clip(bq)
        # KL( Beta(ae,be) || Beta(aq,bq) ), summed over dims.
        kl = (
            betaln(aq, bq)
            - betaln(ae, be)
            + (ae - aq) * digamma(ae)
            + (be - bq) * digamma(be)
            + (aq - ae + bq - be) * digamma(ae + be)
        )
        return jnp.sum(kl, axis=-1) / jnp.sqrt(self.cfg.dim)
