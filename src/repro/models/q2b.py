"""Query2Box (Ren et al., 2020): box embeddings (center ⊕ offset)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, QueryEncoder, glorot, mlp_apply, mlp_params, register_model


@register_model("q2b")
class Q2B(QueryEncoder):
    ALPHA = 0.02  # inside-distance downweight (paper default)

    @property
    def state_dim(self) -> int:
        return 2 * self.cfg.dim

    def init_geometry(self, key, n_entities, n_relations):
        d, h = self.cfg.dim, self.cfg.dim * self.cfg.hidden_mult
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        p = {
            "rel_center": jax.random.normal(k1, (n_relations, d)) * (1.0 / jnp.sqrt(d)),
            "rel_offset": jax.random.normal(k2, (n_relations, d)) * 0.1,
        }
        p.update(mlp_params(k3, (2 * d, h, d), "att"))   # center attention scorer
        p.update(mlp_params(k4, (2 * d, h, d), "off"))   # offset DeepSets
        p.update(mlp_params(k5, (2 * d, h, 2 * d), "neg"))
        return p

    def _split(self, s):
        d = self.cfg.dim
        return s[..., :d], s[..., d:]

    def _join(self, c, o):
        return jnp.concatenate([c, o], axis=-1)

    def entity_state(self, params, ent_vec):
        return self._join(ent_vec, jnp.zeros_like(ent_vec))

    def project(self, params, x, rel_ids):
        c, o = self._split(x)
        c = c + params["rel_center"][rel_ids]
        o = o + jax.nn.softplus(params["rel_offset"][rel_ids])
        return self._join(c, o)

    def intersect(self, params, X):
        C, O = self._split(X)                                   # [n, k, d]
        att = jax.nn.softmax(mlp_apply(params, "att", X, 2), axis=1)
        c = jnp.sum(att * C, axis=1)
        deep = jax.nn.sigmoid(jnp.mean(mlp_apply(params, "off", X, 2), axis=1))
        o = jnp.min(O, axis=1) * deep                           # shrink
        return self._join(c, o)

    def union(self, params, X):
        # Enclosing-box surrogate (native Q2B rewrites unions to DNF).
        C, O = self._split(X)
        c = jnp.mean(C, axis=1)
        o = jnp.max(jnp.abs(C - c[:, None, :]) + O, axis=1)
        return self._join(c, o)

    def negate(self, params, x):
        return mlp_apply(params, "neg", x, 2)

    def distance(self, params, q, ent_vec):
        c, o = self._split(q)
        delta = jnp.abs(ent_vec - c)
        d_out = jnp.sum(jnp.maximum(delta - o, 0.0), axis=-1)
        d_in = jnp.sum(jnp.minimum(delta, o), axis=-1)
        return d_out + self.ALPHA * d_in
