"""Pre-trained Text Encoder (PTE) substrate — §4.4.

The container is offline, so Qwen3-Embedding / BGE are stood in by a small
deterministic transformer encoder over synthetic "descriptions" (token
sequences derived from an entity's id and graph neighborhood). The system
treats H_sem as an opaque [E, d_l] buffer either way, so every systems claim
(decoupled offline encode, unload, GPU-resident gather) is exercised for real;
only the linguistic content is synthetic.

To make the semantic prior *useful* (the paper's +MRR effect), descriptions
mention neighbor entities, so entities that co-occur in the graph get nearby
embeddings — the same reason real textual priors help on sparse KGs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.kg import KnowledgeGraph

_DESC_LEN = 16
_VOCAB = 4096


@dataclasses.dataclass
class PTEConfig:
    name: str = "stub-qwen3-embedding-0.6b"
    d_l: int = 1024        # Qwen3-Embedding-0.6B output dim
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    seed: int = 1234


class StubPTE:
    """Frozen stub encoder with a real (small) transformer forward pass, so
    joint-training benchmarks pay a genuine per-batch inference cost."""

    def __init__(self, cfg: PTEConfig = PTEConfig()):
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        d, h = cfg.d_model, cfg.d_model * 4
        ks = jax.random.split(key, 4 + 4 * cfg.n_layers)
        s = 1.0 / np.sqrt(d)
        self.params = {
            "tok": jax.random.normal(ks[0], (_VOCAB, d)) * s,
            "pos": jax.random.normal(ks[1], (_DESC_LEN, d)) * s,
            "out_w": jax.random.normal(ks[2], (d, cfg.d_l)) * s,
            "out_b": jnp.zeros((cfg.d_l,)),
        }
        for i in range(cfg.n_layers):
            k0, k1, k2, k3 = ks[4 + 4 * i : 8 + 4 * i]
            self.params[f"l{i}_qkv"] = jax.random.normal(k0, (d, 3 * d)) * s
            self.params[f"l{i}_o"] = jax.random.normal(k1, (d, d)) * s
            self.params[f"l{i}_up"] = jax.random.normal(k2, (d, h)) * s
            self.params[f"l{i}_down"] = jax.random.normal(k3, (h, d)) * s
        self.unloaded = False

    # -- synthetic descriptions ------------------------------------------------
    @staticmethod
    def descriptions(kg: KnowledgeGraph, ent_ids: np.ndarray) -> np.ndarray:
        """Token sequence per entity: hashed id tokens + first neighbors."""
        indptr, rels, tails = kg.relations_by_head
        toks = np.zeros((len(ent_ids), _DESC_LEN), dtype=np.int32)
        for i, e in enumerate(np.asarray(ent_ids)):
            e = int(e)
            row = [e % _VOCAB, (e * 2654435761) % _VOCAB]
            lo, hi = indptr[e], indptr[e + 1]
            for j in range(lo, min(hi, lo + (_DESC_LEN - 2) // 2)):
                row.append(int(rels[j]) % _VOCAB)
                row.append(int(tails[j]) % _VOCAB)
            toks[i, : len(row)] = row[:_DESC_LEN]
        return toks

    # -- forward ---------------------------------------------------------------
    def encode_tokens(self, tokens: jnp.ndarray) -> jnp.ndarray:
        if self.unloaded:
            raise RuntimeError("PTE has been unloaded (decoupled phase ended)")
        p = self.params
        x = p["tok"][tokens] + p["pos"][None, :, :]
        d = self.cfg.d_model
        nh = self.cfg.n_heads
        hd = d // nh
        for i in range(self.cfg.n_layers):
            qkv = x @ p[f"l{i}_qkv"]
            q, k, v = jnp.split(qkv, 3, axis=-1)

            def heads(t):
                return t.reshape(t.shape[0], t.shape[1], nh, hd).transpose(0, 2, 1, 3)

            q, k, v = heads(q), heads(k), heads(v)
            att = jax.nn.softmax(jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd), axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(x.shape)
            x = x + o @ p[f"l{i}_o"]
            x = x + jax.nn.gelu(x @ p[f"l{i}_up"]) @ p[f"l{i}_down"]
        pooled = x.mean(axis=1)
        return pooled @ p["out_w"] + p["out_b"]

    def encode_entities(self, kg: KnowledgeGraph, ent_ids: np.ndarray) -> jnp.ndarray:
        return self.encode_tokens(jnp.asarray(self.descriptions(kg, ent_ids)))

    def unload(self) -> None:
        """§4.4: 'once H_sem is generated, the PTE is unloaded from memory'."""
        self.params = None
        self.unloaded = True


def precompute_semantic_table(
    kg: KnowledgeGraph,
    pte: Optional[StubPTE] = None,
    batch_size: int = 256,
    unload: bool = True,
    smooth: float = 0.5,
) -> np.ndarray:
    """Offline pre-computation phase (Eq. 10): encode every entity, L2
    normalize, then one hop of neighbor smoothing (stands in for the semantic
    relatedness real descriptions carry). Returns host numpy; callers register
    it as a device-resident buffer."""
    pte = pte or StubPTE()
    enc = jax.jit(pte.encode_tokens)
    out = []
    ids = np.arange(kg.n_entities)
    for lo in range(0, kg.n_entities, batch_size):
        chunk = ids[lo : lo + batch_size]
        out.append(np.asarray(enc(jnp.asarray(StubPTE.descriptions(kg, chunk)))))
    table = np.concatenate(out, axis=0)
    table /= np.linalg.norm(table, axis=1, keepdims=True) + 1e-6
    if smooth > 0:
        nb = np.zeros_like(table)
        cnt = np.ones((kg.n_entities, 1))
        np.add.at(nb, kg.triples[:, 0], table[kg.triples[:, 2]])
        np.add.at(cnt, kg.triples[:, 0], 1.0)
        np.add.at(nb, kg.triples[:, 2], table[kg.triples[:, 0]])
        np.add.at(cnt, kg.triples[:, 2], 1.0)
        table = table + smooth * nb / cnt
        table /= np.linalg.norm(table, axis=1, keepdims=True) + 1e-6
    if unload:
        pte.unload()
    return table.astype(np.float32)
