"""Pre-trained Text Encoder (PTE) substrate — §4.4.

The container is offline, so Qwen3-Embedding / BGE are stood in by a small
deterministic transformer encoder over synthetic "descriptions" (token
sequences derived from an entity's id and graph neighborhood). The system
treats H_sem as an opaque [E, d_l] buffer either way, so every systems claim
(decoupled offline encode, unload, GPU-resident gather) is exercised for real;
only the linguistic content is synthetic.

To make the semantic prior *useful* (the paper's +MRR effect), descriptions
mention neighbor entities, so entities that co-occur in the graph get nearby
embeddings — the same reason real textual priors help on sparse KGs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.kg import KnowledgeGraph

_DESC_LEN = 16
_VOCAB = 4096


@dataclasses.dataclass
class PTEConfig:
    name: str = "stub-qwen3-embedding-0.6b"
    d_l: int = 1024        # Qwen3-Embedding-0.6B output dim
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    seed: int = 1234


class StubPTE:
    """Frozen stub encoder with a real (small) transformer forward pass, so
    joint-training benchmarks pay a genuine per-batch inference cost."""

    def __init__(self, cfg: PTEConfig = PTEConfig()):
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        d, h = cfg.d_model, cfg.d_model * 4
        ks = jax.random.split(key, 4 + 4 * cfg.n_layers)
        s = 1.0 / np.sqrt(d)
        self.params = {
            "tok": jax.random.normal(ks[0], (_VOCAB, d)) * s,
            "pos": jax.random.normal(ks[1], (_DESC_LEN, d)) * s,
            "out_w": jax.random.normal(ks[2], (d, cfg.d_l)) * s,
            "out_b": jnp.zeros((cfg.d_l,)),
        }
        for i in range(cfg.n_layers):
            k0, k1, k2, k3 = ks[4 + 4 * i : 8 + 4 * i]
            self.params[f"l{i}_qkv"] = jax.random.normal(k0, (d, 3 * d)) * s
            self.params[f"l{i}_o"] = jax.random.normal(k1, (d, d)) * s
            self.params[f"l{i}_up"] = jax.random.normal(k2, (d, h)) * s
            self.params[f"l{i}_down"] = jax.random.normal(k3, (h, d)) * s
        self.unloaded = False

    # -- synthetic descriptions ------------------------------------------------
    @staticmethod
    def descriptions(kg: KnowledgeGraph, ent_ids: np.ndarray) -> np.ndarray:
        """Token sequence per entity: hashed id tokens + first neighbors.

        Fully vectorized (one numpy pass per neighbor position, not a Python
        loop per entity) so store precompute on large synthetic KGs is not
        host-bound on tokenization."""
        indptr, rels, tails = kg.relations_by_head
        ids = np.asarray(ent_ids, dtype=np.int64).ravel()
        toks = np.zeros((len(ids), _DESC_LEN), dtype=np.int32)
        toks[:, 0] = ids % _VOCAB
        # (e * K) % V == ((e % V) * (K % V)) % V — overflow-safe in int64.
        toks[:, 1] = (ids % _VOCAB) * (2654435761 % _VOCAB) % _VOCAB
        lo = indptr[ids]
        max_pairs = (_DESC_LEN - 2) // 2
        deg = np.minimum(indptr[ids + 1] - lo, max_pairs)
        for j in range(max_pairs):
            m = deg > j
            if not m.any():
                break
            src = lo[m] + j
            toks[m, 2 + 2 * j] = rels[src] % _VOCAB
            toks[m, 3 + 2 * j] = tails[src] % _VOCAB
        return toks

    # -- forward ---------------------------------------------------------------
    def encode_tokens(self, tokens: jnp.ndarray) -> jnp.ndarray:
        if self.unloaded:
            raise RuntimeError("PTE has been unloaded (decoupled phase ended)")
        p = self.params
        x = p["tok"][tokens] + p["pos"][None, :, :]
        d = self.cfg.d_model
        nh = self.cfg.n_heads
        hd = d // nh
        for i in range(self.cfg.n_layers):
            qkv = x @ p[f"l{i}_qkv"]
            q, k, v = jnp.split(qkv, 3, axis=-1)

            def heads(t):
                return t.reshape(t.shape[0], t.shape[1], nh, hd).transpose(0, 2, 1, 3)

            q, k, v = heads(q), heads(k), heads(v)
            att = jax.nn.softmax(jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd), axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(x.shape)
            x = x + o @ p[f"l{i}_o"]
            x = x + jax.nn.gelu(x @ p[f"l{i}_up"]) @ p[f"l{i}_down"]
        pooled = x.mean(axis=1)
        return pooled @ p["out_w"] + p["out_b"]

    def encode_entities(self, kg: KnowledgeGraph, ent_ids: np.ndarray) -> jnp.ndarray:
        return self.encode_tokens(jnp.asarray(self.descriptions(kg, ent_ids)))

    def unload(self) -> None:
        """§4.4: 'once H_sem is generated, the PTE is unloaded from memory'."""
        self.params = None
        self.unloaded = True


def encode_normalized_batches(kg: KnowledgeGraph, pte: StubPTE,
                              batch_size: int = 256):
    """Yield L2-normalized encoder outputs in fixed global batch boundaries.

    Shared by the in-memory ``precompute_semantic_table`` and the streaming
    ``semantic/store.py::precompute_semantic_table_to_store``. Both consume
    the SAME batch boundaries (``range(0, E, batch_size)``) so the jitted
    encoder sees identical shapes and the two paths stay bit-identical;
    normalization is per-row, hence batch-local."""
    enc = jax.jit(pte.encode_tokens)
    ids = np.arange(kg.n_entities)
    for lo in range(0, kg.n_entities, batch_size):
        chunk = ids[lo : lo + batch_size]
        block = np.array(enc(jnp.asarray(StubPTE.descriptions(kg, chunk))))
        block /= np.linalg.norm(block, axis=1, keepdims=True) + 1e-6
        yield block


def precompute_semantic_table(
    kg: KnowledgeGraph,
    pte: Optional[StubPTE] = None,
    batch_size: int = 256,
    unload: bool = True,
    smooth: float = 0.5,
) -> np.ndarray:
    """Offline pre-computation phase (Eq. 10): encode every entity, L2
    normalize, then one hop of neighbor smoothing (stands in for the semantic
    relatedness real descriptions carry). Returns host numpy; callers register
    it as a device-resident buffer.

    This is the FULL-RESIDENT path (small graphs / ablation). At scale, use
    ``semantic/store.py::precompute_semantic_table_to_store`` — it streams the
    same computation shard-by-shard to disk without ever holding the
    ``[E, d_l]`` table in host RAM, and its fp32 output is bit-identical."""
    pte = pte or StubPTE()
    table = np.concatenate(
        list(encode_normalized_batches(kg, pte, batch_size)), axis=0)
    if smooth > 0:
        nb = np.zeros_like(table)
        cnt = np.ones((kg.n_entities, 1))
        np.add.at(nb, kg.triples[:, 0], table[kg.triples[:, 2]])
        np.add.at(cnt, kg.triples[:, 0], 1.0)
        np.add.at(nb, kg.triples[:, 2], table[kg.triples[:, 0]])
        np.add.at(cnt, kg.triples[:, 2], 1.0)
        table = table + smooth * nb / cnt
        table /= np.linalg.norm(table, axis=1, keepdims=True) + 1e-6
    if unload:
        pte.unload()
    return table.astype(np.float32)
